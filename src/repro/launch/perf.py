import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-lower ONE cell under a named variant and diff
its roofline terms against the baseline JSON.

  PYTHONPATH=src python -m repro.launch.perf --arch gemma3_12b \
      --shape decode_32k --variant serve_replicated

Variants (the §Perf iteration levers):
  serve_replicated — decode/prefill with fsdp=False: weights replicated
                     over `data`, sharded over `model` only. Kills the
                     per-step FSDP param all-gather that dominates decode.
  seq_parallel     — shard long-context KV over `data` AND activations'
                     sequence axis between TP blocks.
  ring_kv          — window-bounded KV cache for uniform-sliding-window
                     archs (mixtral): cache length = window, not seq_len.
  microbatch4      — gradient accumulation over 4 microbatches (activation
                     memory lever for train cells).
  remat_full       — full activation rematerialization (memory vs FLOPs).
  unroll_layers    — scan_layers=False (latency vs compile-size lever).
"""
import argparse
import json
from typing import Any, Dict, Optional, Tuple

from ..configs import ARCHS, SHAPES, get_config
from ..models.config import ModelConfig
from ..parallel.sharding import MeshPolicy
from .dryrun import RESULTS, cell_path, run_cell
from .inputs import cell_policy

VARIANTS = ("serve_replicated", "seq_parallel", "ring_kv", "microbatch4",
            "remat_full", "unroll_layers", "grad_compress", "capacity_1x",
            "serve_replicated_ring", "baseline")


def variant_overrides(variant: str, cfg: ModelConfig, shape: str
                      ) -> Tuple[ModelConfig, Optional[MeshPolicy],
                                 Dict[str, Any]]:
    """Returns (cfg', policy' or None to use default, run_cell kwargs)."""
    kind = SHAPES[shape]["kind"]
    if variant == "serve_replicated":
        assert kind in ("decode", "prefill"), "serving-only variant"
        pol = cell_policy(cfg, shape, fsdp=False)
        return cfg, pol, {}
    if variant == "seq_parallel":
        pol = cell_policy(cfg, shape).with_rules(kv_seq="data", seq=None)
        return cfg, pol, {}
    if variant == "ring_kv":
        assert cfg.sliding_window and not cfg.global_interval, \
            "uniform-SWA archs only"
        return cfg, None, {"kv_len_override": cfg.sliding_window}
    if variant == "serve_replicated_ring":
        assert cfg.sliding_window and kind == "decode"
        pol = cell_policy(cfg, shape, fsdp=False)
        return cfg, pol, {"kv_len_override": cfg.sliding_window}
    if variant == "microbatch4":
        assert kind == "train"
        return cfg, None, {"microbatches": 4}
    if variant == "remat_full":
        return cfg.derive(remat="full"), None, {}
    if variant == "grad_compress":
        assert kind == "train"
        return cfg.derive(grad_compress=True), None, {}
    if variant == "capacity_1x":
        assert cfg.is_moe
        return cfg.derive(capacity_factor=1.0), None, {}
    if variant == "unroll_layers":
        return cfg.derive(scan_layers=False), None, {}
    return cfg, None, {}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--variant", choices=VARIANTS, required=True)
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cfg2, pol, kw = variant_overrides(args.variant, cfg, args.shape)
    res = run_cell(args.arch, args.shape, multi_pod=args.multipod,
                   cfg_override=cfg2, policy_override=pol, **kw)
    out = RESULTS / (f"{args.arch}__{args.shape}__"
                     f"{'2x16x16' if args.multipod else '16x16'}"
                     f"__{args.variant}.json")
    out.write_text(json.dumps(res, indent=1))

    base_p = cell_path(args.arch, args.shape, args.multipod)
    if base_p.exists():
        base = json.loads(base_p.read_text())
        b, v = base["roofline"], res["roofline"]
        print(f"--- {args.arch} {args.shape} : baseline -> {args.variant}")
        for term in ("compute_s", "memory_s", "collective_s"):
            delta = (v[term] / b[term] - 1) * 100 if b[term] else 0.0
            print(f"{term:14s} {b[term]:10.4f} -> {v[term]:10.4f} "
                  f"({delta:+.1f}%)")
        print(f"dominant       {b['dominant']} -> {v['dominant']}   "
              f"bound {b['bound_s']:.4f}s -> {v['bound_s']:.4f}s "
              f"({(v['bound_s'] / b['bound_s'] - 1) * 100:+.1f}%)")
        bf = base["model_flops"]["roofline_fraction"]
        vf = res["model_flops"]["roofline_fraction"]
        print(f"roofline frac  {bf:.4f} -> {vf:.4f}")


if __name__ == "__main__":
    main()
