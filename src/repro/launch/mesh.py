"""Production mesh construction (dry-run target topology).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests run on 1 CPU device; only dryrun.py
sets XLA_FLAGS for 512 placeholder devices).
"""
from __future__ import annotations


import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod. The `pod`
    axis is the DCN-linked outer axis (gradient all-reduce only); `data`
    and `model` are ICI axes."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist right now (smoke tests: 1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
