import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
AND the 2x16x16 multi-pod mesh:

  1. **compile proof** — jax.jit(step).lower(**ShapeDtypeStructs).compile()
     of the FULL-depth model (scan-over-layers) with explicit in/out
     shardings; `memory_analysis()` proves per-device footprint,
     `cost_analysis()` is recorded raw.
  2. **roofline accounting** — XLA's cost analysis visits while-loop bodies
     ONCE and reports per-device numbers (verified empirically; see
     EXPERIMENTS.md §Methodology). So FLOPs/bytes/collective-bytes are
     measured from small-depth UNROLLED compiles at full width and
     extrapolated linearly over the layer period:
         total(L) = F(P) + (L/P - 1) * (F(2P) - F(P))
     which is exact for homogeneous-period stacks (P = local:global period
     for gemma3, shared-attn interval for zamba2, else 1). Collective bytes
     are parsed from the compiled HLO (all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute operand bytes).

Results land in results/dryrun/<arch>__<shape>__<mesh>.json; benchmarks
and EXPERIMENTS.md tables read from there.

Usage:
  python -m repro.launch.dryrun --arch gemma3_12b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--skip-existing]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ARCHS, LONG_CONTEXT_OK, SHAPES, cells, get_config
from ..models import abstract_params, param_specs
from ..models.config import ModelConfig
from ..models.params import axes_tree
from ..parallel.sharding import MeshPolicy, logical_to_pspec
from ..train.optimizer import adamw_abstract
from ..train.step import decode_step_fn, prefill_step_fn, train_step_fn
from .analytic import analytic_bytes, analytic_collective_bytes
from .inputs import batch_axes, batch_specs, cache_abstract, cell_policy
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_IS_AXES = lambda l: (isinstance(l, tuple) and
                      all(isinstance(a, (str, type(None))) for a in l))


def _shardings(tree_axes: Any, policy: MeshPolicy, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, logical_to_pspec(ax, policy, mesh)),
        tree_axes, is_leaf=_IS_AXES)


# ---------------------------------------------------------------------------
# collective-bytes parsing
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind over the HLO module.
    (Loop bodies appear once — callers handle trip-count extrapolation.)"""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_s, kind = m.group(1), m.group(2)
        total = 0
        for dm in _SHAPE_RE.finditer(shape_s):
            dt, dims = dm.group(1), dm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    return out


def weighted_collective_bytes(per_kind: Dict[str, float]) -> float:
    """Bytes actually moved per chip: ring all-reduce moves ~2x its payload,
    ag/rs/a2a/permute ~1x."""
    w = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}
    return sum(v * w.get(k, 1.0) for k, v in per_kind.items())


# ---------------------------------------------------------------------------
# per-cell compile
# ---------------------------------------------------------------------------


def _derive_depth(cfg: ModelConfig, L: int, seq: int) -> ModelConfig:
    """Reduced-depth, full-width variant for the cost-extrapolation
    compiles: layers unrolled, inner scans unrolled, attention tiles sized
    so long-sequence HLO stays bounded (~16 q-blocks)."""
    kw: Dict[str, Any] = {"n_layers": L, "scan_layers": False,
                          "unroll_scans": True,
                          "attn_block_q": max(512, seq // 16),
                          "attn_block_k": max(512, min(seq // 16,
                                                       cfg.sliding_window or
                                                       seq))}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = L
        kw["n_dec_layers"] = L
    return cfg.derive(**kw)


def _period(cfg: ModelConfig) -> int:
    if cfg.global_interval:
        return cfg.global_interval
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        return cfg.shared_attn_every
    return 1


def lower_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh,
               policy: MeshPolicy, *, compile_: bool = True,
               microbatches: int = 1,
               kv_len_override: Optional[int] = None):
    """Build + lower (+ compile) the step function for one cell."""
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    p_specs = param_specs(cfg)
    p_abs = abstract_params(p_specs)
    p_axes = axes_tree(p_specs)
    p_sh = _shardings(p_axes, policy, mesh)
    b_abs = batch_specs(cfg, shape_name)
    b_sh = _shardings(batch_axes(cfg, shape_name), policy, mesh)

    if kind == "train":
        o_abs = adamw_abstract(p_abs)
        o_sh = {"mu": p_sh, "nu": p_sh, "step": NamedSharding(mesh, P())}

        def step(params, opt_state, batch):
            return train_step_fn(params, opt_state, batch, cfg=cfg,
                                 policy=policy, mesh=mesh,
                                 microbatches=microbatches)
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          donate_argnums=(0, 1)).lower(p_abs, o_abs, b_abs)
    elif kind == "prefill":
        c_abs, c_axes = cache_abstract(cfg, shape_name)
        c_sh = _shardings(c_axes, policy, mesh)

        def step(params, batch, cache):
            return prefill_step_fn(params, batch, cache, cfg=cfg,
                                   policy=policy, mesh=mesh)
        lowered = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                          donate_argnums=(2,)).lower(p_abs, b_abs, c_abs)
    else:  # decode
        c_abs, c_axes = cache_abstract(cfg, shape_name,
                                       kv_len=kv_len_override)
        c_sh = _shardings(c_axes, policy, mesh)
        i_abs = jax.ShapeDtypeStruct((), jnp.int32)

        def step(params, batch, cache, index):
            return decode_step_fn(params, batch, cache, index, cfg=cfg,
                                  policy=policy, mesh=mesh)
        lowered = jax.jit(
            step, in_shardings=(p_sh, b_sh, c_sh, NamedSharding(mesh, P())),
            donate_argnums=(2,)).lower(p_abs, b_abs, c_abs, i_abs)
    if not compile_:
        return lowered, None
    return lowered, lowered.compile()


def _cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             fast: bool = False, cfg_override: Optional[ModelConfig] = None,
             policy_override: Optional[MeshPolicy] = None,
             microbatches: int = 1,
             kv_len_override: Optional[int] = None) -> Dict[str, Any]:
    """Full dry-run for one cell: compile proof + extrapolated roofline.
    Overrides support the §Perf variants (launch/perf.py)."""
    t_start = time.time()
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    model_axis = mesh.shape["model"]
    data_axis = mesh.shape["data"]
    n_pods = mesh.shape.get("pod", 1)
    policy = policy_override if policy_override is not None else \
        cell_policy(cfg, shape_name, model_axis=model_axis,
                    data_axis=data_axis, n_pods=n_pods)
    sh = SHAPES[shape_name]
    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "kind": sh["kind"], "n_chips": n_chips,
        "policy": {"fsdp": policy.fsdp, "seq_shard": policy.seq_shard,
                   "rules": dict(policy.rules)},
    }

    # ---- 1. full-depth compile proof + memory analysis ------------------
    lowered, compiled = lower_cell(cfg, shape_name, mesh, policy,
                                   microbatches=microbatches,
                                   kv_len_override=kv_len_override)
    ma = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
    }
    out["cost_raw"] = _cost_dict(compiled)
    out["compile_ok"] = True
    out["compile_s"] = round(time.time() - t_start, 1)

    if fast:
        # analytic-only roofline (no extrapolation compiles): compute term
        # from MODEL flops (a lower bound — labeled); memory/collective
        # from the analytic TPU models. Used for cells whose fully-unrolled
        # cost compiles are impractical on one CPU core, and for the
        # multi-pod compile-proof pass.
        mesh_shape = {a: mesh.shape[a] for a in mesh.axis_names}
        ana = analytic_bytes(cfg, shape_name, policy, mesh_shape)
        ana_coll = analytic_collective_bytes(cfg, shape_name, policy,
                                             mesh_shape)
        n_active = cfg.active_param_count()
        tokens = sh["batch"] * (sh["seq"] if sh["kind"] != "decode" else 1)
        mult = 6 if sh["kind"] == "train" else 2
        model_flops = mult * n_active * tokens
        compute_s = model_flops / n_chips / PEAK_FLOPS
        memory_s = ana["total"] / HBM_BW
        collective_s = ana_coll["total"] / ICI_BW
        dom = max((("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s)), key=lambda t: t[1])
        out["roofline"] = {"compute_s": compute_s, "memory_s": memory_s,
                           "collective_s": collective_s,
                           "dominant": dom[0], "bound_s": dom[1],
                           "analytic_only": True}
        out["model_flops"] = {
            "n_active_params": n_active, "tokens": tokens,
            "model_flops": model_flops,
            "hlo_flops_global": 0.0, "useful_ratio": 0.0,
            "roofline_fraction": (model_flops / n_chips / PEAK_FLOPS)
            / dom[1] if dom[1] else 0.0}

    # ---- 2. roofline accounting via depth extrapolation ------------------
    if not fast:
        Pd = _period(cfg)
        L = cfg.n_enc_layers if cfg.family == "encdec" else cfg.n_layers
        reps = L // Pd
        costs = []
        for depth_reps in (1, 2):
            c_small = _derive_depth(cfg, Pd * depth_reps, sh["seq"])
            _, comp_small = lower_cell(c_small, shape_name, mesh, policy,
                                       microbatches=microbatches,
                                       kv_len_override=kv_len_override)
            cd = _cost_dict(comp_small)
            cd["coll"] = collective_bytes(comp_small.as_text())
            costs.append(cd)
        def _extrap(v1: float, v2: float) -> float:
            d = v2 - v1
            if d <= 0:
                # XLA CSE/DCE across the duplicated layers can make the
                # 2P-depth compile cheaper per layer than P-depth; fall
                # back to the per-period average of the deeper compile
                return (v2 / 2.0) * (reps + 1)
            return v1 + (reps - 1) * d

        flops_dev = _extrap(costs[0]["flops"], costs[1]["flops"])
        bytes_dev = _extrap(costs[0]["bytes"], costs[1]["bytes"])
        coll: Dict[str, float] = {}
        for k in set(costs[0]["coll"]) | set(costs[1]["coll"]):
            coll[k] = _extrap(costs[0]["coll"].get(k, 0.0),
                              costs[1]["coll"].get(k, 0.0))
        coll_dev = weighted_collective_bytes(coll)
        mesh_shape = {a: mesh.shape[a] for a in mesh.axis_names}
        ana = analytic_bytes(cfg, shape_name, policy, mesh_shape)
        ana_coll = analytic_collective_bytes(cfg, shape_name, policy,
                                             mesh_shape)
        out["per_device"] = {"flops": flops_dev,
                             "bytes_hlo_upper": bytes_dev,
                             "bytes_kernelized": ana["total"],
                             "bytes_breakdown": ana,
                             "collective_bytes_hlo": coll_dev,
                             "collective_bytes_analytic": ana_coll["total"],
                             "collective_breakdown": ana_coll,
                             "collectives_by_kind": coll}
        # roofline terms (seconds). memory/collective use the analytic TPU
        # models; the HLO-parsed numbers (recorded alongside) are upper
        # bounds — XLA:CPU neither fuses flash/SSD blocks (inflating bytes)
        # nor prices ICI (inflating its choice of resharding collectives).
        compute_s = flops_dev / PEAK_FLOPS
        memory_s = ana["total"] / HBM_BW
        memory_s_upper = bytes_dev / HBM_BW
        collective_s = ana_coll["total"] / ICI_BW
        collective_s_upper = coll_dev / ICI_BW
        dominant = max((("compute", compute_s), ("memory", memory_s),
                        ("collective", collective_s)), key=lambda t: t[1])
        out["roofline"] = {
            "compute_s": compute_s, "memory_s": memory_s,
            "memory_s_hlo_upper": memory_s_upper,
            "collective_s": collective_s,
            "collective_s_hlo_upper": collective_s_upper,
            "dominant": dominant[0],
            "bound_s": dominant[1],
        }
        # model flops: 6*N*D train, 2*N*D inference, N = active params
        n_active = cfg.active_param_count()
        tokens = sh["batch"] * (sh["seq"] if sh["kind"] != "decode" else 1)
        mult = 6 if sh["kind"] == "train" else 2
        model_flops = mult * n_active * tokens
        hlo_flops_global = flops_dev * n_chips
        out["model_flops"] = {
            "n_active_params": n_active, "tokens": tokens,
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_ratio": (model_flops / hlo_flops_global
                             if hlo_flops_global else 0.0),
            "roofline_fraction": (model_flops / n_chips / PEAK_FLOPS)
            / dominant[1] if dominant[1] else 0.0,
        }
    out["elapsed_s"] = round(time.time() - t_start, 1)
    return out


def cell_path(arch: str, shape: str, multi_pod: bool) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    return RESULTS / f"{arch}__{shape}__{mesh}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="compile proof only (skip roofline extrapolation)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    todo = []
    if args.all:
        for a, s, skip in cells():
            todo.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        if args.shape == "long_500k" and args.arch not in LONG_CONTEXT_OK:
            print(f"SKIP {args.arch} long_500k (pure full-attention; "
                  "see DESIGN.md §3.3)")
            return
        todo.append((args.arch, args.shape))

    n_fail = 0
    for arch, shape in todo:
        path = cell_path(arch, shape, args.multipod)
        if args.skip_existing and path.exists():
            print(f"cached {path.name}")
            continue
        try:
            res = run_cell(arch, shape, multi_pod=args.multipod,
                           fast=args.fast)
            path.write_text(json.dumps(res, indent=1))
            rl = res.get("roofline", {})
            print(f"OK  {arch:22s} {shape:12s} mesh={res['mesh']:8s} "
                  f"dominant={rl.get('dominant', '-'):10s} "
                  f"compile={res['compile_s']}s")
        except Exception as e:
            n_fail += 1
            traceback.print_exc()
            print(f"FAIL {arch} {shape}: {type(e).__name__}: {e}")
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
