"""End-to-end training driver.

Wires together: config registry -> data pipeline (registry-backed shards)
-> pjit train step -> checkpoint manager (manifests in the metadata plane)
-> fleet runtime (heartbeats, failover, elastic re-mesh).

On this container it trains reduced configs on the host mesh; on a pod the
same driver takes ``--mesh pod`` and the production sharding policy.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_4b \
      --smoke --steps 20 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config, get_smoke_config
from ..data import DataPipeline, synthetic_batch
from ..metaplane import MetadataPlane
from ..models import init_params, param_specs
from ..parallel.sharding import MeshPolicy
from ..ckpt import CheckpointManager
from ..runtime import FleetRuntime
from ..train.optimizer import OptConfig, adamw_init
from ..train.step import make_train_step
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen1_5_4b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-worker-at", type=int, default=-1,
                    help="inject a worker failure at this step (demo)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    policy = MeshPolicy()
    job = f"{args.arch}-train"

    plane = MetadataPlane()
    fleet = FleetRuntime(plane, n_workers=4, model_axis=mesh.shape["model"])
    pipeline = DataPipeline(plane, f"{args.arch}-ds", n_shards=16)
    ckpt = CheckpointManager(args.ckpt_dir, plane, job, keep=2)

    specs = param_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    start = 0
    if args.resume:
        restored = ckpt.restore_latest()
        if restored is not None:
            start, p_np, o_np = restored
            params = jax.tree.map(jnp.asarray, p_np)
            opt_state = jax.tree.map(jnp.asarray, o_np)
            print(f"resumed from step {start}")

    opt = OptConfig(total_steps=max(args.steps, 1))
    step_fn = jax.jit(make_train_step(cfg, policy, mesh, opt=opt,
                                      microbatches=args.microbatches))

    t0 = time.time()
    for step in range(start, args.steps):
        fleet.tick()
        plane.tick()
        if step == args.fail_worker_at:
            fleet.fail_worker(0)
            print(f"[step {step}] injected worker-0 failure; "
                  f"leader={fleet.leader()} mesh={fleet.maybe_remesh()}")
        shard = pipeline.lease(worker=fleet.leader() or 0)
        if shard is not None:
            pipeline.heartbeat(fleet.leader() or 0, shard)
        b = synthetic_batch(args.batch, args.seq, cfg.vocab_size, step=step)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.ones(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
            batch["positions"] = jnp.zeros((args.batch, args.seq, 3),
                                           jnp.int32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.ones(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if shard is not None:
            pipeline.complete(fleet.leader() or 0, shard)
        plane.record_step(job, step, loss=float(loss))
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):8.4f} "
                  f"({time.time() - t0:5.1f}s)")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state)
            print(f"checkpointed step {step + 1}")
    print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s; "
          f"ledger last step = {plane.last_step(job)}")


if __name__ == "__main__":
    main()
