"""ShapeDtypeStruct stand-ins for every model input per (arch x shape)
cell — weak-type-correct, shardable, no device allocation (dry-run only).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import SHAPES
from ..models import init_cache_specs
from ..models.config import ModelConfig
from ..models.params import axes_tree
from ..parallel.sharding import MeshPolicy


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


#: cache leaves stored in bf16: KV caches + activation carries (conv
#: window, token-shift). The accumulating recurrent states (SSD `h`,
#: WKV `wkv`) stay f32.
_BF16_CACHE_KEYS = {"k", "v", "shared_k", "shared_v", "enc_out",
                    "conv", "shift_a", "shift_f"}


def batch_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for one shape cell."""
    sh = SHAPES[shape_name]
    B, S, kind = sh["batch"], sh["seq"], sh["kind"]
    if kind == "train":
        batch: Dict[str, Any] = {
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
        }
    elif kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
    else:  # decode: one new token; the CACHE holds the seq_len context
        batch = {"tokens": sds((B, 1), jnp.int32)}
    # modality frontends are stubs: precomputed embeddings (assignment)
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        batch["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                    jnp.bfloat16)
        batch["positions"] = sds((B, S), jnp.int32)  # broadcast to 3D inside
        batch["positions"] = sds((B, S, 3), jnp.int32)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        batch["frames"] = sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return batch


def batch_axes(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    axes: Dict[str, Any] = {"tokens": ("batch", None)}
    if kind == "train":
        axes["labels"] = ("batch", None)
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        axes["patch_embeds"] = ("batch", None, "act_embed")
        axes["positions"] = ("batch", None, None)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        axes["frames"] = ("batch", None, "act_embed")
    return axes


def cache_abstract(cfg: ModelConfig, shape_name: str,
                   kv_len: Optional[int] = None) -> Tuple[Any, Any]:
    """(abstract cache tree, cache logical-axes tree) for decode cells.
    `kv_len` overrides the cache length (ring_kv variant: window-bounded
    caches for uniform sliding-window archs)."""
    sh = SHAPES[shape_name]
    specs = init_cache_specs(cfg, sh["batch"], kv_len or sh["seq"])
    # kv caches are bf16; recurrent states (SSD h, WKV S, conv/shift
    # carries) stay f32 (they accumulate across the whole sequence)
    abstract = {k: jax.ShapeDtypeStruct(
        s.shape, jnp.bfloat16 if k in _BF16_CACHE_KEYS else jnp.float32)
        for k, s in specs.items()}
    return abstract, axes_tree(specs)


def cell_policy(cfg: ModelConfig, shape_name: str, *,
                model_axis: int = 16, data_axis: int = 16,
                n_pods: int = 1, fsdp: bool = True) -> MeshPolicy:
    """Sharding policy for one (arch x shape) cell, handling divisibility
    fallbacks (see DESIGN.md hardware-adaptation notes):
      - heads/kv_heads replicated when not divisible by the model axis;
      - batch replicated when smaller than the dp axis (long_500k B=1),
        with the KV cache sequence-sharded over `data` instead.
    """
    sh = SHAPES[shape_name]
    rules = {}
    dp = data_axis * n_pods
    if cfg.n_heads % model_axis:
        rules["heads"] = None
    if cfg.n_kv_heads % model_axis:
        rules["kv_heads"] = None
    if cfg.d_model % model_axis and False:
        rules["heads_flat"] = None
    if (cfg.d_model // cfg.rwkv_head_dim) and cfg.family == "ssm" and \
            cfg.d_model % model_axis:
        rules["heads_flat"] = None
    if cfg.vocab_size % model_axis:
        rules["vocab"] = None
    if cfg.d_ff % model_axis:
        rules["mlp"] = None
    if cfg.n_experts and cfg.n_experts % model_axis:
        # mixtral: 8 experts on a 16-way axis -> TP strategy (every chip
        # holds all experts, each expert's hidden dim sharded; see moe.py)
        rules["experts"] = None
        if (cfg.moe_d_ff or cfg.d_ff) % model_axis == 0:
            rules["expert_mlp"] = "model"
    seq_shard = False
    if sh["batch"] % dp:
        rules["batch"] = None
        seq_shard = True                    # long-context: shard KV seq
    use_fsdp = fsdp and cfg.d_model % data_axis == 0
    return MeshPolicy(fsdp=use_fsdp, seq_shard=seq_shard,
                      rules=tuple(rules.items()))
