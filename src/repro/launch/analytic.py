"""Analytic per-device HBM-traffic model (the "kernelized" memory term).

XLA:CPU's `cost_analysis()["bytes accessed"]` counts every intermediate of
the blocked-attention / SSD / WKV inner loops as memory traffic, because the
CPU backend neither fuses them nor knows they would live in VMEM inside the
TPU Pallas kernels (`repro.kernels`). That figure is therefore an UPPER
bound. This module computes the HBM bytes a kernelized TPU execution
actually moves — weights, activations entering/leaving fused blocks, KV
caches, optimizer state — per device, per step. The §Roofline table reports
both; the dominant-term analysis uses the kernelized number.

Conventions (documented in EXPERIMENTS.md §Methodology):
  * bf16 activations/weights on the compute path; f32 optimizer state;
  * train ≈ fwd traffic + 2x for bwd (read saved activations + write
    grads) + optimizer pass (3 reads + 2 writes of f32 per param on the
    local shard);
  * fused kernels (attention / SSD / WKV / MoE expert matmuls) charge only
    kernel inputs + outputs;
  * remat policies re-read layer inputs (selective ~ +1 activation pass).
"""
from __future__ import annotations

from typing import Dict

from ..configs import SHAPES
from ..models.config import ModelConfig
from ..parallel.sharding import MeshPolicy

BF16 = 2
F32 = 4


def _shards(policy: MeshPolicy, mesh_shape: Dict[str, int]):
    rules = policy.resolve()

    def size_of(logical: str) -> int:
        m = rules.get(logical)
        if m is None:
            return 1
        axes = m if isinstance(m, (tuple, list)) else (m,)
        n = 1
        for a in axes:
            n *= mesh_shape.get(a, 1)
        return n
    return size_of


def layer_param_count(cfg: ModelConfig) -> float:
    """Parameters of ONE decoder layer (all experts for MoE)."""
    d, hd, nh, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
    if cfg.family == "ssm":
        attn = 4 * d * d + d * 64 + 64 * d
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        attn = d * (2 * d_in + 2 * cfg.ssm_state) + d_in * d
    f = cfg.moe_d_ff if cfg.is_moe else cfg.d_ff
    per_expert = (3 if cfg.mlp_type == "swiglu" else 2) * d * f
    mlp = (cfg.n_experts or 1) * per_expert
    return attn + mlp + 4 * d


def active_layer_param_count(cfg: ModelConfig) -> float:
    if not cfg.is_moe:
        return layer_param_count(cfg)
    full = layer_param_count(cfg)
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = (3 if cfg.mlp_type == "swiglu" else 2) * cfg.d_model * f
    return full - (cfg.n_experts - cfg.experts_per_token) * per_expert


def analytic_bytes(cfg: ModelConfig, shape_name: str, policy: MeshPolicy,
                   mesh_shape: Dict[str, int]) -> Dict[str, float]:
    """Per-device HBM bytes for one step, assuming kernelized inner loops."""
    sh = SHAPES[shape_name]
    B, S, kind = sh["batch"], sh["seq"], sh["kind"]
    size_of = _shards(policy, mesh_shape)
    dp = size_of("batch")
    tp_mlp = size_of("mlp")
    tp_heads = size_of("heads")
    tp_vocab = size_of("vocab")
    fsdp = size_of("embed")
    n_chips = 1
    for v in mesh_shape.values():
        n_chips *= v

    d = cfg.d_model
    L = cfg.n_layers if cfg.family != "encdec" \
        else cfg.n_enc_layers + cfg.n_dec_layers
    tokens_dev = B * (S if kind != "decode" else 1) / dp

    # ---- weights traffic: each layer's local weight shard read once ----
    lp = layer_param_count(cfg)
    # MoE EP/TP shards experts; dense shards mlp/heads; fsdp shards the rest
    w_shard = max(tp_mlp, tp_heads if cfg.family not in ("ssm",) else 1,
                  size_of("experts"))
    w_dev = L * lp / max(w_shard, fsdp) + \
        2 * cfg.vocab_size * d / max(tp_vocab * fsdp, 1)
    weight_bytes = w_dev * BF16

    # ---- activation traffic: ~8 fused-block boundaries per layer --------
    act_pass = tokens_dev * d * BF16
    act_bytes = L * 8 * act_pass

    # ---- attention kernel IO -------------------------------------------
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    qkv_dev = tokens_dev * (nh + 2 * nkv) / tp_heads * hd * BF16
    attn_io = L * (qkv_dev * 2 + 2 * tokens_dev * nh / tp_heads * hd * BF16)
    if kind == "decode":
        # decode reads the whole KV cache (window-limited for local layers)
        kv_shard = size_of("kv_heads") * size_of("kv_seq")
        n_global = L
        if cfg.global_interval:
            n_global = L // cfg.global_interval
            n_local = L - n_global
        else:
            n_local = 0
        if cfg.sliding_window and not cfg.global_interval:
            n_global, n_local = 0, L
        eff_S_global, eff_S_local = S, min(S, cfg.sliding_window or S)
        if cfg.family == "ssm":
            attn_io = L * (B / dp) * (d // cfg.rwkv_head_dim) * \
                cfg.rwkv_head_dim ** 2 * F32 * 2
        elif cfg.family == "hybrid":
            d_in = cfg.ssm_expand * d
            H = cfg.ssm_heads or d_in // 64
            state = (B / dp) * H * (d_in // H) * cfg.ssm_state * F32 * 2
            n_apps = max(1, L // max(1, cfg.shared_attn_every))
            kv = n_apps * (B / dp) * S * nkv * hd / kv_shard * BF16 * 2
            attn_io = L * state + kv
        else:
            attn_io = (n_global * eff_S_global + n_local * eff_S_local) * \
                (B / dp) * nkv * hd / kv_shard * BF16 * 2

    # ---- logits ----------------------------------------------------------
    logit_bytes = tokens_dev * cfg.vocab_size / tp_vocab * BF16 * 2

    fwd = weight_bytes + act_bytes + attn_io + logit_bytes
    if kind == "train":
        n_params_dev = (L * lp + 2 * cfg.vocab_size * d) / \
            max(n_chips // dp * dp, 1)  # opt state is fully sharded
        n_params_dev = (L * lp + 2 * cfg.vocab_size * d) / n_chips
        opt_bytes = n_params_dev * (3 * F32 + 2 * F32)
        total = 3.0 * fwd + opt_bytes
    else:
        total = fwd
    return {"weight_bytes": weight_bytes, "act_bytes": act_bytes,
            "attn_io": attn_io, "logit_bytes": logit_bytes,
            "total": total}


def analytic_collective_bytes(cfg: ModelConfig, shape_name: str,
                              policy: MeshPolicy,
                              mesh_shape: Dict[str, int]
                              ) -> Dict[str, float]:
    """Expected per-device collective bytes on TPU with a tuned partitioner.

    The HLO parsed from host-device compiles overstates this: XLA:CPU's
    SPMD cost model treats communication as nearly free and happily
    all-gathers full-batch activations. On a TPU compile the partitioner
    uses ICI cost models and the schedule below is what MaxText-class
    systems observe:

      FSDP   : 2x param all-gather (fwd+bwd) + grad reduce-scatter
      TP     : 2 activation psums/layer fwd, 2 bwd (attention out, FFN out)
      EP     : 2 all-to-alls fwd + 2 bwd of the dispatched token buffers
      logits : bwd dx all-reduce over the vocab axis
      DP/pod : folded into the grad reduce-scatter bytes (DCN for pods)
    """
    sh = SHAPES[shape_name]
    B, S, kind = sh["batch"], sh["seq"], sh["kind"]
    size_of = _shards(policy, mesh_shape)
    dp = size_of("batch")
    fsdp = size_of("embed")
    tp = max(size_of("mlp"), size_of("heads"), size_of("experts"), 1)
    d = cfg.d_model
    L = cfg.n_layers if cfg.family != "encdec" \
        else cfg.n_enc_layers + cfg.n_dec_layers
    tokens_dev = B * (S if kind != "decode" else 1) / dp
    lp = layer_param_count(cfg)
    total_params = L * lp + 2 * cfg.vocab_size * d

    out: Dict[str, float] = {}
    # FSDP param movement (bf16), ring factor (n-1)/n ~ 1
    if fsdp > 1:
        n_ag = 2 if kind == "train" else 1
        out["fsdp_allgather"] = n_ag * total_params / max(tp, 1) * BF16
    # gradient reduce-scatter (+ cross-pod all-reduce folded in); bf16 when
    # gradient compression is on
    if kind == "train":
        gbytes = BF16 if cfg.grad_compress else F32
        out["grad_reduce"] = total_params / max(tp, 1) * gbytes
    # TP activation psums. Dense: 2/layer fwd (attention out + FFN out);
    # EP-MoE: 1/layer (expert combine travels in the all-to-all term).
    # Train doubles them (Megatron: 2 fwd + 2 bwd ARs per layer).
    if tp > 1:
        per_layer = 1 if (cfg.is_moe and size_of("experts") > 1) else 2
        n_psum = per_layer * L * (2 if kind == "train" else 1)
        # ring all-reduce moves ~2x payload
        out["tp_psum"] = n_psum * tokens_dev * d * BF16 * 2
    # MoE all-to-all (2/layer fwd, 2 bwd)
    if cfg.is_moe and size_of("experts") > 1:
        n_a2a = 2 * L * (2 if kind == "train" else 1)
        out["moe_a2a"] = n_a2a * tokens_dev * d * BF16 * \
            cfg.experts_per_token * cfg.capacity_factor / \
            max(cfg.experts_per_token, 1)
    # lm-head bwd dx all-reduce
    if kind == "train" and size_of("vocab") > 1:
        out["logit_bwd"] = tokens_dev * d * F32 * 2
    out["total"] = sum(v for k, v in out.items())
    return out
