"""Batched serving engine: continuous-batching scheduler over the
prefill/decode pjit steps.

Requests enter a queue; the engine packs up to `max_batch` active sequences
into one shared KV cache (slot-per-request), prefilling new requests one
slot at a time and decoding all active slots together — the standard
continuous-batching loop, sized down to run on CPU for the examples while
lowering to the production mesh unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import forward, init_cache_specs
from ..models.config import ModelConfig
from ..models.params import ParamSpec
from ..parallel.sharding import MeshPolicy


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 8
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_batch: int = 4, max_seq: int = 128,
                 policy: MeshPolicy = MeshPolicy(), mesh=None):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_seq = max_seq
        specs = init_cache_specs(cfg, max_batch, max_seq)
        zeros = lambda s: jnp.zeros(
            s.shape, jnp.bfloat16 if len(s.shape) >= 3 else jnp.float32)
        self.cache = jax.tree.map(
            zeros, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.positions = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self._decode = jax.jit(self._decode_fn)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _decode_fn(self, params, tokens, cache, index):
        logits, new_cache = forward(params, {"tokens": tokens},
                                    cfg=self.cfg, policy=self.policy,
                                    mesh=self.mesh, cache=cache,
                                    cache_index=index)
        return jnp.argmax(logits[:, -1], axis=-1), new_cache

    def _prefill(self, slot: int, req: Request) -> None:
        """Prefill one request token-by-token into its slot (slot-local
        decode steps; production fuses this into a chunked prefill)."""
        for t, tok in enumerate(req.prompt):
            tokens = np.zeros((self.max_batch, 1), np.int32)
            tokens[slot, 0] = tok
            _, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                         self.cache, jnp.int32(t))
        self.positions[slot] = len(req.prompt)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit + decode all active slots."""
        while self.queue and self._free_slot() is not None:
            slot = self._free_slot()
            req = self.queue.pop(0)
            self.slots[slot] = req
            self._prefill(slot, req)
        active = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i, r in active:
            last = r.generated[-1] if r.generated else int(r.prompt[-1])
            tokens[i, 0] = last
        index = jnp.int32(int(max(self.positions[i] for i, _ in active)))
        nxt, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                       self.cache, index)
        nxt = np.asarray(nxt)
        for i, r in active:
            r.generated.append(int(nxt[i]))
            self.positions[i] += 1
            if len(r.generated) >= r.max_new or \
                    self.positions[i] >= self.max_seq - 1:
                r.done = True
                self.completed.append(r)
                self.slots[i] = None

    def run(self, max_iters: int = 64) -> List[Request]:
        for _ in range(max_iters):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.completed
