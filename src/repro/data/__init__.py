from .pipeline import DataPipeline, synthetic_batch
