"""Tokenized data pipeline with registry-backed sharding + straggler
mitigation.

Shard assignment comes from the metadata plane's dataset registry; each
worker leases shards (lease rows in the HopsFS lease table via `create`
semantics). Straggler mitigation is backup-task style: when a worker's
heartbeat for a leased shard goes stale, the shard re-enters the work
queue and the first finisher wins (duplicate completions are idempotent —
the sample index makes re-reads deterministic).

Synthetic deterministic token streams stand in for storage I/O on this
container; the interface (shard lease -> sample batches -> complete) is the
production one.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..metaplane import MetadataPlane


def synthetic_batch(batch: int, seq: int, vocab: int, *, step: int,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic batch: restart at step k reproduces the same data."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    tokens = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": tokens, "labels": labels}


@dataclass
class _ShardState:
    owner: Optional[int] = None
    last_hb: int = -1
    done: bool = False


class DataPipeline:
    """Shard scheduler over the registry with straggler re-dispatch."""

    def __init__(self, plane: MetadataPlane, dataset: str, *,
                 n_shards: int = 64, hb_timeout: int = 3):
        self.plane = plane
        self.dataset = dataset
        self.hb_timeout = hb_timeout
        self.now = 0
        try:
            shards = plane.dataset_shards(dataset)
        except Exception:
            shards = []
        if not shards:
            plane.register_dataset(dataset, n_shards)
            shards = plane.dataset_shards(dataset)
        self.state: Dict[str, _ShardState] = {s: _ShardState()
                                              for s in shards}
        self.duplicate_completions = 0

    # -- scheduling -------------------------------------------------------
    def tick(self) -> None:
        self.now += 1

    def lease(self, worker: int) -> Optional[str]:
        # fresh shards first, then stale (straggler) re-dispatch
        for name, st in self.state.items():
            if st.done or st.owner is not None:
                continue
            st.owner, st.last_hb = worker, self.now
            return name
        for name, st in self.state.items():
            if st.done:
                continue
            if st.owner is not None and \
                    self.now - st.last_hb > self.hb_timeout:
                st.owner, st.last_hb = worker, self.now  # backup task
                return name
        return None

    def heartbeat(self, worker: int, shard: str) -> None:
        st = self.state[shard]
        if st.owner == worker:
            st.last_hb = self.now

    def complete(self, worker: int, shard: str) -> bool:
        st = self.state[shard]
        if st.done:
            self.duplicate_completions += 1
            return False
        st.done = True
        return True

    def pending(self) -> int:
        return sum(1 for st in self.state.values() if not st.done)

    # -- reading -----------------------------------------------------------
    def read(self, shard: str, *, batch: int, seq: int, vocab: int,
             step: int) -> Dict[str, np.ndarray]:
        seed = int(hashlib.md5(shard.encode()).hexdigest()[:8], 16)
        return synthetic_batch(batch, seq, vocab, step=step, seed=seed)
