"""Oracle: the model's chunked WKV (itself validated against an explicit
per-timestep recurrence in tests)."""
from ...models.rwkv6 import wkv6_chunked


def wkv6_ref(r, k, v, w, u, *, s0=None, chunk=32):
    return wkv6_chunked(r, k, v, w, u, s0=s0, chunk=chunk)
