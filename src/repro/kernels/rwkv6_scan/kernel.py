"""RWKV-6 WKV chunked recurrence — Pallas TPU kernel.

Grid: (batch*heads, n_chunks), chunk dimension sequential; state S [hd, hd]
in VMEM scratch. Per chunk:

  intra-chunk   y_t += sum_{s<t} (r_t ⊙ e^{cum_{t-1}-cum_s} ⊙ k_s)·1 v_s
                computed with the masked-exponent trick (exponents of all
                VALID pairs are <= 0, so masking precedes exp — stable for
                arbitrary data-dependent decay);
  diagonal      y_t += (r_t ⊙ u ⊙ k_t)·1 v_t;
  state         y_t += r_t S;  S' = diag(prod w) S + sum k~_s v_s^T.

The [Q, Q, hd] pairwise tensor stays in VMEM: Q=32, hd=64 -> 512 KB f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _scratch(shape):
    if _VMEM is not None:
        return _VMEM(shape, jnp.float32)
    return pl.MemorySpace.ANY(shape, jnp.float32)  # type: ignore


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_out_ref,
                s_scr, *, Q: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)            # [Q, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0]                              # [Q, hd] log decay (<0)
    u = u_ref[0]                                # [1, hd]

    cum = jnp.cumsum(lw, axis=0)                # [Q, hd]
    cum_prev = cum - lw
    # pairwise masked exponents (valid pairs <= 0)
    seg = cum_prev[:, None, :] - cum[None, :, :]         # [Q, Q, hd]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1) < \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    seg = jnp.where(tri[..., None], seg, -jnp.inf)
    att = jnp.einsum("qc,sc,qsc->qs", r, k, jnp.exp(seg))
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # diagonal bonus
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)     # [Q, 1]
    y += diag * v
    # carried state
    r_n = r * jnp.exp(cum_prev)
    y += jax.lax.dot_general(r_n, s_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update
    k_end = k * jnp.exp(cum[-1:] - cum)
    s_scr[...] = s_scr[...] * jnp.exp(cum[-1])[:, None] + \
        jax.lax.dot_general(k_end, v, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        s_out_ref[0] = s_scr[...]


def wkv6_fwd(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, *, chunk: int = 32, interpret: bool = True):
    """r/k/v/w [B,S,H,hd] (w in (0,1)); u [H,hd].
    Returns (y [B,S,H,hd], S [B,H,hd,hd])."""
    B, S, H, hd = r.shape
    Q = min(chunk, S)
    nc = S // Q
    tt = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    lw = jnp.maximum(jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30)),
                     -60.0)
    ut = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    ut = ut.astype(jnp.float32)

    kernel = functools.partial(_wkv_kernel, Q=Q, n_chunks=nc)
    y, s = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[_scratch((hd, hd))],
        interpret=interpret,
    )(tt(r), tt(k), tt(v), tt(lw), ut)
    return (y.reshape(B, H, S, hd).transpose(0, 2, 1, 3),
            s.reshape(B, H, hd, hd))
