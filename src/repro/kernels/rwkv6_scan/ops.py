"""jit'd wrapper for the WKV6 kernel (fwd kernel + oracle-VJP backward)."""
from __future__ import annotations

import functools

import jax

from . import ref
from .kernel import wkv6_fwd


def wkv6(r, k, v, w, u, *, s0=None, chunk: int = 32,
         interpret: bool = True):
    if s0 is not None:
        return ref.wkv6_ref(r, k, v, w, u, s0=s0, chunk=chunk)
    return _wkv6_k(r, k, v, w, u, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _wkv6_k(r, k, v, w, u, chunk: int = 32, interpret: bool = True):
    return wkv6_fwd(r, k, v, w, u, chunk=chunk, interpret=interpret)


def _fwd(r, k, v, w, u, chunk, interpret):
    out = wkv6_fwd(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out, (r, k, v, w, u)


def _bwd(chunk, interpret, res, g):
    r, k, v, w, u = res
    _, vjp = jax.vjp(lambda *a: ref.wkv6_ref(*a, chunk=chunk),
                     r, k, v, w, u)
    return vjp(g)


_wkv6_k.defvjp(_fwd, _bwd)
