from . import ops, ref
