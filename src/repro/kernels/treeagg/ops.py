"""jit'd wrapper + padding for the subtree wave-expansion kernel."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..phash.ops import _pad_pow2
from .kernel import treeagg as _treeagg

#: wave padding sentinel — larger than any real inode id, keeps the
#: sorted wave sorted, and slot parents can never equal it
WAVE_PAD = np.int32(np.iinfo(np.int32).max)


@functools.partial(jax.jit, static_argnames=("interpret",))
def treeagg(wave, par, isdir, size, interpret: bool = True):
    return _treeagg(wave, par, isdir, size, interpret=interpret)


def treeagg_expand(wave, par, isdir, size, *,
                   interpret: bool = True):
    """Resolve one BFS wave against the whole inode column set in ONE
    kernel launch.

    ``wave`` is the wave's directory ids (sorted ascending, unique);
    ``par``/``isdir``/``size`` are the columnar table's hot columns
    (cleared slots carry parent ``-1`` and never match).  Both sides are
    padded to a power of two — wave with :data:`WAVE_PAD`, slots with
    parent ``-1`` — so the 1-D grid tiles evenly and jit recompiles stay
    O(log N).  Returns numpy ``(seg [C], counts [W], dirs [W],
    sizes [W])`` int32, sliced back to the unpadded lengths."""
    wave = np.asarray(wave, dtype=np.int64)
    par = np.asarray(par, dtype=np.int64)
    w = wave.shape[0]
    c = par.shape[0]
    if w == 0 or c == 0:
        return (np.full(c, -1, np.int32), np.zeros(w, np.int32),
                np.zeros(w, np.int32), np.zeros(w, np.int32))
    pw = _pad_pow2(w)
    wbuf = np.full(pw, WAVE_PAD, np.int32)
    wbuf[:w] = wave.astype(np.int32)
    pc = _pad_pow2(c)
    pbuf = np.full(pc, -1, np.int32)
    pbuf[:c] = par.astype(np.int32)
    dbuf = np.zeros(pc, np.int32)
    dbuf[:c] = np.asarray(isdir, dtype=np.int64).astype(np.int32)
    sbuf = np.zeros(pc, np.int32)
    sbuf[:c] = np.asarray(size, dtype=np.int64).astype(np.int32)
    seg, cnt, dirs, szs = treeagg(jnp.asarray(wbuf), jnp.asarray(pbuf),
                                  jnp.asarray(dbuf), jnp.asarray(sbuf),
                                  interpret=interpret)
    return (np.asarray(seg)[:c], np.asarray(cnt)[:w],
            np.asarray(dirs)[:w], np.asarray(szs)[:w])
