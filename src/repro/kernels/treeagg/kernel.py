"""Subtree wave expansion + segment aggregation (HopsFS §6 phase 2) — Pallas.

The incremental subtree protocol (``repro.core.subtree``) walks a
directory tree as BFS *waves*: a wave is the set of directory inode ids
whose children must be resolved next.  On the columnar store the child
relation is already materialized as struct-of-arrays hot columns
(``id`` / ``parent_id`` / ``is_dir`` / ``size``), so one wave resolves in
ONE fused launch instead of a partition-pruned scan per directory:

* **expansion** — for every table slot, a lower-bound binary search of its
  ``parent_id`` against the sorted wave gives ``seg``: the wave member the
  slot is a child of (``-1`` = not a child of this wave, including cleared
  slots whose parent is the ``-1`` sentinel);
* **aggregation** — a masked scatter-add folds per-child ``1`` /
  ``is_dir`` / ``size`` into per-wave-member ``counts`` / ``dirs`` /
  ``sizes`` (the segment sums behind ``du`` and ``content_summary``).

The wave is padded with ``INT32_MAX`` (never a real inode id, keeps the
array sorted); slot-side padding uses parent ``-1`` which can never match
a wave member (wave ids are ``>= 0``).  Everything is int32 — the suite
runs with x64 disabled — so sizes are aggregated as int32 partial sums and
widened host-side.

Grid: 1-D over slot blocks; the wave arrays are broadcast whole to every
block, and the three per-wave outputs use a revisited (accumulator) block
so each grid step adds its block's contribution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _treeagg_kernel(wave_ref, par_ref, isdir_ref, size_ref,
                    seg_ref, cnt_ref, dir_ref, sz_ref, *,
                    wcap: int, steps: int):
    wave = wave_ref[...]                   # [wcap] int32 sorted wave ids
    par = par_ref[...]                     # [bn] int32 slot parent / -1
    isd = isdir_ref[...]                   # [bn] int32 slot is_dir (0/1)
    siz = size_ref[...]                    # [bn] int32 slot size

    # rolled lower-bound binary search (NOT a static unroll: the XLA
    # graph stays O(1) in log(wcap), keeping interpret-mode compiles flat
    # — same lesson as the pkval probe loop)
    lo = jnp.zeros(par.shape, jnp.int32)
    hi = jnp.full(par.shape, wcap, jnp.int32)

    def _step(_, carry):
        lo, hi = carry
        cont = lo < hi
        mid = (lo + hi) // 2
        v = jnp.take(wave, jnp.minimum(mid, wcap - 1))
        go_right = cont & (v < par)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, steps, _step, (lo, hi))
    found = ((par >= 0) & (lo < wcap)
             & (jnp.take(wave, jnp.minimum(lo, wcap - 1)) == par))
    seg_ref[...] = jnp.where(found, lo, -1)

    @pl.when(pl.program_id(0) == 0)
    def _init():                           # zero the revisited accumulators
        cnt_ref[...] = jnp.zeros((wcap,), jnp.int32)
        dir_ref[...] = jnp.zeros((wcap,), jnp.int32)
        sz_ref[...] = jnp.zeros((wcap,), jnp.int32)

    # masked scatter-add: misses collapse onto index 0 with value 0
    idx = jnp.where(found, lo, 0)
    zeros = jnp.zeros((wcap,), jnp.int32)
    cnt_ref[...] = cnt_ref[...] + zeros.at[idx].add(
        jnp.where(found, 1, 0).astype(jnp.int32))
    dir_ref[...] = dir_ref[...] + zeros.at[idx].add(
        jnp.where(found, isd, 0).astype(jnp.int32))
    sz_ref[...] = sz_ref[...] + zeros.at[idx].add(
        jnp.where(found, siz, 0).astype(jnp.int32))


def treeagg(wave: jax.Array, par: jax.Array, isdir: jax.Array,
            size: jax.Array, *, block_n: int = 8192,
            interpret: bool = True):
    """wave [W] (sorted, INT32_MAX-padded) x slots (par/isdir/size [C]) ->
    (seg [C], counts [W], dirs [W], sizes [W]) int32."""
    (C,) = par.shape
    (W,) = wave.shape
    bn = min(block_n, C)
    kernel = functools.partial(_treeagg_kernel, wcap=W,
                               steps=max(1, W.bit_length()))
    return pl.pallas_call(
        kernel,
        grid=(C // bn,),
        in_specs=[pl.BlockSpec((W,), lambda i: (0,)),
                  pl.BlockSpec((bn,), lambda i: (i,)),
                  pl.BlockSpec((bn,), lambda i: (i,)),
                  pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((bn,), lambda i: (i,)),
                   pl.BlockSpec((W,), lambda i: (0,)),
                   pl.BlockSpec((W,), lambda i: (0,)),
                   pl.BlockSpec((W,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((C,), jnp.int32),
                   jax.ShapeDtypeStruct((W,), jnp.int32),
                   jax.ShapeDtypeStruct((W,), jnp.int32),
                   jax.ShapeDtypeStruct((W,), jnp.int32)],
        interpret=interpret,
    )(wave, par, isdir, size)
