from . import ops, ref
