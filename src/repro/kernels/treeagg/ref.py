"""Numpy oracle for the treeagg kernel (also its fallback)."""
import numpy as np


def treeagg_ref(wave, par, isdir, size):
    """Bit-identical host mirror of the fused wave-expansion kernel:
    ``seg`` [C] int32 (wave index each slot is a child of, -1 = none) plus
    per-wave-member int32 segment sums ``counts`` / ``dirs`` / ``sizes``.
    ``wave`` must be sorted ascending (padding, if any, at the top)."""
    wave = np.asarray(wave, dtype=np.int32)
    par = np.asarray(par, dtype=np.int32)
    isdir = np.asarray(isdir, dtype=np.int32)
    size = np.asarray(size, dtype=np.int32)
    w = wave.shape[0]
    # lower-bound binary search, same as the kernel's rolled fori_loop
    idx = np.searchsorted(wave, par).astype(np.int32)
    found = (par >= 0) & (idx < w)
    if w:
        found &= wave[np.minimum(idx, w - 1)] == par
    seg = np.where(found, idx, np.int32(-1)).astype(np.int32)
    counts = np.zeros(w, np.int32)
    dirs = np.zeros(w, np.int32)
    sizes = np.zeros(w, np.int32)
    with np.errstate(over="ignore"):
        np.add.at(counts, idx[found], np.int32(1))
        np.add.at(dirs, idx[found], isdir[found])
        np.add.at(sizes, idx[found], size[found])
    return seg, counts, dirs, sizes
