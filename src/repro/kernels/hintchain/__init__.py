from . import ops, ref
