"""Vectorized hint-chain resolution (HopsFS §5.1 inode hint cache) — Pallas.

The client-side batch planner resolves every op's path against its hint
view: the client's own response-warmed cache first, the merged namenode
caches as fallback (``HintResolver`` / ``MultiCacheResolver``).  The
Python loop probes one ``(parent_id, name)`` per step, per op.  This
kernel walks ALL chains of a planner window at once: both cache views are
snapshotted into open-addressing hash tables (``repro.core.columnar.
HashIndex``) and the kernel advances every op's parent pointer one depth
per unrolled step — each step probing the client table, then the fallback
table, exactly the resolver's precedence.

Output encoding per (op, depth):

  child  > 0   resolved inode id        src 0 = client cache, 1 = fallback
  child == -1  miss (chain stops)       src -1
  child == -2  never probed (past the miss, or past the op's depth)
  child == -3  collided bucket — the host must re-resolve this op through
               the exact per-probe Python walk (names, not 32-bit hashes)

Grid: 1-D over op blocks; both snapshot tables broadcast whole per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..pkval.kernel import MAX_PROBE, _bucket_hash


def _probe_table(tp, tn, tv, cap: int, max_probe: int, par, nam):
    """One linear-probe lookup of every op's current (parent, name-hash)
    against one snapshot table; -1 = miss, passes -3 buckets through."""
    slot = _bucket_hash(par, nam) & jnp.uint32(cap - 1)

    # rolled probe loop — see pkval.kernel: unrolled gather chains make
    # XLA compile time explode; fori_loop keeps the graph O(1) in depth
    def _step(step, carry):
        val, alive = carry
        j = ((slot + step.astype(jnp.uint32)) & jnp.uint32(cap - 1)) \
            .astype(jnp.int32)
        ep = jnp.take(tp, j)
        en = jnp.take(tn, j)
        ev = jnp.take(tv, j)
        hit = alive & (ep >= 0) & (ep == par) & (en == nam)
        val = jnp.where(hit, ev, val)
        alive = alive & ~hit & (ep != jnp.int32(-1))
        return val, alive

    val = jnp.full(par.shape, -1, jnp.int32)
    alive = par >= 0
    val, _ = jax.lax.fori_loop(0, max_probe, _step, (val, alive))
    return val


def _hintchain_kernel(cp_ref, cn_ref, cv_ref, fp_ref, fn_ref, fv_ref,
                      nam_ref, dep_ref, child_ref, src_ref, *,
                      depth: int, ccap: int, fcap: int, root_id: int,
                      max_probe: int):
    cp, cn, cv = cp_ref[...], cn_ref[...], cv_ref[...]
    fp, fn, fv = fp_ref[...], fn_ref[...], fv_ref[...]
    nam = nam_ref[...]                       # [bn, depth] uint32
    dep = dep_ref[...]                       # [bn] int32 (0 = dead op)

    # rolled depth loop: compile time is independent of the chain-depth
    # bound (an unrolled depth x probe x 2-table gather chain previously
    # took minutes to compile even in interpret mode)
    def _depth(d, carry):
        parent, alive, childs, srcs = carry
        probing = alive & (d < dep)
        nd = jax.lax.dynamic_index_in_dim(nam, d, axis=1, keepdims=False)
        cval = _probe_table(cp, cn, cv, ccap, max_probe, parent, nd)
        fval = _probe_table(fp, fn, fv, fcap, max_probe, parent, nd)
        # resolver precedence: any client answer (including a collided
        # bucket — the Python walk might have resolved it) wins
        val = jnp.where(cval != jnp.int32(-1), cval, fval)
        found = probing & (val > 0)
        child_d = jnp.where(probing, val, jnp.int32(-2))
        src_d = jnp.where(found & (cval > 0), jnp.int32(0),
                          jnp.where(found, jnp.int32(1), jnp.int32(-1)))
        childs = jax.lax.dynamic_update_index_in_dim(childs, child_d, d,
                                                     axis=1)
        srcs = jax.lax.dynamic_update_index_in_dim(srcs, src_d, d, axis=1)
        parent = jnp.where(found, val, parent)
        alive = alive & found
        return parent, alive, childs, srcs

    parent = jnp.full(dep.shape, root_id, jnp.int32)
    alive = dep > 0
    childs = jnp.full(nam.shape, -2, jnp.int32)
    srcs = jnp.full(nam.shape, -1, jnp.int32)
    _, _, childs, srcs = jax.lax.fori_loop(
        0, depth, _depth, (parent, alive, childs, srcs))
    child_ref[...] = childs
    src_ref[...] = srcs


def hintchain(cp: jax.Array, cn: jax.Array, cv: jax.Array, fp: jax.Array,
              fn: jax.Array, fv: jax.Array, name_hashes: jax.Array,
              depths: jax.Array, *, root_id: int = 1, block_n: int = 1024,
              max_probe: int = MAX_PROBE, interpret: bool = True):
    """client table [Cc] x fallback table [Cf] x chains [N, D] ->
    (child_ids [N, D] int32, src [N, D] int32)."""
    N, D = name_hashes.shape
    (Cc,) = cp.shape
    (Cf,) = fp.shape
    bn = min(block_n, N)
    kernel = functools.partial(_hintchain_kernel, depth=D, ccap=Cc,
                               fcap=Cf, root_id=root_id,
                               max_probe=max_probe)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((Cc,), lambda i: (0,)),
                  pl.BlockSpec((Cc,), lambda i: (0,)),
                  pl.BlockSpec((Cc,), lambda i: (0,)),
                  pl.BlockSpec((Cf,), lambda i: (0,)),
                  pl.BlockSpec((Cf,), lambda i: (0,)),
                  pl.BlockSpec((Cf,), lambda i: (0,)),
                  pl.BlockSpec((bn, D), lambda i: (i, 0)),
                  pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((bn, D), lambda i: (i, 0)),
                   pl.BlockSpec((bn, D), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((N, D), jnp.int32),
                   jax.ShapeDtypeStruct((N, D), jnp.int32)],
        interpret=interpret,
    )(cp, cn, cv, fp, fn, fv, name_hashes, depths)
