"""Numpy oracle for the hint-chain resolution kernel (also its fallback)."""
import numpy as np

from ..pkval.kernel import MAX_PROBE
from ..pkval.ref import bucket_hash_ref


def _probe_table_ref(tp, tn, tv, par, nam, max_probe):
    cap = tp.shape[0]
    slot = bucket_hash_ref(par, nam) & np.uint32(cap - 1)
    val = np.full(par.shape, -1, np.int32)
    alive = par >= 0
    with np.errstate(over="ignore"):
        for step in range(max_probe):
            j = ((slot + np.uint32(step)) & np.uint32(cap - 1)) \
                .astype(np.int64)
            ep, en, ev = tp[j], tn[j], tv[j]
            hit = alive & (ep >= 0) & (ep == par) & (en == nam)
            val = np.where(hit, ev, val)
            alive = alive & ~hit & (ep != np.int32(-1))
    return val


def hintchain_ref(cp, cn, cv, fp, fn, fv, name_hashes, depths, *,
                  root_id: int = 1, max_probe: int = MAX_PROBE):
    """Bit-identical host walk of every chain: (child_ids, src) [N, D]."""
    cp = np.asarray(cp).astype(np.int32)
    cn = np.asarray(cn).astype(np.uint32)
    cv = np.asarray(cv).astype(np.int32)
    fp = np.asarray(fp).astype(np.int32)
    fn = np.asarray(fn).astype(np.uint32)
    fv = np.asarray(fv).astype(np.int32)
    nam = np.asarray(name_hashes).astype(np.uint32)
    dep = np.asarray(depths).astype(np.int32)
    n, d_max = nam.shape
    parent = np.full(n, root_id, np.int32)
    alive = dep > 0
    childs = np.full((n, d_max), -2, np.int32)
    srcs = np.full((n, d_max), -1, np.int32)
    for d in range(d_max):
        probing = alive & (np.int32(d) < dep)
        nd = nam[:, d]
        cval = _probe_table_ref(cp, cn, cv, parent, nd, max_probe)
        fval = _probe_table_ref(fp, fn, fv, parent, nd, max_probe)
        val = np.where(cval != np.int32(-1), cval, fval)
        found = probing & (val > 0)
        childs[:, d] = np.where(probing, val, np.int32(-2))
        srcs[:, d] = np.where(found & (cval > 0), np.int32(0),
                              np.where(found, np.int32(1), np.int32(-1)))
        parent = np.where(found, val, parent).astype(np.int32)
        alive = alive & found
    return childs, srcs
