"""jit'd wrapper + padding for the hint-chain resolution kernel."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..phash.ops import _pad_pow2
from ..pkval.kernel import MAX_PROBE
from .kernel import hintchain as _hintchain


@functools.partial(jax.jit,
                   static_argnames=("root_id", "max_probe", "interpret"))
def hintchain(cp, cn, cv, fp, fn, fv, name_hashes, depths,
              root_id: int = 1, max_probe: int = MAX_PROBE,
              interpret: bool = True):
    return _hintchain(cp, cn, cv, fp, fn, fv, name_hashes, depths,
                      root_id=root_id, max_probe=max_probe,
                      interpret=interpret)


def hintchain_resolve(client_idx, fallback_idx, name_hashes, depths, *,
                      root_id: int = 1, max_probe: int = MAX_PROBE,
                      interpret: bool = True
                      ) -> "tuple[np.ndarray, np.ndarray]":
    """Resolve a whole window's hint chains in ONE kernel launch.

    ``client_idx``/``fallback_idx`` are (parent, name_hash, value) array
    triples — ``HashIndex.arrays()`` snapshots of the client cache and the
    merged namenode caches.  ``name_hashes [N, D]`` / ``depths [N]``
    describe every op's component chain (depth 0 = never probed).  N is
    padded to a power of two so the 1-D grid tiles evenly.  Returns the
    kernel's (child_ids, src) [N, D] encoding (see kernel module doc)."""
    nam = np.asarray(name_hashes, dtype=np.int64) & 0xFFFFFFFF
    dep = np.asarray(depths, dtype=np.int32)
    n = nam.shape[0]
    if n == 0:
        d0 = nam.shape[1] if nam.ndim == 2 else 0
        return (np.full((0, d0), -2, np.int32),
                np.full((0, d0), -1, np.int32))
    d = nam.shape[1]
    pn = _pad_pow2(n)
    nbuf = np.zeros((pn, d), np.uint32)
    nbuf[:n] = nam.astype(np.uint32)
    dbuf = np.zeros(pn, np.int32)
    dbuf[:n] = dep
    cp, cn_, cv = (np.asarray(a) for a in client_idx)
    fp, fn_, fv = (np.asarray(a) for a in fallback_idx)
    childs, srcs = hintchain(
        jnp.asarray(cp.astype(np.int32)), jnp.asarray(cn_.astype(np.uint32)),
        jnp.asarray(cv.astype(np.int32)), jnp.asarray(fp.astype(np.int32)),
        jnp.asarray(fn_.astype(np.uint32)), jnp.asarray(fv.astype(np.int32)),
        jnp.asarray(nbuf), jnp.asarray(dbuf), root_id=root_id,
        max_probe=max_probe, interpret=interpret)
    return np.asarray(childs)[:n], np.asarray(srcs)[:n]
