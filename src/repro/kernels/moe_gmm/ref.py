"""Oracle for the grouped matmul."""
import jax.numpy as jnp


def gmm_ref(x, w):
    """x [E, C, D] @ w [E, D, F] -> [E, C, F]."""
    return jnp.einsum("ecd,edf->ecf", x, w)
