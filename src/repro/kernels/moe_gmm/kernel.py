"""Grouped expert matmul (MoE capacity buffers) — Pallas TPU kernel.

Computes y[e] = x[e] @ w[e] for E experts in one launch: grid
(E, C/bc, F/bf, D/bd) with the contraction dimension sequential and an
f32 VMEM accumulator. MXU-aligned tiles: bc x bd and bd x bf multiples of
(8, 128) — the dispatch capacity C is padded to 128 upstream.

This replaces E separate XLA dots, eliminating per-expert launch overhead
and keeping the expert loop on-chip — the MoE FFN hot spot for
qwen3-moe (128 experts, tiny d_ff=768 per expert, where per-dot overhead
dominates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _scratch(shape):
    if _VMEM is not None:
        return _VMEM(shape, jnp.float32)
    return pl.MemorySpace.ANY(shape, jnp.float32)  # type: ignore


def _gmm_kernel(x_ref, w_ref, y_ref, acc, *, n_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == n_d_blocks - 1)
    def _flush():
        y_ref[0] = acc[...].astype(y_ref.dtype)


def gmm(x: jax.Array, w: jax.Array, *, block_c: int = 128,
        block_f: int = 128, block_d: int = 128,
        interpret: bool = True) -> jax.Array:
    """x [E, C, D] @ w [E, D, F] -> [E, C, F]."""
    E, C, D = x.shape
    F = w.shape[-1]
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    grid = (E, C // bc, F // bf, D // bd)
    kernel = functools.partial(_gmm_kernel, n_d_blocks=D // bd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[_scratch((bc, bf))],
        interpret=interpret,
    )(x, w)
