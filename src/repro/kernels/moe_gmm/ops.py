"""jit'd wrapper for the grouped expert matmul."""
from __future__ import annotations

import functools

import jax

from . import ref
from .kernel import gmm as _gmm_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def gmm(x, w, interpret: bool = True):
    return _gmm_fwd(x, w, interpret=interpret)


def _fwd(x, w, interpret):
    return _gmm_fwd(x, w, interpret=interpret), (x, w)


def _bwd(interpret, res, g):
    x, w = res
    _, vjp = jax.vjp(ref.gmm_ref, x, w)
    return vjp(g)


gmm.defvjp(_fwd, _bwd)
