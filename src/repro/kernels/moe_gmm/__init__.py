from . import ops, ref
