"""Pallas TPU kernels for the compute hot-spots (+ the metadata-plane
partition hash). Each subpackage: kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper + custom_vjp), ref.py (pure-jnp oracle).

TPU is the TARGET; this container validates via interpret=True.
"""
