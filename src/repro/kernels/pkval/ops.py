"""jit'd wrapper + padding for the grouped PK-validation kernel."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..phash.ops import _pad_pow2
from .kernel import MAX_PROBE
from .kernel import pkval as _pkval


@functools.partial(jax.jit, static_argnames=("max_probe", "interpret"))
def pkval(tp, tn, tv, parents, name_hashes, max_probe: int = MAX_PROBE,
          interpret: bool = True):
    return _pkval(tp, tn, tv, parents, name_hashes, max_probe=max_probe,
                  interpret=interpret)


def pkval_lookup(tp, tn, tv, parents, name_hashes, *,
                 max_probe: int = MAX_PROBE,
                 interpret: bool = True) -> np.ndarray:
    """Resolve a whole batch of (parent_id, name_hash) composite-PK probes
    against the columnar store's hash index in ONE kernel launch.

    ``tp``/``tn``/``tv`` are the index's parent/name-hash/value arrays
    (capacity a power of two; see ``repro.core.columnar.HashIndex``).
    Probes are padded to a power-of-two length with parent ``-1`` (always a
    miss) so the 1-D grid tiles evenly and jit recompiles stay O(log N).
    Returns ids [N] int32: resolved inode id, ``-1`` = no such row,
    ``-3`` = collided bucket (caller must fall back, not trust)."""
    par = np.asarray(parents, dtype=np.int64)
    nam = np.asarray(name_hashes, dtype=np.int64) & 0xFFFFFFFF
    n = par.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    pn = _pad_pow2(n)
    pbuf = np.full(pn, -1, np.int32)
    pbuf[:n] = par.astype(np.int32)
    nbuf = np.zeros(pn, np.uint32)
    nbuf[:n] = nam.astype(np.uint32)
    out = pkval(jnp.asarray(np.asarray(tp, np.int32)),
                jnp.asarray(np.asarray(tn, np.uint32)),
                jnp.asarray(np.asarray(tv, np.int32)),
                jnp.asarray(pbuf), jnp.asarray(nbuf),
                max_probe=max_probe, interpret=interpret)
    return np.asarray(out)[:n]
