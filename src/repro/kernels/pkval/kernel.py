"""Grouped-batch PK validation (HopsFS §5.1 batched PK reads) — Pallas.

The columnar inode table (``repro.core.columnar``) maintains an
open-addressing hash index over its composite PK ``(parent_id,
name_hash32(name))``.  This kernel probes that index for a whole planner
window's ``(parent_id, name)`` chain in ONE launch: every probe walks the
same linear-probe sequence the host-side :class:`~repro.core.columnar.
HashIndex` inserts along (load factor <= 0.5, bounded probe length), so a
window of several hundred path components validates against the store in
one fused pass instead of per-row dict gets.

Sentinels share the host encoding: slot parent ``-1`` = empty (ends the
probe chain), ``-2`` = tombstone (probe continues), value ``-3`` = a
32-bit name-hash collision (two live names, one bucket) — collided keys
report "cannot validate" rather than a wrong id.  Probe rows with parent
``< 0`` are padding and always miss.

Grid: 1-D over probe blocks; the index arrays are broadcast whole to every
block (they are the shared read-only side).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..phash.kernel import GOLDEN, GOLDEN2

#: linear-probe bound shared with the host-side HashIndex insert path —
#: the host GROWS the table rather than place a key further than this,
#: so a kernel miss after MAX_PROBE steps is a real miss
MAX_PROBE = 8


def _bucket_hash(par, nam):
    """uint32 bucket mix over the composite key — one multiply per half,
    xor-folded, same avalanche finish as the scalar store hash."""
    h = ((par.astype(jnp.uint32) * jnp.uint32(GOLDEN))
         ^ (nam.astype(jnp.uint32) * jnp.uint32(GOLDEN2)))
    h = (h ^ (h >> jnp.uint32(16))).astype(jnp.uint32)
    return h


def _pkval_kernel(tp_ref, tn_ref, tv_ref, par_ref, nam_ref, out_ref, *,
                  cap: int, max_probe: int):
    tp = tp_ref[...]                       # [cap] int32 parent / sentinel
    tn = tn_ref[...]                       # [cap] uint32 name hash
    tv = tv_ref[...]                       # [cap] int32 child id / -3
    par = par_ref[...]                     # [bn] int32 probe parent
    nam = nam_ref[...]                     # [bn] uint32 probe name hash
    slot = _bucket_hash(par, nam) & jnp.uint32(cap - 1)

    # rolled probe loop (NOT a static unroll): the XLA graph stays O(1)
    # in max_probe, keeping compile time flat — an unrolled chain of
    # gathers made even interpret-mode compiles pathologically slow
    def _step(step, carry):
        out, alive = carry
        j = ((slot + step.astype(jnp.uint32)) & jnp.uint32(cap - 1)) \
            .astype(jnp.int32)
        ep = jnp.take(tp, j)
        en = jnp.take(tn, j)
        ev = jnp.take(tv, j)
        hit = alive & (ep >= 0) & (ep == par) & (en == nam)
        out = jnp.where(hit, ev, out)
        alive = alive & ~hit & (ep != jnp.int32(-1))
        return out, alive

    out = jnp.full(par.shape, -1, jnp.int32)
    alive = par >= 0
    out, _ = jax.lax.fori_loop(0, max_probe, _step, (out, alive))
    out_ref[...] = out


def pkval(tp: jax.Array, tn: jax.Array, tv: jax.Array, parents: jax.Array,
          name_hashes: jax.Array, *, block_n: int = 1024,
          max_probe: int = MAX_PROBE, interpret: bool = True) -> jax.Array:
    """index (tp/tn/tv [C]) x probes (parents/name_hashes [N]) ->
    resolved ids [N] int32 (-1 = no such row, -3 = hash-collided bucket)."""
    (N,) = parents.shape
    (C,) = tp.shape
    bn = min(block_n, N)
    kernel = functools.partial(_pkval_kernel, cap=C, max_probe=max_probe)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((C,), lambda i: (0,)),
                  pl.BlockSpec((C,), lambda i: (0,)),
                  pl.BlockSpec((C,), lambda i: (0,)),
                  pl.BlockSpec((bn,), lambda i: (i,)),
                  pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(tp, tn, tv, parents, name_hashes)
