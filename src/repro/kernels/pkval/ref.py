"""Numpy oracle for the grouped PK-validation kernel (also its fallback)."""
import numpy as np

from .kernel import MAX_PROBE

GOLDEN = 0x9E3779B1
GOLDEN2 = 0x85EBCA6B


def bucket_hash_ref(par, nam):
    """Host mirror of the kernel's uint32 bucket mix."""
    par = np.asarray(par).astype(np.uint32)
    nam = np.asarray(nam).astype(np.uint32)
    with np.errstate(over="ignore"):
        h = ((par * np.uint32(GOLDEN)) ^ (nam * np.uint32(GOLDEN2))) \
            .astype(np.uint32)
        h = (h ^ (h >> np.uint32(16))).astype(np.uint32)
    return h


def pkval_ref(tp, tn, tv, parents, name_hashes, *,
              max_probe: int = MAX_PROBE):
    """Vectorized linear-probe lookup, bit-identical to the kernel:
    ids [N] int32, -1 = miss, -3 = collided bucket."""
    tp = np.asarray(tp).astype(np.int32)
    tn = np.asarray(tn).astype(np.uint32)
    tv = np.asarray(tv).astype(np.int32)
    par = np.asarray(parents).astype(np.int32)
    nam = np.asarray(name_hashes).astype(np.uint32)
    cap = tp.shape[0]
    slot = bucket_hash_ref(par, nam) & np.uint32(cap - 1)
    out = np.full(par.shape, -1, np.int32)
    alive = par >= 0
    with np.errstate(over="ignore"):
        for step in range(max_probe):
            j = ((slot + np.uint32(step)) & np.uint32(cap - 1)) \
                .astype(np.int64)
            ep, en, ev = tp[j], tn[j], tv[j]
            hit = alive & (ep >= 0) & (ep == par) & (en == nam)
            out = np.where(hit, ev, out)
            alive = alive & ~hit & (ep != np.int32(-1))
    return out
