from . import ops, ref
