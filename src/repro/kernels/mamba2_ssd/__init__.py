from . import ops, ref
