"""Oracle: the model's own chunked SSD (validated against an explicit
per-timestep scan in tests)."""
from ...models.mamba2 import ssd_chunked


def ssd_ref(x, dt, A, Bc, Cc, *, h0=None, chunk=128):
    return ssd_chunked(x, dt, A, Bc, Cc, h0=h0, chunk=chunk)
