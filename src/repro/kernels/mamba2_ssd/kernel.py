"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid: (batch*heads, n_chunks) with the chunk dimension sequential
(`arbitrary`): the recurrent state h [hd, N] lives in VMEM scratch across
chunk steps. Per chunk the kernel computes the intra-chunk quadratic form
(C B^T masked by cumulative decays — an MXU matmul over [Q, N] tiles), the
inter-chunk state contribution, and the state update.

BlockSpecs: x [Q, hd], dt [Q, 1], B/C [Q, N] tiles (B/C are shared across
heads: their index_map drops the head coordinate). VMEM per step:
Q*(hd + 2N + Q) f32 ~= 0.6 MB at Q=128, hd=64, N=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _scratch(shape):
    if _VMEM is not None:
        return _VMEM(shape, jnp.float32)
    return pl.MemorySpace.ANY(shape, jnp.float32)  # type: ignore


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, h_scr, *,
                Q: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # [Q, hd]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, 1]
    A = a_ref[0]                              # [1, 1] per-head decay coeff
    Bc = b_ref[0].astype(jnp.float32)         # [Q, N]
    Cc = c_ref[0].astype(jnp.float32)         # [Q, N]

    la = dt * A[0, 0]                         # [Q, 1] log-decay per step
    cum = jnp.cumsum(la, axis=0)              # [Q, 1]
    # intra-chunk: M[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s, s<=t
    cb = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    seg = cum - cum.T                         # [Q, Q] cum_t - cum_s
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    M = cb * decay * dt.T                     # [Q, Q]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y += (C exp(cum)) @ h^T     h: [hd, N]
    y += jax.lax.dot_general(Cc * jnp.exp(cum), h_scr[...],
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update: h' = h * exp(cum_Q) + sum_s x_s (dt_s e^{cum_Q-cum_s}) B_s
    rem = jnp.exp(cum[-1:] - cum) * dt        # [Q, 1]
    h_scr[...] = h_scr[...] * jnp.exp(cum[-1, 0]) + jax.lax.dot_general(
        x * rem, Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        h_ref[0] = h_scr[...]


def ssd_fwd(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
            Cc: jax.Array, *, chunk: int = 128, interpret: bool = True):
    """x [B,S,H,hd]; dt [B,S,H] (softplus'd); A [H]; Bc/Cc [B,S,N].
    Returns (y [B,S,H,hd], h [B,H,hd,N])."""
    B, S, H, hd = x.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    xt = x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    dtt = dt.transpose(0, 2, 1).reshape(B * H, S, 1)
    at = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H, 1, 1)
    bt = Bc.reshape(B, S, N)
    ct = Cc.reshape(B, S, N)

    kernel = functools.partial(_ssd_kernel, Q=Q, n_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, ci: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b, ci, H=H: (b // H, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, ci, H=H: (b // H, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, hd, N), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), x.dtype),
            jax.ShapeDtypeStruct((B * H, hd, N), jnp.float32),
        ],
        scratch_shapes=[_scratch((hd, N))],
        interpret=interpret,
    )(xt, dtt, at, bt, ct)
    return (y.reshape(B, H, S, hd).transpose(0, 2, 1, 3),
            h.reshape(B, H, hd, N))
