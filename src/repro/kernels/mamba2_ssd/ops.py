"""jit'd wrapper for the SSD kernel (fwd kernel + oracle-VJP backward)."""
from __future__ import annotations

import functools

import jax

from . import ref
from .kernel import ssd_fwd


def ssd(x, dt, A, Bc, Cc, *, h0=None, chunk: int = 128,
        interpret: bool = True):
    """Kernel path for h0=0 (training); a carried state falls back to the
    chunked jnp reference (prefill-continuation is not the hot path)."""
    if h0 is not None:
        return ref.ssd_ref(x, dt, A, Bc, Cc, h0=h0, chunk=chunk)
    return _ssd_k(x, dt, A, Bc, Cc, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_k(x, dt, A, Bc, Cc, chunk: int = 128, interpret: bool = True):
    y, h = ssd_fwd(x, dt, A, Bc, Cc, chunk=chunk, interpret=interpret)
    return y, h


def _fwd(x, dt, A, Bc, Cc, chunk, interpret):
    out = ssd_fwd(x, dt, A, Bc, Cc, chunk=chunk, interpret=interpret)
    return out, (x, dt, A, Bc, Cc)


def _bwd(chunk, interpret, res, g):
    x, dt, A, Bc, Cc = res
    _, vjp = jax.vjp(lambda *a: ref.ssd_ref(*a, chunk=chunk),
                     x, dt, A, Bc, Cc)
    return vjp(g)


_ssd_k.defvjp(_fwd, _bwd)
