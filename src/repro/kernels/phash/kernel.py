"""Batched partition hash (HopsFS ADP hot path) — Pallas TPU kernel.

The metadata plane hashes billions of (parent_id | inode_id) keys to
partition ids (paper §4.2: inodes partitioned by parent id, file-related
rows by inode id). At exabyte scale this runs over block-report streams and
bulk-import manifests — a pure integer-VPU workload:

    h  = key * 0x9E3779B1 (mod 2^32);  h ^= h >> 16;  partition = h % P

which matches ``repro.core.store._hash_key`` exactly, so the Python
metadata plane and the TPU data pipeline agree on placement.

Grid: 1-D over key blocks; BlockSpec moves [block_n] int32 tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GOLDEN = 0x9E3779B1


def _phash_kernel(keys_ref, out_ref, *, n_partitions: int):
    k = keys_ref[...].astype(jnp.uint32)
    h = (k * jnp.uint32(GOLDEN)).astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    out_ref[...] = (h % jnp.uint32(n_partitions)).astype(jnp.int32)


def phash(keys: jax.Array, *, n_partitions: int = 64, block_n: int = 1024,
          interpret: bool = True) -> jax.Array:
    """keys [N] int32/uint32 -> partition ids [N] int32."""
    (N,) = keys.shape
    bn = min(block_n, N)
    kernel = functools.partial(_phash_kernel, n_partitions=n_partitions)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(keys)
