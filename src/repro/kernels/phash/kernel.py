"""Batched partition hash (HopsFS ADP hot path) — Pallas TPU kernel.

The metadata plane hashes billions of (parent_id | inode_id) keys to
partition ids (paper §4.2: inodes partitioned by parent id, file-related
rows by inode id). At exabyte scale this runs over block-report streams and
bulk-import manifests — a pure integer-VPU workload:

    h  = key * 0x9E3779B1 (mod 2^32);  h ^= h >> 16;  partition = h % P

which matches ``repro.core.store._hash_key`` exactly, so the Python
metadata plane and the TPU data pipeline agree on placement.

Grid: 1-D over key blocks; BlockSpec moves [block_n] int32 tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GOLDEN = 0x9E3779B1
# second mix constant for the fused chain signature (murmur3 fmix)
GOLDEN2 = 0x85EBCA6B


def _phash_kernel(keys_ref, out_ref, *, n_partitions: int):
    k = keys_ref[...].astype(jnp.uint32)
    h = (k * jnp.uint32(GOLDEN)).astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    out_ref[...] = (h % jnp.uint32(n_partitions)).astype(jnp.int32)


def phash(keys: jax.Array, *, n_partitions: int = 64, block_n: int = 1024,
          interpret: bool = True) -> jax.Array:
    """keys [N] int32/uint32 -> partition ids [N] int32."""
    (N,) = keys.shape
    bn = min(block_n, N)
    kernel = functools.partial(_phash_kernel, n_partitions=n_partitions)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(keys)


def _phash_chain_kernel(parents_ref, names_ref, hints_ref, depths_ref,
                        comp_ref, hint_ref, sig_ref, *,
                        n_partitions: int, depth: int):
    """Fused chain hash: per-component partition ids, per-path hint (leaf)
    partition ids, and a per-path chain signature, in one pass.

    ``parents[n, d]`` is the parent inode id of path n's d-th component and
    ``names[n, d]`` a 32-bit hash of its name — i.e. the composite PK
    (parent_id, name) the hint cache resolves (§5.1). Component partitions
    use the SAME mix as the scalar store hash (inodes are partitioned by
    parent_id, §4.2), so client-side routing agrees with ``MetadataStore``
    placement exactly; the signature folds every (parent, name) pair into
    a constant-time path-equality probe for chain-level consumers."""
    par = parents_ref[...].astype(jnp.uint32)          # [bn, depth]
    nam = names_ref[...].astype(jnp.uint32)            # [bn, depth]
    h = (par * jnp.uint32(GOLDEN)).astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    comp_ref[...] = (h % jnp.uint32(n_partitions)).astype(jnp.int32)
    hv = (hints_ref[...].astype(jnp.uint32)
          * jnp.uint32(GOLDEN)).astype(jnp.uint32)
    hv = hv ^ (hv >> jnp.uint32(16))
    hint_ref[...] = (hv % jnp.uint32(n_partitions)).astype(jnp.int32)
    d = depths_ref[...]                                # [bn] int32
    sig = jnp.zeros(par.shape[:1], jnp.uint32)
    for k in range(depth):       # static unroll over the (small) max depth
        step = ((sig ^ h[:, k] ^ nam[:, k])
                * jnp.uint32(GOLDEN2)).astype(jnp.uint32)
        step = step ^ (step >> jnp.uint32(15))
        sig = jnp.where(k < d, step, sig)
    sig_ref[...] = sig


def phash_chain(parents: jax.Array, names: jax.Array, hints: jax.Array,
                depths: jax.Array, *, n_partitions: int = 64,
                block_n: int = 1024, interpret: bool = True):
    """parents/names [N, D] uint32, hints [N] uint32, depths [N] int32 ->
    (comp_parts [N, D] int32, hint_parts [N] int32, sigs [N] uint32)."""
    N, D = parents.shape
    bn = min(block_n, N)
    kernel = functools.partial(_phash_chain_kernel,
                               n_partitions=n_partitions, depth=D)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((bn, D), lambda i: (i, 0)),
                  pl.BlockSpec((bn, D), lambda i: (i, 0)),
                  pl.BlockSpec((bn,), lambda i: (i,)),
                  pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((bn, D), lambda i: (i, 0)),
                   pl.BlockSpec((bn,), lambda i: (i,)),
                   pl.BlockSpec((bn,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((N, D), jnp.int32),
                   jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((N,), jnp.uint32)],
        interpret=interpret,
    )(parents, names, hints, depths)
