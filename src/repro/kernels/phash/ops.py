"""jit'd wrapper for the partition hash."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import phash as _phash


@functools.partial(jax.jit, static_argnames=("n_partitions", "interpret"))
def phash(keys, n_partitions: int = 64, interpret: bool = True):
    return _phash(keys, n_partitions=n_partitions, interpret=interpret)


def phash_partitions(keys, n_partitions: int = 64, *,
                     interpret: bool = True) -> np.ndarray:
    """Partition ids for a whole batch of integer keys at once.

    This is the vectorized path->partition step of the batched request
    pipeline: a namenode hashes every hinted inode id in a pulled batch in
    one kernel launch instead of per-op Python hashing. Results match
    ``repro.core.store._hash_key(key) % n_partitions`` exactly for integer
    keys (both sides operate on the low 32 bits).

    Keys are padded to a power-of-two length (>= 8) so the 1-D grid always
    tiles evenly and jit recompiles are bounded to O(log N) shapes.
    """
    arr = np.asarray(keys, dtype=np.int64) & 0xFFFFFFFF
    n = arr.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    padded = 8
    while padded < n:
        padded *= 2
    buf = np.zeros(padded, dtype=np.uint32)
    buf[:n] = arr.astype(np.uint32)
    out = phash(jnp.asarray(buf), n_partitions=n_partitions,
                interpret=interpret)
    return np.asarray(out)[:n]
