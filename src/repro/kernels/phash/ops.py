"""jit'd wrapper for the partition hash."""
import functools

import jax

from .kernel import phash as _phash


@functools.partial(jax.jit, static_argnames=("n_partitions", "interpret"))
def phash(keys, n_partitions: int = 64, interpret: bool = True):
    return _phash(keys, n_partitions=n_partitions, interpret=interpret)
