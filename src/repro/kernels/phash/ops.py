"""jit'd wrapper for the partition hash."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import phash as _phash
from .kernel import phash_chain as _phash_chain


@functools.partial(jax.jit, static_argnames=("n_partitions", "interpret"))
def phash(keys, n_partitions: int = 64, interpret: bool = True):
    return _phash(keys, n_partitions=n_partitions, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_partitions", "interpret"))
def phash_chain(parents, names, hints, depths, n_partitions: int = 64,
                interpret: bool = True):
    return _phash_chain(parents, names, hints, depths,
                        n_partitions=n_partitions, interpret=interpret)


def phash_partitions(keys, n_partitions: int = 64, *,
                     interpret: bool = True) -> np.ndarray:
    """Partition ids for a whole batch of integer keys at once.

    This is the vectorized path->partition step of the batched request
    pipeline: a namenode hashes every hinted inode id in a pulled batch in
    one kernel launch instead of per-op Python hashing. Results match
    ``repro.core.store._hash_key(key) % n_partitions`` exactly for integer
    keys (both sides operate on the low 32 bits).

    Keys are padded to a power-of-two length (>= 8) so the 1-D grid always
    tiles evenly and jit recompiles are bounded to O(log N) shapes.
    """
    arr = np.asarray(keys, dtype=np.int64) & 0xFFFFFFFF
    n = arr.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    padded = 8
    while padded < n:
        padded *= 2
    buf = np.zeros(padded, dtype=np.uint32)
    buf[:n] = arr.astype(np.uint32)
    out = phash(jnp.asarray(buf), n_partitions=n_partitions,
                interpret=interpret)
    return np.asarray(out)[:n]


def _pad_pow2(n: int, floor: int = 8) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


def phash_chains(parent_ids, name_hashes, hint_ids, depths,
                 n_partitions: int = 64, *, interpret: bool = True
                 ) -> "tuple[np.ndarray, np.ndarray, np.ndarray]":
    """Fused chain hashing for the client-side batch planner: ONE kernel
    launch over every path's (parent_id, name) component chain returns

      * ``comp_parts [N, D]`` — partition of every component's inode row
        (inodes are partitioned by parent_id, §4.2), matching
        ``repro.core.store._hash_key(parent_id) % n_partitions`` exactly;
      * ``hint_parts [N]``    — partition of each op's hinted (leaf) inode
        id, the key the planner groups partition-aligned batches on;
      * ``sigs [N]``          — 32-bit fold of the whole chain, a
        constant-time path-equality probe for chain-level consumers.

    ``parent_ids``/``name_hashes`` are [N, D] arrays padded with zeros
    beyond ``depths[n]`` components. N is padded to a power of two (>= 8)
    so the 1-D grid tiles evenly and jit recompiles stay O(log N)."""
    par = np.asarray(parent_ids, dtype=np.int64) & 0xFFFFFFFF
    nam = np.asarray(name_hashes, dtype=np.int64) & 0xFFFFFFFF
    hin = np.asarray(hint_ids, dtype=np.int64) & 0xFFFFFFFF
    dep = np.asarray(depths, dtype=np.int32)
    n = par.shape[0]
    if n == 0:
        d0 = par.shape[1] if par.ndim == 2 else 0
        return (np.zeros((0, d0), np.int32), np.zeros(0, np.int32),
                np.zeros(0, np.uint32))
    d = max(1, par.shape[1])
    pn = _pad_pow2(n)
    bufs = [np.zeros((pn, d), np.uint32), np.zeros((pn, d), np.uint32)]
    bufs[0][:n, :par.shape[1]] = par.astype(np.uint32)
    bufs[1][:n, :nam.shape[1]] = nam.astype(np.uint32)
    hbuf = np.zeros(pn, np.uint32)
    hbuf[:n] = hin.astype(np.uint32)
    dbuf = np.zeros(pn, np.int32)
    dbuf[:n] = dep
    comp, hint_parts, sigs = phash_chain(
        jnp.asarray(bufs[0]), jnp.asarray(bufs[1]), jnp.asarray(hbuf),
        jnp.asarray(dbuf), n_partitions=n_partitions, interpret=interpret)
    return (np.asarray(comp)[:n], np.asarray(hint_parts)[:n],
            np.asarray(sigs)[:n])
