from . import ops, ref
