"""Oracle matching repro.core.store._hash_key for integer keys."""
import numpy as np


def phash_ref(keys, n_partitions: int = 64):
    k = np.asarray(keys).astype(np.uint32)
    h = (k * np.uint32(0x9E3779B1)).astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    return (h % np.uint32(n_partitions)).astype(np.int32)
