"""Oracle matching repro.core.store._hash_key for integer keys."""
import numpy as np


def phash_ref(keys, n_partitions: int = 64):
    k = np.asarray(keys).astype(np.uint32)
    h = (k * np.uint32(0x9E3779B1)).astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    return (h % np.uint32(n_partitions)).astype(np.int32)


def phash_chain_ref(parents, names, hints, depths, n_partitions: int = 64):
    """Numpy oracle for the fused chain kernel (also the planner's fallback
    when the Pallas stack is unavailable): per-component partitions, hint
    partitions, and chain signatures."""
    par = np.asarray(parents).astype(np.uint32)
    nam = np.asarray(names).astype(np.uint32)
    d = np.asarray(depths).astype(np.int32)
    with np.errstate(over="ignore"):
        h = (par * np.uint32(0x9E3779B1)).astype(np.uint32)
        h = h ^ (h >> np.uint32(16))
        comp = (h % np.uint32(n_partitions)).astype(np.int32)
        hint_parts = phash_ref(hints, n_partitions)
        sig = np.zeros(par.shape[0], dtype=np.uint32)
        for k in range(par.shape[1]):
            step = ((sig ^ h[:, k] ^ nam[:, k])
                    * np.uint32(0x85EBCA6B)).astype(np.uint32)
            step = step ^ (step >> np.uint32(15))
            sig = np.where(k < d, step, sig)
    return comp, hint_parts, sig
