"""Pure-jnp oracle for the flash-attention kernel (exact softmax)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jax.Array:
    """q [B,S,H,hd]; k/v [B,S,KV,hd] (GQA) -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    logits /= jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)
