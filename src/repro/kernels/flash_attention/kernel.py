"""Flash attention forward — Pallas TPU kernel.

Grid: (batch*q_heads, n_q_blocks, n_k_blocks); the k dimension is
`arbitrary` (sequential) so the online-softmax state (running max m,
denominator l, accumulator acc) lives in VMEM scratch across k-steps.

BlockSpecs move [block_q, head_dim] query tiles and [block_k, head_dim]
key/value tiles HBM->VMEM; GQA is handled by the k/v index_map (q head h
reads kv head h // group_size) with no HBM duplication. Causal +
sliding-window masking is applied in-kernel; fully-masked k-blocks are
skipped via pl.when (the TPU grid still visits them, but no MXU work is
issued).

VMEM budget per step: bq*hd (q) + 2*bk*hd (k,v) + bq*bk (scores) +
bq*(hd+2) f32 scratch ~= 1.3 MB at bq=bk=512, hd=128 — well inside the
~16 MB/core VMEM of v5e.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; ANY works for interpret mode on CPU
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _scratch(shape):
    if _VMEM is not None:
        return _VMEM(shape, jnp.float32)
    return pl.MemorySpace.ANY(shape, jnp.float32)  # type: ignore


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      causal: bool, window: Optional[int],
                      softcap: Optional[float], block_q: int, block_k: int,
                      n_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # static-shape mask bounds for this block pair
    def compute():
        q = q_ref[0].astype(jnp.float32)                  # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= (1.0 / (q.shape[-1] ** 0.5))
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_cur
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal or window is not None:
        # skip blocks with no valid (q, k) pair
        valid = jnp.bool_(True)
        if causal:
            valid &= k_start <= q_start + block_q - 1
        if window is not None:
            valid &= k_start + block_k - 1 > q_start - window
        pl.when(valid)(compute)
    else:
        compute()

    @pl.when(ki == n_k_blocks - 1)
    def _flush():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None, block_q: int = 512,
                        block_k: int = 512, interpret: bool = True
                        ) -> jax.Array:
    """q [B,S,H,hd]; k/v [B,S,KV,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq, nk = S // bq, S // bk
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, n_k_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki, G=G: (b // G, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki, G=G: (b // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            _scratch((block_q if S >= block_q else S, 1)),
            _scratch((block_q if S >= block_q else S, 1)),
            _scratch((block_q if S >= block_q else S, hd)),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
