"""jit'd wrapper: Pallas forward + recompute-based backward (custom_vjp).

The backward recomputes attention through the jnp oracle's VJP — the
standard flash recipe (save only q,k,v + output stats; recompute blocks),
expressed here at the layer granularity.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_attention_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = True):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=interpret)


def _fwd(q, k, v, causal, window, softcap, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              softcap=softcap, interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, softcap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(
        q_, k_, v_, causal=causal, window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
