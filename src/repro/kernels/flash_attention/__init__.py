from . import ops, ref
