"""Gemma3-12B [hf:google/gemma-3; unverified] — 48L d=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144. 5 local (sliding 1024) : 1 global interleave,
128k context."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    head_dim=256, d_ff=15360, vocab_size=262144,
    sliding_window=1024, global_interval=6,   # 5 local : 1 global
    rope_theta=1_000_000.0, mlp_type="gelu", norm="rmsnorm",
    tie_embeddings=True, logit_softcap=None,
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=256,
                         sliding_window=16, global_interval=2)
