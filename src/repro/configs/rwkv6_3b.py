"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf] — 32L d=2560 (attention-free)
d_ff=8960 vocab=65536. Data-dependent decay; constant-state decode."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,  # 40*64 = 2560
    d_ff=8960, vocab_size=65536,
    rwkv_head_dim=64,
    mlp_type="relu2", norm="layernorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab_size=256, rwkv_head_dim=16)
