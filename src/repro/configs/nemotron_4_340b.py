"""Nemotron-4-340B [arXiv:2402.16819; unverified] — 96L d=18432 96H (GQA
kv=8) d_ff=73728 vocab=256000. Squared-ReLU MLP."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron_4_340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab_size=256000,
    mlp_type="relu2", norm="layernorm", rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256)
