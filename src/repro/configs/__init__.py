"""Architecture registry: ``get_config(name)`` + ``ARCHS`` listing.

Each module defines CONFIG (the full published architecture) and
``smoke_config()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "qwen2_vl_7b",
    "qwen3_moe_30b_a3b",
    "mixtral_8x22b",
    "command_r_plus_104b",
    "gemma3_12b",
    "nemotron_4_340b",
    "qwen1_5_4b",
    "zamba2_2_7b",
    "rwkv6_3b",
    "seamless_m4t_medium",
]

# input shapes assigned to the LM pool (seq_len, global_batch, kind)
SHAPES: Dict[str, Dict] = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

#: archs that can run the sub-quadratic long_500k cell (SSM / hybrid /
#: windowed attention); pure full-attention archs skip it (see DESIGN.md
#: §3.3)
LONG_CONTEXT_OK = {"rwkv6_3b", "zamba2_2_7b", "mixtral_8x22b", "gemma3_12b"}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{name}", __package__)
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{name}", __package__)
    return mod.smoke_config()


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honoring the long-context skip."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            skip = (s == "long_500k" and a not in LONG_CONTEXT_OK)
            if include_skipped or not skip:
                out.append((a, s, skip))
    return out
