"""Mixtral-8x22B [arXiv:2401.04088; hf] — 56L d=6144 48H (GQA kv=8)
expert d_ff=16384, vocab=32768, MoE 8 experts top-2, sliding-window attn.
8 experts don't divide the 16-way model axis -> TP expert strategy."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, moe_d_ff=16384, vocab_size=32768,
    n_experts=8, experts_per_token=2,
    sliding_window=4096,
    rope_theta=1_000_000.0, mlp_type="swiglu", norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, moe_d_ff=128, vocab_size=256,
                         n_experts=4, experts_per_token=2,
                         sliding_window=16)
