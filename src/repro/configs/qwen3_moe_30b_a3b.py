"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 48L d=2048 32H (GQA kv=4)
expert d_ff=768, vocab=151936, MoE 128 experts top-8 (EP over `model`)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_30b_a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=6144, moe_d_ff=768, vocab_size=151936,
    n_experts=128, experts_per_token=8,
    rope_theta=1_000_000.0, mlp_type="swiglu", norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, moe_d_ff=32, vocab_size=256,
                         n_experts=8, experts_per_token=2)
