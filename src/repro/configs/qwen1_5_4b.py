"""Qwen1.5-4B [hf:Qwen/Qwen1.5; hf] — 40L d=2560 20H (GQA kv=20 = MHA)
d_ff=6912 vocab=151936. QKV bias. 20 heads don't divide the 16-way model
axis -> attention runs data-parallel (see DESIGN.md hardware notes)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1_5_4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab_size=151936,
    qkv_bias=True, rope_theta=1_000_000.0,
    mlp_type="swiglu", norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=2, d_model=60, n_heads=5, n_kv_heads=5,
                         d_ff=128, vocab_size=256)
