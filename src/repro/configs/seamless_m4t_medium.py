"""SeamlessM4T-medium [arXiv:2308.11596; hf] — 12L enc + 12L dec, d=1024
16H (kv=16) d_ff=4096 vocab=256206 (padded to 256256 for 16-way TP).
Speech frontend is a STUB: input_specs provides precomputed frame
embeddings [B, T_frames, d]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium", family="encdec",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256256,   # 256206 padded to /128
    n_patches=1024,                 # frame count stand-in for enc input
    mlp_type="gelu", norm="layernorm", rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=4, n_enc_layers=2, n_dec_layers=2,
                         d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                         vocab_size=256, n_patches=16)
