"""Command R+ 104B [hf:CohereForAI; unverified] — 64L d=12288 96H (GQA
kv=8) d_ff=33792 vocab=256000. No biases; parallel attention+FFN block."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command_r_plus_104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    parallel_block=True, tie_embeddings=True,
    rope_theta=75_000_000.0, mlp_type="swiglu", norm="layernorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256)
