"""Zamba2-2.7B [arXiv:2411.15242; hf] — 54L d=2560, Mamba2 backbone
(state=64) + SHARED attention block (32H, kv=32) every 6 layers,
d_ff=10240 vocab=32000."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_2_7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_heads=80, ssm_expand=2, ssm_conv=4,
    shared_attn_every=6,
    mlp_type="swiglu", norm="rmsnorm", rope_theta=10_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab_size=256, ssm_state=16,
                         ssm_heads=4, shared_attn_every=2)
