"""Qwen2-VL-7B [arXiv:2409.12191; hf] — 28L d=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064. M-RoPE over (t,h,w); dynamic-resolution vision
frontend is a STUB (precomputed patch embeddings via input_specs)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    rope_theta=1_000_000.0, mrope=True, mrope_sections=(16, 24, 24),
    qkv_bias=True, mlp_type="swiglu", norm="rmsnorm",
    n_patches=1024,
)


def smoke_config() -> ModelConfig:
    return CONFIG.derive(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256, n_patches=8,
                         mrope_sections=(4, 2, 2))
