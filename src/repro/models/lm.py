"""Model assembly: one composable decoder covering all ten architectures.

Families:
  dense / moe / vlm — transformer decoder, scan-over-layers, per-layer
        flags drive local:global attention (gemma3) and MoE (qwen3/mixtral);
        vlm (qwen2-vl) splices precomputed patch embeddings + M-RoPE.
  hybrid            — zamba2: Mamba2 backbone + a SHARED attention block
        applied every `shared_attn_every` layers (own KV slot per
        application).
  ssm               — rwkv6: attention-free WKV blocks.
  encdec            — seamless: bidirectional encoder over frame embeddings
        (stub frontend per assignment) + causal decoder w/ cross-attention.

Interface (all pure functions):
  param_specs(cfg)                      -> ParamSpec tree
  init_cache_specs(cfg, B, S_max)       -> ParamSpec-like tree for caches
  forward(params, batch, cfg, policy, mesh, ...) -> logits
  decode_step(params, batch, cache, index, ...)  -> (logits, new cache)
  loss_fn(params, batch, ...)           -> scalar loss
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..parallel.sharding import MeshPolicy, shard_constraint
from .config import ModelConfig
from .layers import (apply_norm, attention_block, attn_specs, embed,
                     embed_specs, lm_head, mlp_block, mlp_specs, norm_specs,
                     _sdpa)
from .mamba2 import mamba2_block, mamba2_specs
from .moe import moe_apply, moe_specs
from .params import ParamSpec
from .rwkv6 import rwkv6_att, rwkv6_ffn, rwkv6_specs


def _stack(specs: Any, L: int) -> Any:
    """Prepend a scanned `layers` axis to every leaf spec."""
    return jax.tree.map(
        lambda s: ParamSpec((L,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def layer_flags(cfg: ModelConfig) -> np.ndarray:
    """Per-layer is_global flags (gemma3 5:1 local:global; SWA archs are
    all-local; others all-global)."""
    L = cfg.n_layers
    if cfg.global_interval:
        return np.asarray([(i % cfg.global_interval) ==
                           (cfg.global_interval - 1) for i in range(L)])
    if cfg.sliding_window:
        return np.zeros(L, bool)
    return np.ones(L, bool)


# ===========================================================================
# decoder transformer (dense / moe / vlm)
# ===========================================================================


def _layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {"ln1": norm_specs(cfg), "ln2": norm_specs(cfg),
                         "attn": attn_specs(cfg)}
    if cfg.is_moe:
        s["moe"] = moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg)
    return s


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family == "ssm":
        return _rwkv_param_specs(cfg)
    if cfg.family == "hybrid":
        return _hybrid_param_specs(cfg)
    if cfg.family == "encdec":
        return _encdec_param_specs(cfg)
    s = {"embed": embed_specs(cfg),
         "layers": _stack(_layer_specs(cfg), cfg.n_layers),
         "ln_f": norm_specs(cfg)}
    if cfg.family == "vlm":
        s["patch_proj"] = {
            "w": ParamSpec((cfg.d_model, cfg.d_model), ("embed", None))}
    return s


def init_cache_specs(cfg: ModelConfig, B: int, S_max: int) -> Any:
    """KV-cache / state trees as ParamSpecs (zeros init; `kv_seq` logical
    axis lets long-context policies shard the cache over `data`)."""
    if cfg.family == "ssm":
        d = cfg.d_model
        H, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        L = cfg.n_layers
        return {"wkv": ParamSpec((L, B, H, hd, hd),
                                 ("layers", "batch", "heads", None, None),
                                 "zeros"),
                "shift_a": ParamSpec((L, B, 1, d),
                                     ("layers", "batch", None, "act_embed"),
                                     "zeros"),
                "shift_f": ParamSpec((L, B, 1, d),
                                     ("layers", "batch", None, "act_embed"),
                                     "zeros")}
    if cfg.family == "hybrid":
        d = cfg.d_model
        d_in = cfg.ssm_expand * d
        H = cfg.ssm_heads or max(1, d_in // 64)
        hd = d_in // H
        L, N, K = cfg.n_layers, cfg.ssm_state, cfg.ssm_conv
        n_apps = max(1, L // max(1, cfg.shared_attn_every))
        kv = cfg.n_kv_heads
        return {"h": ParamSpec((L, B, H, hd, N),
                               ("layers", "batch", None, None, "state"),
                               "zeros"),
                "conv": ParamSpec((L, B, K - 1, d_in + 2 * N),
                                  ("layers", "batch", None, None), "zeros"),
                "shared_k": ParamSpec((n_apps, B, S_max, kv, cfg.hd),
                                      (None, "batch", "kv_seq", "kv_heads",
                                       None), "zeros"),
                "shared_v": ParamSpec((n_apps, B, S_max, kv, cfg.hd),
                                      (None, "batch", "kv_seq", "kv_heads",
                                       None), "zeros")}
    if cfg.family == "encdec":
        kv = cfg.n_kv_heads
        return {"k": ParamSpec((cfg.n_dec_layers, B, S_max, kv, cfg.hd),
                               ("layers", "batch", "kv_seq", "kv_heads",
                                None), "zeros"),
                "v": ParamSpec((cfg.n_dec_layers, B, S_max, kv, cfg.hd),
                               ("layers", "batch", "kv_seq", "kv_heads",
                                None), "zeros"),
                "enc_out": ParamSpec((B, cfg.n_patches, cfg.d_model),
                                     ("batch", "frames", "act_embed"), "zeros")}
    kv = cfg.n_kv_heads
    return {"k": ParamSpec((cfg.n_layers, B, S_max, kv, cfg.hd),
                           ("layers", "batch", "kv_seq", "kv_heads", None),
                           "zeros"),
            "v": ParamSpec((cfg.n_layers, B, S_max, kv, cfg.hd),
                           ("layers", "batch", "kv_seq", "kv_heads", None),
                           "zeros")}


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.
                              nothing_saveable)
    if cfg.remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _decoder_stack(params: Dict[str, Any], x: jax.Array, *,
                   cfg: ModelConfig, policy: MeshPolicy,
                   mesh: Optional[Mesh], positions: jax.Array,
                   cache: Optional[Dict[str, jax.Array]] = None,
                   cache_index: Optional[jax.Array] = None,
                   use_pallas: bool = False) -> Tuple[jax.Array, Any]:
    flags = jnp.asarray(layer_flags(cfg))
    decode = cache_index is not None

    def layer(carry_x, scanned):
        lp, is_global, ck, cv = scanned
        h = apply_norm(cfg, lp["ln1"], carry_x)
        layer_cache = {"k": ck, "v": cv} if ck is not None else None
        a, new_cache = attention_block(
            lp["attn"], h, cfg=cfg, positions=positions, policy=policy,
            mesh=mesh, is_global=is_global, cache=layer_cache,
            cache_index=cache_index, use_pallas=use_pallas)
        if cfg.parallel_block:
            # command-r: x + attn(ln(x)) + mlp(ln(x)) with the same norm
            m = mlp_block(lp["mlp"], h, cfg=cfg, policy=policy, mesh=mesh)
            out = carry_x + a + m
        else:
            h2 = carry_x + a
            hn = apply_norm(cfg, lp["ln2"], h2)
            if cfg.is_moe:
                m = moe_apply(lp["moe"], hn, cfg=cfg, policy=policy,
                              mesh=mesh)
            else:
                m = mlp_block(lp["mlp"], hn, cfg=cfg, policy=policy,
                              mesh=mesh)
            out = h2 + m
        out = shard_constraint(out, ("batch", "seq", "act_embed"), policy, mesh)
        nk = new_cache["k"] if new_cache is not None else ck
        nv = new_cache["v"] if new_cache is not None else cv
        return out, (nk, nv)

    layer = _maybe_remat(layer, cfg)

    if cfg.scan_layers:
        ck = cache["k"] if cache is not None else None
        cv = cache["v"] if cache is not None else None

        def body(carry, xs):
            out, (nk, nv) = layer(carry, xs)
            return out, (nk, nv)
        xs = (params["layers"], flags,
              ck if ck is not None else jnp.zeros((cfg.n_layers,)),
              cv if cv is not None else jnp.zeros((cfg.n_layers,)))
        if cache is None:
            def body_nc(carry, xs):
                lp, fl, _, _ = xs
                out, _ = layer(carry, (lp, fl, None, None))
                return out, None
            x, _ = jax.lax.scan(body_nc, x, xs)
            return x, None
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        return x, {"k": nk, "v": nv}
    # unrolled (hillclimb alternative)
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        ck = cache["k"][i] if cache is not None else None
        cv = cache["v"][i] if cache is not None else None
        x, (nk, nv) = layer(x, (lp, flags[i], ck, cv))
        if cache is not None:
            new_k.append(nk)
            new_v.append(nv)
    nc = {"k": jnp.stack(new_k), "v": jnp.stack(new_v)} \
        if cache is not None else None
    return x, nc


def forward(params: Dict[str, Any], batch: Dict[str, jax.Array], *,
            cfg: ModelConfig, policy: MeshPolicy,
            mesh: Optional[Mesh] = None,
            cache: Optional[Any] = None,
            cache_index: Optional[jax.Array] = None,
            use_pallas: bool = False) -> Tuple[jax.Array, Any]:
    """Returns (logits, new_cache). Train/prefill: cache_index None."""
    if cfg.family == "ssm":
        return _rwkv_forward(params, batch, cfg=cfg, policy=policy,
                             mesh=mesh, cache=cache,
                             cache_index=cache_index,
                             use_pallas=use_pallas)
    if cfg.family == "hybrid":
        return _hybrid_forward(params, batch, cfg=cfg, policy=policy,
                               mesh=mesh, cache=cache,
                               cache_index=cache_index,
                               use_pallas=use_pallas)
    if cfg.family == "encdec":
        return _encdec_forward(params, batch, cfg=cfg, policy=policy,
                               mesh=mesh, cache=cache,
                               cache_index=cache_index,
                               use_pallas=use_pallas)
    tokens = batch["tokens"]
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, policy=policy, mesh=mesh, dtype=dtype)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        # splice precomputed patch embeddings (frontend stub) over the
        # leading n_patches token positions
        pe = batch["patch_embeds"].astype(dtype) @ \
            params["patch_proj"]["w"].astype(dtype)
        pe = pe.astype(dtype)
        P_ = pe.shape[1]
        x = jnp.concatenate([pe, x[:, P_:]], axis=1)
    if cfg.mrope:
        positions = batch.get("positions")
        if positions is None:
            S = tokens.shape[1]
            pos1 = (jnp.arange(S)[None, :, None] if cache_index is None
                    else cache_index[None, None, None] +
                    jnp.zeros((1, 1, 1), jnp.int32))
            positions = jnp.broadcast_to(pos1, tokens.shape + (3,))
    else:
        S = tokens.shape[1]
        positions = (jnp.arange(S)[None, :] if cache_index is None
                     else jnp.full((tokens.shape[0], S), 0) + cache_index)
        positions = jnp.broadcast_to(positions, tokens.shape)
    x, new_cache = _decoder_stack(params, x, cfg=cfg, policy=policy,
                                  mesh=mesh, positions=positions,
                                  cache=cache, cache_index=cache_index,
                                  use_pallas=use_pallas)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = lm_head(params["embed"], x, policy=policy, mesh=mesh)
    return logits, new_cache


# ===========================================================================
# rwkv6 (ssm family)
# ===========================================================================


def _rwkv_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    per_layer = dict(rwkv6_specs(cfg))
    per_layer["ln1"] = norm_specs(cfg)
    per_layer["ln2"] = norm_specs(cfg)
    return {"embed": embed_specs(cfg),
            "layers": _stack(per_layer, cfg.n_layers),
            "ln_f": norm_specs(cfg)}


def _rwkv_forward(params, batch, *, cfg, policy, mesh, cache=None,
                  cache_index=None, use_pallas=False):
    tokens = batch["tokens"]
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, policy=policy, mesh=mesh, dtype=dtype)
    decode = cache_index is not None

    def layer(carry_x, lp, st):
        from .layers import rmsnorm
        h = rmsnorm(carry_x, lp["ln1"]["scale"], cfg.norm_eps)
        a, st_a = rwkv6_att(lp["att"], h, cfg=cfg, policy=policy, mesh=mesh,
                            state=st, decode=decode, use_pallas=use_pallas)
        x2 = carry_x + a
        h2 = rmsnorm(x2, lp["ln2"]["scale"], cfg.norm_eps)
        f, new_sf = rwkv6_ffn(lp["ffn"], h2,
                              cfg=cfg, policy=policy, mesh=mesh,
                              state={"shift_f": st["shift_f"]}
                              if st is not None else None)
        out = x2 + f
        if st_a is not None:
            return out, {"wkv": st_a["wkv"], "shift_a": st_a["shift_a"],
                         "shift_f": new_sf}
        return out, None

    lp_all = params["layers"]
    if cache is not None or decode:
        c = cache

        def body(carry, s):
            lp, wkv, sa, sf = s
            out, st = layer(carry, lp,
                            {"wkv": wkv, "shift_a": sa, "shift_f": sf})
            return out, (st["wkv"], st["shift_a"], st["shift_f"])
        x, (wkv, sa, sf) = jax.lax.scan(
            body, x, (lp_all, c["wkv"], c["shift_a"], c["shift_f"]))
        new_cache = {"wkv": wkv, "shift_a": sa, "shift_f": sf}
    else:
        def body(carry, lp):
            out, _ = layer(carry, lp, None)
            return out, None
        x, _ = jax.lax.scan(body, x, lp_all)
        new_cache = None
    from .layers import rmsnorm
    x = rmsnorm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_head(params["embed"], x, policy=policy, mesh=mesh)
    return logits, new_cache


# ===========================================================================
# zamba2 (hybrid family)
# ===========================================================================


def _hybrid_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    per_layer = {"ln1": norm_specs(cfg), "mamba": mamba2_specs(cfg),
                 "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    shared = {"ln1": norm_specs(cfg), "attn": attn_specs(cfg),
              "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    return {"embed": embed_specs(cfg),
            "layers": _stack(per_layer, cfg.n_layers),
            "shared": shared,
            "ln_f": norm_specs(cfg)}


def _hybrid_forward(params, batch, *, cfg, policy, mesh, cache=None,
                    cache_index=None, use_pallas=False):
    tokens = batch["tokens"]
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, policy=policy, mesh=mesh, dtype=dtype)
    decode = cache_index is not None
    B, S = tokens.shape
    every = max(1, cfg.shared_attn_every)
    positions = (jnp.arange(S)[None, :] if not decode
                 else jnp.zeros((B, S), jnp.int32) + cache_index)
    positions = jnp.broadcast_to(positions, (B, S))

    def mamba_layer(x_in, lp, st):
        h = apply_norm(cfg, lp["ln1"], x_in)
        m, new_st = mamba2_block(lp["mamba"], h, cfg=cfg, policy=policy,
                                 mesh=mesh, state=st, decode=decode,
                                 use_pallas=use_pallas)
        x2 = x_in + m
        h2 = apply_norm(cfg, lp["ln2"], x2)
        x3 = x2 + mlp_block(lp["mlp"], h2, cfg=cfg, policy=policy,
                            mesh=mesh)
        return x3, new_st

    c = cache
    # scan the mamba backbone; shared attention applied OUTSIDE the scan at
    # its interval positions (keeps the scan homogeneous; n_apps is small)
    n_apps = max(1, cfg.n_layers // every)
    seg = every
    new_h, new_conv = [], []
    new_sk, new_sv = [], []
    for app in range(n_apps):
        sl = slice(app * seg, (app + 1) * seg)
        seg_params = jax.tree.map(lambda a: a[sl], params["layers"])
        if c is not None or decode:
            def body_s(carry, s):
                lp, hs, cs = s
                out, st = mamba_layer(carry, lp, {"h": hs, "conv": cs})
                return out, (st["h"], st["conv"])
            x, ys = jax.lax.scan(body_s, x,
                                 (seg_params, c["h"][sl], c["conv"][sl]))
            new_h.append(ys[0])
            new_conv.append(ys[1])
        else:
            def body_t(carry, lp):
                out, _ = mamba_layer(carry, lp, None)
                return out, None
            x, _ = jax.lax.scan(body_t, x, seg_params)
        # shared attention block (same params every application)
        sp = params["shared"]
        hh = apply_norm(cfg, sp["ln1"], x)
        app_cache = None
        if c is not None:
            app_cache = {"k": c["shared_k"][app], "v": c["shared_v"][app]}
        a, new_app_cache = attention_block(
            sp["attn"], hh, cfg=cfg, positions=positions, policy=policy,
            mesh=mesh, is_global=True, cache=app_cache,
            cache_index=cache_index, use_pallas=use_pallas)
        x = x + a
        h2 = apply_norm(cfg, sp["ln2"], x)
        x = x + mlp_block(sp["mlp"], h2, cfg=cfg, policy=policy, mesh=mesh)
        if c is not None and new_app_cache is not None:
            new_sk.append(new_app_cache["k"])
            new_sv.append(new_app_cache["v"])
    new_cache = None
    if c is not None:
        new_cache = {"h": jnp.concatenate(new_h) if new_h else c["h"],
                     "conv": jnp.concatenate(new_conv) if new_conv
                     else c["conv"],
                     "shared_k": jnp.stack(new_sk) if new_sk
                     else c["shared_k"],
                     "shared_v": jnp.stack(new_sv) if new_sv
                     else c["shared_v"]}
    x = apply_norm(cfg, params["ln_f"], x)
    logits = lm_head(params["embed"], x, policy=policy, mesh=mesh)
    return logits, new_cache


# ===========================================================================
# seamless (encdec family)
# ===========================================================================


def _encdec_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    enc_layer = {"ln1": norm_specs(cfg), "attn": attn_specs(cfg),
                 "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    dec_layer = {"ln1": norm_specs(cfg), "attn": attn_specs(cfg),
                 "ln_x": norm_specs(cfg), "xattn": attn_specs(cfg),
                 "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    return {"embed": embed_specs(cfg),
            "enc": _stack(enc_layer, cfg.n_enc_layers),
            "dec": _stack(dec_layer, cfg.n_dec_layers),
            "ln_enc": norm_specs(cfg), "ln_f": norm_specs(cfg)}


def _cross_attention(p, x, enc_out, *, cfg, policy, mesh):
    B, Sq, d = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    Sk = enc_out.shape[1]
    mask = jnp.ones((B, Sq, Sk), bool)
    out = _sdpa(q, k, v, mask, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def _encdec_forward(params, batch, *, cfg, policy, mesh, cache=None,
                    cache_index=None, use_pallas=False):
    dtype = jnp.dtype(cfg.dtype)
    decode = cache_index is not None
    # ---------------- encoder (skipped during decode: enc_out cached) ----
    if not decode:
        enc_x = batch["frames"].astype(dtype)          # stub frontend
        pos_e = jnp.broadcast_to(jnp.arange(enc_x.shape[1])[None, :],
                                 enc_x.shape[:2])

        def enc_layer(carry, lp):
            h = apply_norm(cfg, lp["ln1"], carry)
            B, S, _ = h.shape
            dte = h.dtype
            q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"].astype(dte))
            k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"].astype(dte))
            v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"].astype(dte))
            from .layers import apply_rope
            q = apply_rope(q, pos_e, cfg.rope_theta)
            k = apply_rope(k, pos_e, cfg.rope_theta)
            a = _sdpa(q, k, v, jnp.ones((B, S, S), bool), None)
            a = jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"].astype(dte))
            x2 = carry + a
            h2 = apply_norm(cfg, lp["ln2"], x2)
            return x2 + mlp_block(lp["mlp"], h2, cfg=cfg, policy=policy,
                                  mesh=mesh), None
        enc_out, _ = jax.lax.scan(enc_layer, enc_x, params["enc"])
        enc_out = apply_norm(cfg, params["ln_enc"], enc_out)
    else:
        enc_out = cache["enc_out"].astype(dtype)
    # ---------------- decoder -------------------------------------------
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, policy=policy, mesh=mesh, dtype=dtype)
    B, S = tokens.shape
    positions = (jnp.arange(S)[None, :] if not decode
                 else jnp.zeros((B, S), jnp.int32) + cache_index)
    positions = jnp.broadcast_to(positions, (B, S))

    def dec_layer(carry, scanned):
        lp, ck, cv = scanned
        h = apply_norm(cfg, lp["ln1"], carry)
        layer_cache = {"k": ck, "v": cv} if ck is not None else None
        a, new_cache_l = attention_block(
            lp["attn"], h, cfg=cfg, positions=positions, policy=policy,
            mesh=mesh, is_global=True, cache=layer_cache,
            cache_index=cache_index, use_pallas=use_pallas)
        x2 = carry + a
        hx = apply_norm(cfg, lp["ln_x"], x2)
        x3 = x2 + _cross_attention(lp["xattn"], hx, enc_out, cfg=cfg,
                                   policy=policy, mesh=mesh)
        h2 = apply_norm(cfg, lp["ln2"], x3)
        out = x3 + mlp_block(lp["mlp"], h2, cfg=cfg, policy=policy,
                             mesh=mesh)
        nk = new_cache_l["k"] if new_cache_l is not None else ck
        nv = new_cache_l["v"] if new_cache_l is not None else cv
        return out, (nk, nv)

    if cache is not None:
        x, (nk, nv) = jax.lax.scan(dec_layer, x,
                                   (params["dec"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv, "enc_out": enc_out.astype(
            cache["enc_out"].dtype)}
    else:
        def body(carry, lp):
            out, _ = dec_layer(carry, (lp, None, None))
            return out, None
        x, _ = jax.lax.scan(body, x, params["dec"])
        new_cache = None
    x = apply_norm(cfg, params["ln_f"], x)
    logits = lm_head(params["embed"], x, policy=policy, mesh=mesh)
    return logits, new_cache


# ===========================================================================
# loss
# ===========================================================================


def loss_fn(params, batch, *, cfg: ModelConfig, policy: MeshPolicy,
            mesh: Optional[Mesh] = None, use_pallas: bool = False
            ) -> jax.Array:
    logits, _ = forward(params, batch, cfg=cfg, policy=policy, mesh=mesh,
                        use_pallas=use_pallas)
    labels = batch["labels"]
    # vocab stays TP-sharded throughout: logsumexp and the one-hot-masked
    # gold-logit reduction are elementwise+reduce over the sharded axis
    # (take_along_axis over a sharded vocab makes XLA all-gather logits)
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    viota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
    gold = jnp.sum(jnp.where(viota == labels[..., None], lf, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
