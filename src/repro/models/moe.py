"""Mixture-of-Experts layer: token-choice top-k routing with two
TPU-native execution strategies (selected by expert/mesh divisibility):

  * **EP (expert parallel)** — experts sharded over the `model` axis;
    per-device capacity-buffer dispatch + ``all_to_all`` exchange inside
    ``shard_map`` (GShard-style, qwen3-moe: 128 experts / 16 = 8 per chip).
  * **TP (tensor parallel)** — when n_experts doesn't divide the `model`
    axis (mixtral: 8 experts on 16 chips), every chip keeps all experts but
    shards each expert's hidden dim; the combine is a psum (standard
    Mixtral TP practice).

A dense reference (``moe_dense``) computes the same function without any
collective, used by single-device smoke tests and as the kernels' oracle.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..parallel.sharding import MeshPolicy, logical_to_pspec
from .config import ModelConfig
from .params import ParamSpec


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", "experts")),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def _router(p: Dict[str, Any], x: jax.Array, k: int
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (weights [.., k], experts [.., k]); weights softmaxed over
    the selected k (qwen3/mixtral convention)."""
    logits = jnp.einsum("...d,de->...e", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    top, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(top, axis=-1)
    return w.astype(x.dtype), idx


def _expert_ffn(p, h, which=slice(None)):
    """h: [E?, C, d] -> [E?, C, d] through each expert's SwiGLU."""
    wi, wg, wo = p["wi"][which], p["wg"][which], p["wo"][which]
    a = jnp.einsum("ecd,edf->ecf", h, wi.astype(h.dtype))
    g = jnp.einsum("ecd,edf->ecf", h, wg.astype(h.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * a,
                      wo.astype(h.dtype))


# ---------------------------------------------------------------------------
# dense reference (no collectives): every token through its k experts via
# gather of expert outputs computed for all experts. O(E/k) extra FLOPs —
# fine for the tiny smoke configs and as a correctness oracle.
# ---------------------------------------------------------------------------


def moe_dense(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig
              ) -> jax.Array:
    B, S, d = x.shape
    w, idx = _router(p, x, cfg.experts_per_token)        # [B,S,k]
    xt = x.reshape(1, B * S, d)
    ys = _expert_ffn(p, jnp.broadcast_to(xt, (cfg.n_experts, B * S, d)))
    ys = ys.reshape(cfg.n_experts, B, S, d)
    sel = jnp.take_along_axis(
        jnp.moveaxis(ys, 0, 2),                          # [B,S,E,d]
        idx[..., None], axis=2)                          # [B,S,k,d]
    return jnp.sum(sel * w[..., None], axis=2)


# ---------------------------------------------------------------------------
# capacity-buffer dispatch (shared by EP and TP paths). Everything below
# operates on per-device token blocks inside shard_map.
# ---------------------------------------------------------------------------


def _dispatch(x2: jax.Array, w: jax.Array, idx: jax.Array, E: int, C: int
              ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """x2 [T,d]; w/idx [T,k]. Scatter tokens into per-expert capacity
    buffers. Returns (buffers [E,C,d], keep mask [T,k], pos [T,k], w)."""
    T, k = idx.shape
    flat_e = idx.reshape(-1)                             # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) * onehot            # 1-based positions
    pos_in_e = (pos.sum(-1) - 1).reshape(T, k)           # [T,k]
    keep = pos_in_e < C
    buf = jnp.zeros((E, C, x2.shape[-1]), x2.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    e_safe = jnp.where(keep, idx, 0)
    p_safe = jnp.where(keep, pos_in_e, C - 1)
    buf = buf.at[e_safe.reshape(-1), p_safe.reshape(-1)].add(
        jnp.where(keep.reshape(-1)[:, None], x2[tok_idx.reshape(-1)], 0))
    return buf, keep, pos_in_e, w


def _combine(y_buf: jax.Array, idx: jax.Array, pos: jax.Array,
             keep: jax.Array, w: jax.Array) -> jax.Array:
    """y_buf [E,C,d] -> per-token combine [T,d]."""
    e_safe = jnp.where(keep, idx, 0)
    p_safe = jnp.where(keep, pos, 0)
    gathered = y_buf[e_safe.reshape(-1), p_safe.reshape(-1)]    # [T*k, d]
    T, k = idx.shape
    gathered = gathered.reshape(T, k, -1)
    gathered = jnp.where(keep[..., None], gathered, 0)
    return jnp.sum(gathered * w[..., None], axis=1)


def moe_apply(p: Dict[str, Any], x: jax.Array, *, cfg: ModelConfig,
              policy: MeshPolicy, mesh: Optional[Mesh] = None) -> jax.Array:
    """Dispatch to EP / TP / dense based on mesh shape."""
    if mesh is None or "model" not in mesh.axis_names:
        return moe_dense(p, x, cfg)
    M = mesh.shape["model"]
    if M == 1:
        return moe_dense(p, x, cfg)
    if cfg.n_experts % M == 0:
        return _moe_ep(p, x, cfg, policy, mesh)
    return _moe_tp(p, x, cfg, policy, mesh)


def _token_pspec(policy: MeshPolicy, mesh: Mesh) -> P:
    return logical_to_pspec(("batch", "seq", "act_embed"), policy, mesh)


def _moe_ep(p, x, cfg: ModelConfig, policy: MeshPolicy, mesh: Mesh
            ) -> jax.Array:
    """Expert parallelism over the `model` axis with all_to_all."""
    E, k, M = cfg.n_experts, cfg.experts_per_token, mesh.shape["model"]
    E_loc = E // M
    xs = _token_pspec(policy, mesh)
    # experts sharded over model on their leading dim; router replicated
    wspec = {"router": P(None, None),
             "wi": P("model", None, None), "wg": P("model", None, None),
             "wo": P("model", None, None)}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(wspec, xs), out_specs=xs, check_rep=False)
    def run(pp, xb):
        B, S, d = xb.shape
        T = B * S
        C = max(8, int(np.ceil(T * k / E * cfg.capacity_factor)))
        w, idx = _router(pp, xb, k)
        x2 = xb.reshape(T, d)
        buf, keep, pos, w2 = _dispatch(x2, w.reshape(T, k),
                                       idx.reshape(T, k), E, C)
        # exchange: [E, C, d] -> [M, E_loc, C, d] -> a2a -> peers' blocks
        buf = buf.reshape(M, E_loc, C, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                 tiled=False)            # [M, E_loc, C, d]
        h = buf.reshape(E_loc, M * C, d)
        y = _expert_ffn(pp, h)                           # local experts
        y = y.reshape(M, E_loc, C, d)
        y = jax.lax.all_to_all(y, "model", split_axis=0, concat_axis=0,
                               tiled=False)
        y_buf = y.reshape(E, C, d)
        out = _combine(y_buf, idx.reshape(T, k), pos, keep, w2)
        return out.reshape(B, S, d)

    return run(p, x)


def _moe_tp(p, x, cfg: ModelConfig, policy: MeshPolicy, mesh: Mesh
            ) -> jax.Array:
    """Tensor parallelism: all experts on every chip, hidden dim sharded
    over `model`; psum combines the down-projection."""
    E, k = cfg.n_experts, cfg.experts_per_token
    xs = _token_pspec(policy, mesh)
    wspec = {"router": P(None, None),
             "wi": P(None, None, "model"), "wg": P(None, None, "model"),
             "wo": P(None, "model", None)}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(wspec, xs), out_specs=xs, check_rep=False)
    def run(pp, xb):
        B, S, d = xb.shape
        T = B * S
        C = max(8, int(np.ceil(T * k / E * cfg.capacity_factor)))
        w, idx = _router(pp, xb, k)
        x2 = xb.reshape(T, d)
        buf, keep, pos, w2 = _dispatch(x2, w.reshape(T, k),
                                       idx.reshape(T, k), E, C)
        y_buf = _expert_ffn(pp, buf)                     # sharded hidden
        y_buf = jax.lax.psum(y_buf, "model")
        out = _combine(y_buf, idx.reshape(T, k), pos, keep, w2)
        return out.reshape(B, S, d)

    return run(p, x)
