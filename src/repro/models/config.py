"""Model configuration covering all ten assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # ---- attention ----
    rope_theta: float = 1e4
    mrope: bool = False                     # qwen2-vl M-RoPE (t,h,w sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # per half-dim
    qkv_bias: bool = False                  # qwen1.5
    sliding_window: Optional[int] = None    # mixtral SWA / gemma3 local
    global_interval: Optional[int] = None   # gemma3: every Nth layer global
    parallel_block: bool = False            # command-r: attn+FFN in parallel
    logit_softcap: Optional[float] = None

    # ---- mlp ----
    mlp_type: str = "swiglu"                # swiglu | relu2 | gelu
    tie_embeddings: bool = False

    # ---- MoE ----
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None          # expert hidden dim
    capacity_factor: float = 1.25

    # ---- SSM / hybrid (zamba2, rwkv6) ----
    ssm_state: int = 0                      # mamba2 N
    ssm_heads: int = 0                      # mamba2 heads (d_inner/headdim)
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0              # zamba2: shared block interval
    rwkv_head_dim: int = 64

    # ---- encoder-decoder (seamless) ----
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # ---- VLM (qwen2-vl) ----
    n_patches: int = 1024                   # precomputed patch embeddings

    # ---- norms / precision ----
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ---- runtime knobs (hillclimbed in §Perf) ----
    remat: str = "none"               # none | full | selective
    scan_layers: bool = True
    # gradient compression: cast grads to bf16 before the cross-device
    # reduction (halves DP/FSDP gradient bytes; f32 accumulation resumes
    # inside the optimizer)
    grad_compress: bool = False
    # dry-run accounting: unroll inner (seq-chunk) scans so HLO cost
    # analysis sees every iteration (cost_analysis counts loop bodies once)
    unroll_scans: bool = False
    # flash-attention tile sizes (the Pallas kernel's block shape; also the
    # jnp blocked-attention tiling). Cost compiles raise these for long
    # sequences to bound HLO size.
    attn_block_q: int = 512
    attn_block_k: int = 512

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None \
            else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def derive(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # ------------------------------------------------------------------
    # parameter count (dense formulas; MoE counts all + active separately)
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        d, L = self.d_model, self.n_layers
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.family == "ssm":                      # rwkv6: no attention
            attn = 4 * d * d + d * d // 2             # r,k,v,o + decay lora
        mlp_in = self.moe_d_ff if self.is_moe else self.d_ff
        per_expert = (3 if self.mlp_type == "swiglu" else 2) * d * mlp_in
        if self.is_moe:
            mlp = self.n_experts * per_expert + d * self.n_experts
        else:
            mlp = (3 if self.mlp_type == "swiglu" else 2) * d * self.d_ff
        dense_mlp = 0
        if self.family == "hybrid":
            # mamba2 mixer instead of attention
            d_in = self.ssm_expand * d
            attn = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        layers = L if self.family != "encdec" \
            else (self.n_enc_layers + self.n_dec_layers)
        return layers * (attn + mlp + dense_mlp + 4 * d) + emb

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        per_expert = (3 if self.mlp_type == "swiglu" else 2) * \
            self.d_model * (self.moe_d_ff or self.d_ff)
        inactive = (self.n_experts - self.experts_per_token) * per_expert
        return full - self.n_layers * inactive
