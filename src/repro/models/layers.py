"""Core layers: norms, RoPE / M-RoPE, GQA attention (train + KV-cache
decode, sliding-window and local:global variants), MLP variants.

All functions are pure; parameters come in as pytrees built from
:mod:`repro.models.params` specs. Activation sharding is annotated with
logical axes via ``shard_constraint`` so one :class:`MeshPolicy` governs the
whole network.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..parallel.sharding import MeshPolicy, shard_constraint
from .config import ModelConfig
from .params import ParamSpec

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def norm_specs(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = d or cfg.d_model
    s = {"scale": ParamSpec((d,), ("embed",), "zeros")}
    if cfg.norm == "layernorm":
        s = {"scale": ParamSpec((d,), ("embed",), "ones"),
             "bias": ParamSpec((d,), ("embed",), "zeros")}
    return s


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): positions3 [B, S, 3] = (t, h, w) ids;
    the rotary half-dim is split into `sections` (t/h/w bands), each band
    rotated by its own position stream."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    # band assignment for every frequency index
    bounds = jnp.cumsum(jnp.asarray(sections))          # e.g. [16, 40, 64]
    idx = jnp.arange(hd // 2)
    band = jnp.searchsorted(bounds, idx, side="right")  # 0,1,2
    band = jnp.clip(band, 0, positions3.shape[-1] - 1)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(band, positions3.shape[:2] + (hd // 2,)),
        axis=-1)                                        # [B,S,hd/2]
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd, nh, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    s = {
        "wq": ParamSpec((d, nh, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((nh, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((nh, hd), ("heads", "head_dim"), "zeros")
        s["bk"] = ParamSpec((nkv, hd), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = ParamSpec((nkv, hd), ("kv_heads", "head_dim"), "zeros")
    return s


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
          softcap: Optional[float]) -> jax.Array:
    """q [B,Sq,H,hd], k/v [B,Sk,KV,hd] with H = KV*G. Returns [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      is_global: Any = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      block_q: int = 512, block_k: int = 512,
                      unroll: bool = False) -> jax.Array:
    """Causal attention without materializing the [Sq, Sk] matrix
    (flash-attention algorithm in pure jnp; the oracle for
    ``kernels/flash_attention``).

    Outer python loop over query blocks; inner scan over key blocks with an
    online softmax (running max + denominator). Key blocks that are fully
    masked (beyond the causal frontier, or — for STATIC local layers —
    outside the sliding window) are skipped entirely, so sliding-window
    archs get their S*W FLOPs instead of S^2. A traced `is_global` (gemma3
    scan) disables the window skip and applies the mask dynamically.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq, nk = S // bq, S // bk
    static_local = isinstance(is_global, bool) and not is_global \
        and window is not None
    qg = q.reshape(B, nq, bq, KV, G, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    out_blocks = []
    for qi in range(nq):
        # keep blocks in the input dtype (bf16): f32 casts of whole q/k/v
        # force XLA's SPMD solver into full-batch all-gathers; the matmuls
        # accumulate in f32 via preferred_element_type regardless
        qb = qg[:, qi]                                   # [B,bq,KV,G,hd]
        lo = 0
        hi = ((qi + 1) * bq + bk - 1) // bk              # causal frontier
        if static_local:
            lo = max(0, (qi * bq - (window - 1)) // bk)
        m = jnp.full((B, KV, G, bq), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, KV, G, bq), jnp.float32)
        acc = jnp.zeros((B, KV, G, bq, hd), jnp.float32)

        def kv_step(carry, ki):
            m0, l0, a0 = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, 1)
            s_ = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb,
                            preferred_element_type=jnp.float32) * scale
            if softcap:
                s_ = softcap * jnp.tanh(s_ / softcap)
            qpos = qi * bq + jnp.arange(bq)[:, None]
            kpos = ki * bk + jnp.arange(bk)[None, :]
            mask = kpos <= qpos
            if window is not None:
                wmask = kpos > qpos - window
                if isinstance(is_global, bool):
                    if not is_global:
                        mask = mask & wmask
                else:
                    mask = mask & jnp.where(is_global, True, wmask)
            s_ = jnp.where(mask[None, None, None], s_, -jnp.inf)
            m1 = jnp.maximum(m0, s_.max(-1))
            # guard fully-masked rows (m1 = -inf)
            m1s = jnp.where(jnp.isfinite(m1), m1, 0.0)
            p = jnp.exp(s_ - m1s[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m0), jnp.exp(m0 - m1s), 0.0)
            l1 = l0 * corr + p.sum(-1)
            a1 = a0 * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m1, l1, a1), None

        kis = jnp.arange(lo, hi)
        if unroll or len(kis) <= 1:
            carry = (m, l, acc)
            for ki in range(lo, hi):
                carry, _ = kv_step(carry, ki)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m, l, acc), kis)
        ob = acc / jnp.maximum(l, 1e-30)[..., None]      # [B,KV,G,bq,hd]
        out_blocks.append(ob.transpose(0, 3, 1, 2, 4))  # [B,bq,KV,G,hd]
    out = jnp.concatenate(out_blocks, axis=1)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def causal_mask(Sq: int, Sk: int, *, window: Optional[int] = None,
                offset: int = 0) -> jax.Array:
    """[1, Sq, Sk] causal (+sliding-window) mask. `offset` = absolute
    position of query 0 (for decode, offset = cache length)."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None]


def attention_block(p: Dict[str, Any], x: jax.Array, *, cfg: ModelConfig,
                    positions: jax.Array, policy: MeshPolicy,
                    mesh: Optional[Mesh] = None,
                    is_global: Any = True,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    cache_index: Optional[jax.Array] = None,
                    use_pallas: bool = False
                    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """GQA attention. Train/prefill when `cache` is None or being filled;
    decode (Sq=1) updates `cache` at `cache_index` and attends to the whole
    cache. `is_global` may be a traced bool (scan over mixed local/global
    layers, gemma3): local layers apply the sliding-window mask.
    """
    B, Sq, d = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        pos1d = positions[..., 0]
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        pos1d = positions
    q = shard_constraint(q, ("batch", "seq", "heads", None), policy, mesh)
    k = shard_constraint(k, ("batch", "kv_seq", "kv_heads", None), policy,
                         mesh)

    window = cfg.sliding_window
    new_cache = cache
    if cache is not None and cache_index is not None:
        # decode: write k/v at cache_index, attend over the cache
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(
            cache["k"].dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(
            cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": ck, "v": cv}
        Sk = ck.shape[1]
        kpos = jnp.arange(Sk)[None, :]
        valid = kpos <= cache_index                     # causal over cache
        wmask = jnp.where(jnp.asarray(is_global),
                          jnp.ones((1, Sk), bool),
                          kpos > cache_index - (window or Sk))
        mask = (valid & wmask)[:, None, :]              # [1,1,Sk]
        out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype),
                    jnp.broadcast_to(mask, (B, Sq, Sk)), cfg.logit_softcap)
    else:
        if use_pallas:
            from ..kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(
                q, k, v, causal=True,
                window=None if (is_global is True) else window,
                softcap=cfg.logit_softcap)
        elif Sq >= 1024:
            # blocked online-softmax: never materializes [Sq,Sk] (memory
            # roofline) and skips out-of-window blocks for static-local
            # layers (compute roofline for SWA archs)
            out = blocked_attention(q, k, v, is_global=is_global,
                                    window=window,
                                    softcap=cfg.logit_softcap,
                                    block_q=cfg.attn_block_q,
                                    block_k=cfg.attn_block_k,
                                    unroll=cfg.unroll_scans)
        else:
            full = causal_mask(Sq, Sq)
            local = causal_mask(Sq, Sq, window=window)
            mask = jnp.where(jnp.asarray(is_global), full, local)
            out = _sdpa(q, k, v, jnp.broadcast_to(mask, (B, Sq, Sq)),
                        cfg.logit_softcap)
        if cache is not None:                            # prefill fills cache
            pad = cache["k"].shape[1] - Sq
            ck = jnp.pad(k.astype(cache["k"].dtype),
                         ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = jnp.pad(v.astype(cache["v"].dtype),
                         ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_cache = {"k": ck, "v": cv}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    y = shard_constraint(y, ("batch", "seq", "act_embed"), policy, mesh)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None
              ) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_type == "swiglu":
        return {"wi": ParamSpec((d, f), ("embed", "mlp")),
                "wg": ParamSpec((d, f), ("embed", "mlp")),
                "wo": ParamSpec((f, d), ("mlp", "embed"))}
    return {"wi": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed"))}


def mlp_block(p: Dict[str, Any], x: jax.Array, *, cfg: ModelConfig,
              policy: MeshPolicy, mesh: Optional[Mesh] = None) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    elif cfg.mlp_type == "relu2":                     # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(x @ p["wi"].astype(dt)))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    h = shard_constraint(h, ("batch", "seq", "mlp"), policy, mesh)
    y = h @ p["wo"].astype(dt)
    return shard_constraint(y, ("batch", "seq", "act_embed"), policy, mesh)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model),
                          ("vocab", "embed"), "normal", 1.0)}
    if not cfg.tie_embeddings:
        s["head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                              ("embed", "vocab"))
    return s


def embed(p: Dict[str, Any], tokens: jax.Array, *, policy: MeshPolicy,
          mesh: Optional[Mesh] = None, dtype=jnp.bfloat16) -> jax.Array:
    x = jnp.take(p["tok"].astype(dtype), tokens, axis=0)
    return shard_constraint(x, ("batch", "seq", "act_embed"), policy, mesh)


def lm_head(p: Dict[str, Any], x: jax.Array, *, policy: MeshPolicy,
            mesh: Optional[Mesh] = None) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return shard_constraint(logits, ("batch", "seq", "vocab"), policy, mesh)
