"""Mamba2 (state-space dual / SSD) mixer — the zamba2 backbone block.

Chunked SSD algorithm (the TPU-friendly formulation; also the spec for the
``kernels/mamba2_ssd`` Pallas kernel):

  within a chunk of length Q the output is an attention-like quadratic form
  masked by cumulative decays; across chunks a recurrent state
  ``h [B, H, hd, N]`` carries the summary. Decode is a single-step state
  update (constant memory — why SSM archs run the long_500k cell).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshPolicy, shard_constraint
from .config import ModelConfig
from .params import ParamSpec


def mamba2_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_heads or max(1, d_in // 64)
    N = cfg.ssm_state
    return {
        # in_proj output width (2*d_in + 2N + H) is generally not divisible
        # by the model axis -> kept replicated on that dim; the out_proj
        # carries the TP sharding for this mixer
        "in_proj": ParamSpec((d, 2 * d_in + 2 * N + H), ("embed", None)),
        "conv": ParamSpec((cfg.ssm_conv, d_in + 2 * N), ("conv", None)),
        "A_log": ParamSpec((H,), (None,), "ones"),
        "D": ParamSpec((H,), (None,), "ones"),
        "dt_bias": ParamSpec((H,), (None,), "zeros"),
        "norm": ParamSpec((d_in,), ("mlp",), "zeros"),
        "out_proj": ParamSpec((d_in, d), ("mlp", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array
                ) -> Tuple[jax.Array, ...]:
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_in // 64)
    N = cfg.ssm_state
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    x, Bc, Cc = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    return z, x, Bc, Cc, dt


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x [B,S,D]; w [K,D]. Returns (y, new_state)
    where state is the last K-1 inputs (decode carry)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(y), xp[:, -(K - 1):, :]


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
                Cc: jax.Array, *, chunk: int = 128,
                h0: Optional[jax.Array] = None, unroll: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. x [B,S,H,hd]; dt [B,S,H] (softplus'd); A [H] (negative);
    Bc/Cc [B,S,N]. Returns (y [B,S,H,hd], h [B,H,hd,N])."""
    B, S, H, hd = x.shape
    N = Bc.shape[-1]
    nc = max(1, S // chunk)
    Q = S // nc
    xr = x.reshape(B, nc, Q, H, hd)
    dtr = dt.reshape(B, nc, Q, H)
    Br = Bc.reshape(B, nc, Q, N)
    Cr = Cc.reshape(B, nc, Q, N)
    if h0 is None:
        h0 = jnp.zeros((B, H, hd, N), jnp.float32)
    h0 = h0.astype(jnp.float32)

    la = dtr * A[None, None, None, :]                  # log decay per step
    cum = jnp.cumsum(la, axis=2)                       # [B,nc,Q,H]

    def body(h, inputs):
        xq, dtq, bq, cq, laq, cumq = inputs            # per-chunk slices
        # intra-chunk quadratic form: M[t,s] = C_t.B_s * exp(cum_t - cum_s)
        # * dt_s   for s <= t
        cb = jnp.einsum("bqn,bsn->bqs", cq, bq,
                        preferred_element_type=jnp.float32)  # [B,Q,Q]
        seg = cumq[:, :, None, :] - cumq[:, None, :, :]      # [B,Q,S,H]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        # mask BEFORE exp: discarded (future) entries carry positive
        # exponents that overflow, and where(c, exp(x), 0) back-propagates
        # inf * 0 = NaN through the discarded branch
        seg = jnp.where(tri[None, :, :, None], seg, -jnp.inf)
        decay = jnp.exp(seg)
        M = cb[..., None] * decay * dtq[:, None, :, :]       # [B,Q,S,H]
        y_intra = jnp.einsum("bqsh,bshp->bqhp", M,
                             xq.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        state_decay = jnp.exp(cumq)                          # [B,Q,H]
        y_state = jnp.einsum("bqn,bhpn,bqh->bqhp", cq.astype(jnp.float32),
                             h, state_decay)
        # state update
        rem = jnp.exp(cumq[:, -1:, :] - cumq)                # [B,Q,H]
        dx = xq.astype(jnp.float32) * (dtq * rem)[..., None]
        h_new = h * jnp.exp(cumq[:, -1, :])[:, :, None, None] + \
            jnp.einsum("bqhp,bqn->bhpn", dx, bq.astype(jnp.float32))
        return h_new, (y_intra + y_state).astype(x.dtype)

    ins = (jnp.moveaxis(xr, 1, 0), jnp.moveaxis(dtr, 1, 0),
           jnp.moveaxis(Br, 1, 0), jnp.moveaxis(Cr, 1, 0),
           jnp.moveaxis(la.reshape(B, nc, Q, H), 1, 0),
           jnp.moveaxis(cum, 1, 0))
    h, ys = jax.lax.scan(body, h0, ins, unroll=nc if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    return y, h


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bc: jax.Array, Cc: jax.Array, h: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token state update. x [B,1,H,hd]; h [B,H,hd,N]."""
    a = jnp.exp(dt[:, 0, :] * A[None, :])              # [B,H]
    hf = h * a[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", x[:, 0].astype(jnp.float32),
        Bc[:, 0].astype(jnp.float32), dt[:, 0])
    y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), hf)
    return y[:, None].astype(x.dtype), hf


def mamba2_block(p: Dict[str, Any], x: jax.Array, *, cfg: ModelConfig,
                 policy: MeshPolicy, mesh=None,
                 state: Optional[Dict[str, jax.Array]] = None,
                 decode: bool = False, use_pallas: bool = False
                 ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full mixer: in_proj -> causal conv -> SSD -> gated RMSNorm ->
    out_proj. `state` = {"h": [B,H,hd,N], "conv": [B,K-1,D]} for decode."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_heads or max(1, d_in // 64)
    hd = d_in // H
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xi, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv"].astype(x.dtype), conv_state)
    xi, Bc, Cc = jnp.split(conv_out, [d_in, d_in + cfg.ssm_state], axis=-1)
    dtp = jax.nn.softplus(dt + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, S, H, hd)
    h0 = state["h"] if state is not None else None
    if decode:
        y, h = ssd_decode_step(xh, dtp, A, Bc, Cc,
                               h0 if h0 is not None else
                               jnp.zeros((B, H, hd, N1 := cfg.ssm_state),
                                         jnp.float32))
    elif use_pallas:
        from ..kernels.mamba2_ssd import ops as ssd_ops
        y, h = ssd_ops.ssd(xh, dtp, A, Bc, Cc, h0=h0)
    else:
        y, h = ssd_chunked(xh, dtp, A, Bc, Cc, h0=h0,
                           unroll=cfg.unroll_scans)
    y = y + xh.astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)
    from .layers import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(y.dtype)
    out = shard_constraint(out, ("batch", "seq", "act_embed"), policy, mesh)
    new_state = {"h": h, "conv": new_conv} if (state is not None or decode) \
        else None
    return out, new_state
