"""RWKV-6 "Finch" block (attention-free; data-dependent decay).

Recurrence (per head; k,r,w in R^hd, v in R^hd):

    y_t = r_t · S_{t-1} + (r_t ⊙ u ⊙ k_t) · 1 * v_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with w_t = exp(-exp(w0 + LoRA(x_t))) data-dependent per channel. The chunked
form (also the spec for ``kernels/rwkv6_scan``) rewrites the intra-chunk
part as a [Q,Q] quadratic form over decay-normalized keys/receptances, and
carries S across chunks. Decode is a single-step state update.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshPolicy, shard_constraint
from .config import ModelConfig
from .params import ParamSpec


def rwkv6_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    lora = 64
    return {
        "att": {
            "mu": ParamSpec((5, d), (None, "embed"), "zeros"),   # r,k,v,w,g
            "wr": ParamSpec((d, d), ("embed", "heads_flat")),
            "wk": ParamSpec((d, d), ("embed", "heads_flat")),
            "wv": ParamSpec((d, d), ("embed", "heads_flat")),
            "wg": ParamSpec((d, d), ("embed", "heads_flat")),
            "wo": ParamSpec((d, d), ("heads_flat", "embed")),
            "w0": ParamSpec((d,), ("heads_flat",), "zeros"),
            "w_lora_a": ParamSpec((d, lora), ("embed", None)),
            "w_lora_b": ParamSpec((lora, d), (None, "heads_flat")),
            "u": ParamSpec((d,), ("heads_flat",), "zeros"),
            "ln_x": ParamSpec((d,), ("heads_flat",), "zeros"),
        },
        "ffn": {
            "mu": ParamSpec((2, d), (None, "embed"), "zeros"),   # k,r
            "wk": ParamSpec((d, f), ("embed", "mlp")),
            "wv": ParamSpec((f, d), ("mlp", "embed")),
            "wr": ParamSpec((d, d), ("embed", None)),
        },
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]
                 ) -> Tuple[jax.Array, jax.Array]:
    """x_{t-1} stream; `prev` is the last token of the previous segment
    (decode carry). Returns (shifted, new_prev)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    shifted = jnp.concatenate([prev, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, *, chunk: int = 64,
                 s0: Optional[jax.Array] = None, unroll: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    """r/k/v/w: [B,S,H,hd] (w = per-step decay in (0,1)); u: [H,hd].
    Returns (y [B,S,H,hd], S [B,H,hd,hd])."""
    B, S, H, hd = r.shape
    nc = max(1, S // chunk)
    Q = S // nc
    rr = r.reshape(B, nc, Q, H, hd)
    kk = k.reshape(B, nc, Q, H, hd)
    vv = v.reshape(B, nc, Q, H, hd)
    # clamp: strong data-dependent decay underflows w to 0 in f32 (and
    # 1e-38 is denormal -> flushed to 0 on TPU); -60 per step keeps all
    # chunk-cumulative exponents finite while exp() underflows cleanly
    lw = jnp.maximum(jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30)),
                     -60.0).reshape(B, nc, Q, H, hd)
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def body(s, inp):
        rq, kq, vq, lwq = inp
        cum = jnp.cumsum(lwq, axis=1)                  # [B,Q,H,hd]
        # intra-chunk: y_t += sum_{s<t} (r_t . prod_{j=s+1..t-1} w_j . k_s) v_s
        # The pairwise exponent cum_{t-1} - cum_s is <= 0 for every VALID
        # (s < t) pair, so masking BEFORE exponentiation is numerically
        # safe for arbitrary data-dependent decays (separate exp(±cum)
        # factorization overflows for strong decay).
        cum_prev = cum - lwq                           # cum_{t-1}
        seg = cum_prev[:, :, None] - cum[:, None]      # [B,Q,S,H,hd]
        tri = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        seg = jnp.where(tri[None, :, :, None, None], seg, -jnp.inf)
        att = jnp.einsum("bqhc,bshc,bqshc->bhqs",
                         rq.astype(jnp.float32), kq.astype(jnp.float32),
                         jnp.exp(seg))
        # carried-state receptance (exponent cum_{t-1} <= 0: safe)
        r_n = rq.astype(jnp.float32) * jnp.exp(cum_prev)
        # diagonal (s == t) uses the bonus u
        diag = jnp.einsum("bqhc,bqhc->bqh",
                          rq.astype(jnp.float32) * u[None, None],
                          kq.astype(jnp.float32))
        y = jnp.einsum("bhqs,bshd->bqhd", att, vv_f := vq.astype(jnp.float32))
        y += diag[..., None] * vv_f
        # contribution of the carried state
        y += jnp.einsum("bqhc,bhcd->bqhd", r_n, s)
        # state update: S' = diag(prod w) S + sum_s (k_s exp(cum_Q - cum_s)) v_s
        k_end = kq.astype(jnp.float32) * jnp.exp(cum[:, -1:, :, :] - cum)
        s_new = s * jnp.exp(cum[:, -1])[..., None] + \
            jnp.einsum("bshc,bshd->bhcd", k_end, vv_f)
        return s_new, y

    ins = (jnp.moveaxis(rr, 1, 0), jnp.moveaxis(kk, 1, 0),
           jnp.moveaxis(vv, 1, 0), jnp.moveaxis(lw, 1, 0))
    s, ys = jax.lax.scan(body, s0, ins, unroll=nc if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    return y.astype(r.dtype), s


def wkv6_step(r, k, v, w, u, s):
    """Single decode step. r/k/v/w: [B,1,H,hd]; s: [B,H,hd,hd]."""
    rf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
    wf = w[:, 0].astype(jnp.float32)
    y = jnp.einsum("bhc,bhcd->bhd", rf, s) + \
        jnp.einsum("bhc,bhc,bhd->bhd", rf * u[None], kf, vf)
    s_new = s * wf[..., None] + jnp.einsum("bhc,bhd->bhcd", kf, vf)
    return y[:, None].astype(r.dtype), s_new


def rwkv6_att(p: Dict[str, Any], x: jax.Array, *, cfg: ModelConfig,
              policy: MeshPolicy, mesh=None,
              state: Optional[Dict[str, jax.Array]] = None,
              decode: bool = False, use_pallas: bool = False
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    prev = state["shift_a"] if state is not None else None
    xs, new_prev = _token_shift(x, prev)
    dt = x.dtype
    mu = p["mu"].astype(dt)                              # [5, d]
    mix = [x + (xs - x) * mu[i] for i in range(5)]
    r = (mix[0] @ p["wr"].astype(dt)).reshape(B, S, H, hd)
    k = (mix[1] @ p["wk"].astype(dt)).reshape(B, S, H, hd)
    v = (mix[2] @ p["wv"].astype(dt)).reshape(B, S, H, hd)
    g = jax.nn.silu(mix[4] @ p["wg"].astype(dt))
    wlog = p["w0"].astype(jnp.float32) + \
        ((mix[3] @ p["w_lora_a"].astype(dt)) @
         p["w_lora_b"].astype(dt)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32))).reshape(B, S, H, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)
    s0 = state["wkv"] if state is not None else None
    if decode:
        y, s = wkv6_step(r, k, v, w, u,
                         s0 if s0 is not None else
                         jnp.zeros((B, H, hd, hd), jnp.float32))
    elif use_pallas:
        from ..kernels.rwkv6_scan import ops as wkv_ops
        y, s = wkv_ops.wkv6(r, k, v, w, u, s0=s0)
    else:
        y, s = wkv6_chunked(r, k, v, w, u, s0=s0,
                            unroll=cfg.unroll_scans)
    from .layers import rmsnorm
    y = rmsnorm(y.reshape(B, S, d), p["ln_x"], cfg.norm_eps) * g
    out = y.astype(dt) @ p["wo"].astype(dt)
    out = shard_constraint(out, ("batch", "seq", "act_embed"), policy, mesh)
    new_state = None
    if state is not None or decode:
        new_state = {"wkv": s, "shift_a": new_prev}
    return out, new_state


def rwkv6_ffn(p: Dict[str, Any], x: jax.Array, *, cfg: ModelConfig,
              policy: MeshPolicy, mesh=None,
              state: Optional[Dict[str, jax.Array]] = None
              ) -> Tuple[jax.Array, Optional[jax.Array]]:
    prev = state["shift_f"] if state is not None else None
    xs, new_prev = _token_shift(x, prev)
    dt = x.dtype
    mu = p["mu"].astype(dt)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    kk = shard_constraint(kk, ("batch", "seq", "mlp"), policy, mesh)
    y = (kk @ p["wv"].astype(dt)) * jax.nn.sigmoid(xr @ p["wr"].astype(dt))
    return shard_constraint(y, ("batch", "seq", "act_embed"), policy, mesh), \
        new_prev
