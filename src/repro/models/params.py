"""Parameter trees: shapes + logical axes + initialization.

Models declare a nested dict of :class:`ParamSpec` (shape, logical axes,
init law). From one spec tree we derive:

  * ``init_params``     — materialized arrays (smoke tests / examples)
  * ``abstract_params`` — ShapeDtypeStruct stand-ins (dry-run: no allocation)
  * ``axes_tree``       — logical-axes pytree -> PartitionSpecs via
                          :mod:`repro.parallel.sharding`
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Any, key: jax.Array, dtype: Any = jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / math.sqrt(max(1, fan_in))
            out.append((jax.random.normal(k, s.shape) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs: Any, dtype: Any = jnp.float32) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs, is_leaf=_is_spec)


def axes_tree(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def count_params(specs: Any) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=_is_spec):
        total += int(np.prod(s.shape))
    return total


def param_bytes(specs: Any, bytes_per_param: int = 4) -> int:
    return count_params(specs) * bytes_per_param
