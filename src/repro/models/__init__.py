from .config import ModelConfig
from .lm import (forward, init_cache_specs, layer_flags, loss_fn,
                 param_specs)
from .params import (ParamSpec, abstract_params, axes_tree, count_params,
                     init_params, param_bytes)
