from .sharding import (LOGICAL_RULES, MeshPolicy, logical_to_pspec,
                       shard_constraint, param_pspecs)
