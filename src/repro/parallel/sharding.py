"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP over one mesh).

Every parameter and activation in the model zoo carries *logical* axis names
(("vocab", "embed"), ("batch", "seq", "embed"), ...). A :class:`MeshPolicy`
maps logical names to mesh axes:

  batch        -> ("pod", "data")     data parallelism (pods are the slow,
                                      DCN-linked outer axis: only gradient
                                      all-reduce crosses pods)
  heads/mlp/experts/vocab -> "model"  tensor / expert parallelism
  embed        -> "data" (fsdp=True)  ZeRO-3 parameter sharding
  kv_seq       -> "data" (seq_shard)  long-context KV caches (batch=1 cells)

The model code never mentions mesh axes; swapping policies re-shards the
whole system (this is what the §Perf hillclimb iterates on).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# default logical->mesh rules (single- and multi-pod; missing axes are
# silently dropped by PartitionSpec when the mesh lacks them)
LOGICAL_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,                 # activations keep sequence unsharded (TP)
    "kv_seq": None,              # overridden by seq_shard policies
    "embed": None,               # PARAM hidden dim (fsdp shards it)
    "act_embed": None,           # ACTIVATION hidden dim: never sharded
                                 # by fsdp (fsdp is a weights-only policy)
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",       # rwkv: flattened H*hd projection dim
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "vocab": "model",
    "layers": None,
    "state": None,
    "conv": None,
    "frames": None,
    "cap": None,
}


@dataclass(frozen=True)
class MeshPolicy:
    """Sharding policy: logical rules + toggles.

    fsdp      — shard parameter "embed" dims over `data` (ZeRO-3).
    seq_shard — shard KV caches' "kv_seq" over `data` (long-context decode).
    rules     — overrides merged over LOGICAL_RULES.
    """
    fsdp: bool = False
    seq_shard: bool = False
    rules: Tuple[Tuple[str, Any], ...] = ()

    def resolve(self) -> Dict[str, Any]:
        r = dict(LOGICAL_RULES)
        if self.fsdp:
            r["embed"] = "data"
        if self.seq_shard:
            r["kv_seq"] = "data"
        r.update(dict(self.rules))
        return r

    def with_rules(self, **kw: Any) -> "MeshPolicy":
        return replace(self, rules=self.rules + tuple(kw.items()))


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def logical_to_pspec(axes: Sequence[Optional[str]], policy: MeshPolicy,
                     mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec under `policy`,
    dropping mesh axes that don't exist in `mesh` (lets one policy serve
    single-pod and multi-pod meshes)."""
    rules = policy.resolve()
    present = set(_mesh_axes(mesh)) if mesh is not None else None
    out = []
    used: set = set()
    for ax in axes:
        if ax is None:
            out.append(None)
            continue
        m = rules.get(ax)
        if m is None:
            out.append(None)
            continue
        if isinstance(m, (tuple, list)):
            ms = tuple(x for x in m
                       if (present is None or x in present) and x not in used)
            used.update(ms)
            out.append(ms if ms else None)
        else:
            if (present is not None and m not in present) or m in used:
                out.append(None)
            else:
                used.add(m)
                out.append(m)
    return P(*out)


def shard_constraint(x: jax.Array, axes: Sequence[Optional[str]],
                     policy: MeshPolicy, mesh: Optional[Mesh] = None
                     ) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, logical_to_pspec(axes, policy, mesh))
    except (ValueError, RuntimeError):
        return x


def param_pspecs(axes_tree: Any, policy: MeshPolicy,
                 mesh: Optional[Mesh] = None) -> Any:
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_pspec(axes, policy, mesh),
        axes_tree, is_leaf=lambda l: isinstance(l, tuple) and
        all(isinstance(a, (str, type(None))) for a in l))


def named_shardings(axes_tree: Any, policy: MeshPolicy, mesh: Mesh) -> Any:
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                        param_pspecs(axes_tree, policy, mesh))
