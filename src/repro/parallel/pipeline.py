"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Offered as a config option for pods where a `stage` mesh axis is
preferable to deeper FSDP (e.g. cross-pod DCN too slow for per-layer param
all-gathers). The schedule is the classic GPipe 1F1B-ish loop expressed
with `jax.lax.ppermute`: microbatch activations rotate through stages;
each stage applies its local layer block.

The 40-cell dry-run baseline uses DP×FSDP×TP (dominant on a 16×16 ICI
mesh); this module is exercised by tests/test_pipeline.py and available as
`MeshPolicy` + `pipeline_apply` for stage-sharded deployments.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(layer_fn: Callable[[Any, jax.Array], jax.Array],
                   stacked_params: Any, x: jax.Array, *, mesh: Mesh,
                   stage_axis: str = "stage",
                   n_microbatches: int = None) -> jax.Array:
    """Run `x` through `n_stages * layers_per_stage` layers, stages sharded
    over `stage_axis`.

    stacked_params: pytree with leading [n_stages, layers_per_stage, ...]
    x: [n_microbatches, mb, ...] microbatched activations.

    Schedule (GPipe): T = n_micro + n_stages - 1 ticks; at tick t, stage s
    processes microbatch (t - s) if 0 <= t - s < n_micro. Activations hop
    stage->stage+1 via ppermute; bubbles are masked compute (charged in the
    roofline as the (S-1)/(M+S-1) bubble fraction).
    """
    S = mesh.shape[stage_axis]
    M = x.shape[0] if n_microbatches is None else n_microbatches

    p_spec = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    x_spec = P(None)          # microbatches replicated; stages gate by id

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(p_spec, x_spec), out_specs=x_spec, check_rep=False)
    def run(params_local, xs):
        # params_local: [1, layers_per_stage, ...] (this stage's block)
        params_me = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        n_stages = S          # static mesh extent (jax.lax.axis_size is
                              # not available on older jax releases)
        T = M + S - 1
        buf = jnp.zeros_like(xs[0])          # activation entering this stage
        outs = jnp.zeros_like(xs)

        def stage_block(p, h):
            def body(carry, lp):
                return layer_fn(lp, carry), None
            out, _ = jax.lax.scan(body, h, p)
            return out

        def tick(carry, t):
            buf, outs = carry
            mb = t - sid                      # microbatch at this stage
            active = (mb >= 0) & (mb < M)
            # stage 0 ingests a fresh microbatch from xs
            feed = jnp.where(sid == 0,
                             xs[jnp.clip(t, 0, M - 1)], buf)
            h = stage_block(params_me, feed)
            h = jnp.where(active, h, feed)
            # last stage emits; others forward
            out_mb = jnp.clip(mb, 0, M - 1)
            emit = active & (sid == n_stages - 1)
            outs = jnp.where(
                emit,
                outs.at[out_mb].set(h), outs)
            nxt = jax.lax.ppermute(
                h, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
        # all stages computed `outs` divergently; the true values live on
        # the last stage: broadcast them
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    return run(stacked_params, x)


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
