from .service import MetadataPlane, CheckpointManifest
