"""The training framework's metadata plane = HopsFS consumed as a library.

This is the paper's technique integrated as a first-class feature: the
cluster's checkpoint manifests, dataset registry, and job ledger live in the
HopsFS namespace (hierarchical, partitioned by parent — so listing one
step's shards is a single partition-pruned scan) served by stateless
namenodes with transparent failover.

Namespace layout:

    /jobs/<job>/ledger/step-<n>           (job progress rows)
    /ckpt/<job>/step-<n>/<param-path>.shard-<k>    (one file per tensor shard)
    /data/<dataset>/shard-<k>             (input shards; straggler
                                           re-dispatch bookkeeping)

At 512 chips, one nemotron-340B checkpoint writes ~360 param leaves x 512
shards ~ O(10^5) manifest rows; at 1000+ nodes with frequent checkpoints
the single-coordinator design (= HDFS' single NN) saturates exactly as the
paper describes — the scale-out metadata plane is what keeps checkpoint
commit latency flat (benchmarks/bench_ckpt_metadata.py measures this).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from ..core import (Client, FileNotFound, MetadataStore, NamenodeCluster,
                    format_fs)


@dataclass
class CheckpointManifest:
    job: str
    step: int
    shards: Dict[str, List[int]]        # param path -> shard ids present
    complete: bool = False


class MetadataPlane:
    """Checkpoint/data/job metadata on a HopsFS cluster."""

    def __init__(self, *, n_namenodes: int = 3, n_ndb: int = 4,
                 store: Optional[MetadataStore] = None):
        self.store = store or MetadataStore(n_datanodes=n_ndb)
        if self.store.table("inode").n_rows == 0:
            format_fs(self.store)
        self.cluster = NamenodeCluster(self.store, n_namenodes)
        self.client = Client(self.cluster, policy="sticky")
        for root in ("/jobs", "/ckpt", "/data"):
            self._mkdirs(root)

    # -- namespace helpers ------------------------------------------------
    def _mkdirs(self, path: str) -> None:
        self.client.execute("mkdirs", path)

    def tick(self) -> None:
        self.cluster.tick()

    # -- job ledger ---------------------------------------------------------
    def open_job(self, job: str) -> None:
        self._mkdirs(f"/jobs/{job}/ledger")
        self._mkdirs(f"/ckpt/{job}")

    def record_step(self, job: str, step: int, *, loss: float) -> None:
        self.client.execute("create", f"/jobs/{job}/ledger/step-{step:08d}")

    def last_step(self, job: str) -> Optional[int]:
        names = self.client.execute("ls", f"/jobs/{job}/ledger").value
        steps = sorted(int(n.split("-")[1]) for n in names
                       if n.startswith("step-"))
        return steps[-1] if steps else None

    # -- checkpoint manifests ------------------------------------------------
    def begin_checkpoint(self, job: str, step: int) -> str:
        base = f"/ckpt/{job}/step-{step:08d}.tmp"
        self._mkdirs(base)
        return base

    def add_shard(self, base: str, param_path: str, shard: int) -> None:
        name = param_path.replace("/", "~")
        self.client.execute("create", f"{base}/{name}.shard-{shard:05d}")

    def commit_checkpoint(self, job: str, step: int) -> None:
        """Atomic rename .tmp -> committed (the paper's subtree rename:
        one phase-3 transaction on the root, inner inodes untouched)."""
        src = f"/ckpt/{job}/step-{step:08d}.tmp"
        dst = f"/ckpt/{job}/step-{step:08d}"
        self.client.execute("rename_subtree", src, dst)

    def manifest(self, job: str, step: int) -> CheckpointManifest:
        base = f"/ckpt/{job}/step-{step:08d}"
        try:
            names = self.client.execute("ls", base).value
        except FileNotFound:
            return CheckpointManifest(job, step, {}, complete=False)
        shards: Dict[str, List[int]] = {}
        for n in names:
            if ".shard-" not in n:
                continue
            p, s = n.rsplit(".shard-", 1)
            shards.setdefault(p.replace("~", "/"), []).append(int(s))
        return CheckpointManifest(job, step, shards, complete=bool(shards))

    def latest_checkpoint(self, job: str) -> Optional[int]:
        names = self.client.execute("ls", f"/ckpt/{job}").value
        steps = [int(n.split("-")[1]) for n in names
                 if n.startswith("step-") and not n.endswith(".tmp")]
        return max(steps) if steps else None

    def gc_checkpoint(self, job: str, step: int) -> int:
        """Delete an old checkpoint tree (subtree-op protocol; batched
        post-order; crash-safe per §6.2)."""
        res = self.client.execute("delete_subtree",
                                  f"/ckpt/{job}/step-{step:08d}")
        return res.value["deleted"]

    # -- dataset registry ------------------------------------------------------
    def register_dataset(self, name: str, n_shards: int) -> None:
        self._mkdirs(f"/data/{name}")
        for k in range(n_shards):
            self.client.execute("create", f"/data/{name}/shard-{k:05d}")

    def dataset_shards(self, name: str) -> List[str]:
        return self.client.execute("ls", f"/data/{name}").value
