from .checkpoint import CheckpointManager
