"""Checkpoint/restart with manifests in the metadata plane.

Tensor shards are written per (param leaf x shard) — at scale each host
writes its local shards in parallel — and registered as rows in the HopsFS
namespace. Commit is the paper's subtree rename (atomic at the root), so a
writer crash mid-checkpoint leaves only an uncommitted ``.tmp`` tree that
the next GC sweep removes; restore always sees a complete manifest or none
(fault tolerance for 1000+ node fleets).

Async mode double-buffers: the step returns as soon as arrays are snapshot
to host memory; serialization + manifest writes happen on a worker thread.
"""
from __future__ import annotations

import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..metaplane import MetadataPlane


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, plane: MetadataPlane, job: str,
                 *, keep: int = 2, async_mode: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.plane = plane
        self.job = job
        self.keep = keep
        self.async_mode = async_mode
        self._worker: Optional[threading.Thread] = None
        plane.open_job(job)

    # ------------------------------------------------------------------
    def save(self, step: int, params: Any, opt_state: Any) -> None:
        flat = _flatten({"params": params, "opt": opt_state})
        host = {k: np.asarray(v) for k, v in flat.items()}
        if self.async_mode:
            self._join()
            self._worker = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._worker.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: Dict[str, np.ndarray]) -> None:
        base = self.plane.begin_checkpoint(self.job, step)
        step_dir = self.dir / f"step-{step:08d}"
        step_dir.mkdir(parents=True, exist_ok=True)
        for path, arr in host.items():
            fname = path.replace("/", "~") + ".shard-00000.npy"
            np.save(step_dir / fname, arr)
            self.plane.add_shard(base, path, 0)
        self.plane.commit_checkpoint(self.job, step)
        self._gc()

    def _gc(self) -> None:
        names = self.plane.client.execute("ls", f"/ckpt/{self.job}").value
        steps = sorted(int(n.split("-")[1]) for n in names
                       if n.startswith("step-") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            self.plane.gc_checkpoint(self.job, s)
            d = self.dir / f"step-{s:08d}"
            if d.exists():
                for f in d.iterdir():
                    f.unlink()
                d.rmdir()

    def _join(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    # ------------------------------------------------------------------
    def restore_latest(self) -> Optional[Tuple[int, Any, Any]]:
        self._join()
        step = self.plane.latest_checkpoint(self.job)
        if step is None:
            return None
        man = self.plane.manifest(self.job, step)
        assert man.complete, "manifest incomplete after commit"
        step_dir = self.dir / f"step-{step:08d}"
        flat = {}
        for path in man.shards:
            fname = path.replace("/", "~") + ".shard-00000.npy"
            flat[path] = np.load(step_dir / fname)
        tree = _unflatten(flat)
        return step, tree["params"], tree["opt"]
