from .optimizer import adamw_init, adamw_update, OptConfig
from .step import make_train_step, train_step_fn
