"""Train / serve step builders (the functions the launcher pjit-compiles).

``train_step_fn``   — loss + grads + AdamW update (+ optional gradient
                      accumulation over microbatches via lax.scan).
``prefill_step_fn`` — forward over a full prompt, filling the KV cache.
``decode_step_fn``  — one token against the cache (the decode_32k /
                      long_500k dry-run target).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..models import forward, loss_fn
from ..models.config import ModelConfig
from ..parallel.sharding import MeshPolicy
from .optimizer import OptConfig, adamw_update


def train_step_fn(params: Any, opt_state: Any, batch: Dict[str, jax.Array],
                  *, cfg: ModelConfig, policy: MeshPolicy,
                  mesh: Optional[Mesh] = None, opt: OptConfig = OptConfig(),
                  microbatches: int = 1, use_pallas: bool = False
                  ) -> Tuple[Any, Any, jax.Array]:
    """One optimizer step. With microbatches>1, grads accumulate over a
    lax.scan of microbatch slices (activation memory / compile-size lever
    used by the §Perf hillclimb)."""

    def lf(p, b):
        return loss_fn(p, b, cfg=cfg, policy=policy, mesh=mesh,
                       use_pallas=use_pallas)

    if microbatches <= 1:
        loss, grads = jax.value_and_grad(lf)(params, batch)
        if cfg.grad_compress:
            # bf16 on the wire (the DP/FSDP reduce-scatter happens on the
            # cast values); the optimizer re-ups to f32 for accumulation
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    else:
        B = batch["tokens"].shape[0]
        mb = B // microbatches
        sliced = jax.tree.map(
            lambda x: x.reshape((microbatches, mb) + x.shape[1:]), batch)

        def acc(carry, mbatch):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(lf)(params, mbatch)
            g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
            return (g_acc, l_acc + l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), sliced)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        loss = loss / microbatches
    new_params, new_opt = adamw_update(opt, params, grads, opt_state)
    return new_params, new_opt, loss


def make_train_step(cfg: ModelConfig, policy: MeshPolicy,
                    mesh: Optional[Mesh] = None,
                    opt: OptConfig = OptConfig(), microbatches: int = 1,
                    use_pallas: bool = False):
    return functools.partial(train_step_fn, cfg=cfg, policy=policy,
                             mesh=mesh, opt=opt, microbatches=microbatches,
                             use_pallas=use_pallas)


def prefill_step_fn(params: Any, batch: Dict[str, jax.Array], cache: Any,
                    *, cfg: ModelConfig, policy: MeshPolicy,
                    mesh: Optional[Mesh] = None, use_pallas: bool = False
                    ) -> Tuple[jax.Array, Any]:
    logits, new_cache = forward(params, batch, cfg=cfg, policy=policy,
                                mesh=mesh, cache=cache, cache_index=None,
                                use_pallas=use_pallas)
    return logits[:, -1:], new_cache


def decode_step_fn(params: Any, batch: Dict[str, jax.Array], cache: Any,
                   index: jax.Array, *, cfg: ModelConfig,
                   policy: MeshPolicy, mesh: Optional[Mesh] = None,
                   use_pallas: bool = False) -> Tuple[jax.Array, Any]:
    """`serve_step`: one new token (batch["tokens"] is [B,1]) against a KV
    cache of seq_len (decode_32k / long_500k cells)."""
    logits, new_cache = forward(params, batch, cfg=cfg, policy=policy,
                                mesh=mesh, cache=cache, cache_index=index,
                                use_pallas=use_pallas)
    return logits, new_cache


def make_decode_step(cfg: ModelConfig, policy: MeshPolicy,
                     mesh: Optional[Mesh] = None, use_pallas: bool = False):
    return functools.partial(decode_step_fn, cfg=cfg, policy=policy,
                             mesh=mesh, use_pallas=use_pallas)
