"""AdamW with fully-sharded state (moments inherit the parameters' logical
axes, so FSDP shards optimizer memory 3x alongside the weights)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def lr_at(c: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, c.warmup_steps))
    t = jnp.clip((step - c.warmup_steps) /
                 max(1, c.total_steps - c.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return c.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_abstract(params_abs: Any) -> Dict[str, Any]:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"mu": jax.tree.map(z, params_abs),
            "nu": jax.tree.map(z, params_abs),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_axes_tree(param_axes: Any) -> Dict[str, Any]:
    """Moments shard exactly like their parameters."""
    return {"mu": param_axes, "nu": param_axes, "step": ()}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(c: OptConfig, params: Any, grads: Any, state: Dict[str, Any]
                 ) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / (gnorm + 1e-9))
    lr = lr_at(c, step)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = c.b1 * mu + (1 - c.b1) * g
        nu2 = c.b2 * nu + (1 - c.b2) * jnp.square(g)
        pf = p.astype(jnp.float32)
        delta = (mu2 / b1c) / (jnp.sqrt(nu2 / b2c) + c.eps)
        pf = pf - lr * (delta + c.weight_decay * pf)
        return pf.astype(p.dtype), mu2, nu2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}
