"""HDFS-style ``DFSClient`` facade over the typed operation protocol.

The client-facing API of the reproduction: typed methods
(``mkdirs/create/open/rename/delete/stat/ls/...``) returning typed results
(:class:`FileStatus`, :class:`BlockLocation`, ...), executed through the
op registry on a fleet of stateless namenodes with the composable
middleware stack of :mod:`~repro.core.middleware`:

  * ``subtree_retry`` — ops that voluntarily abort on a live subtree lock
    (§6.3) are retried with backoff before :class:`SubtreeLockedError`
    surfaces;
  * ``failover``      — a namenode dying mid-op is transparent (§7.6.1);
  * batching          — :meth:`DFSClient.batch` defers calls and flushes
    them through :meth:`Namenode.execute_batch` (grouped path validation,
    §5.1), and :meth:`DFSClient.run_trace` drives whole traces through the
    shared-queue :class:`RequestPipeline`.

Every operation the registry knows — including ones registered after
import, see ``docs/API.md`` — is reachable via :meth:`call`; the named
methods are typed sugar over it.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Type)

from .admission import DeadlineExpired, OverloadShed
from .fs import (FSError, FileAlreadyExists, FileNotFound, LeaseConflict,
                 OpResult, SubtreeLockedError)
from .hint_cache import InodeHintCache, absorb_response
from .middleware import (CallContext, Handler, Middleware, compose, failover,
                         membership_refresh, subtree_retry, txn_retry)
from .namenode import (Client, Namenode, NamenodeCluster, PipelineStats,
                       RequestPipeline)
from .ops_registry import REGISTRY, WorkloadOp
from .store import (LockTimeout, NetworkPartition, NodeGroupDown,
                    RowNotFound, StoreError, TransactionAborted)

# ---------------------------------------------------------------------------
# typed results
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FileStatus:
    """``stat`` result — the HDFS ``FileStatus`` equivalent."""
    path: str
    inode_id: int
    is_dir: bool
    perm: int
    owner: str
    group: str
    size: int
    repl: int
    mtime: float


@dataclass(frozen=True)
class BlockLocation:
    """One block of an opened file with its replica locations."""
    block_id: int
    size: int
    datanodes: Tuple[int, ...]


@dataclass(frozen=True)
class ContentSummary:
    path: str
    children: int
    size: int


@dataclass(frozen=True)
class DeleteSummary:
    path: str
    deleted: int          # inodes removed (1 for a plain file)
    recursive: bool


@dataclass(frozen=True)
class TruncateSummary:
    path: str
    size: int
    removed_blocks: int


@dataclass(frozen=True)
class ConcatSummary:
    target: str
    blocks_moved: int
    size: int


#: error-name -> class, used to rehydrate typed errors out of batched
#: :class:`~repro.core.namenode.OpOutcome` records
ERROR_TYPES: Dict[str, Type[Exception]] = {
    cls.__name__: cls
    for cls in (FSError, FileNotFound, FileAlreadyExists, LeaseConflict,
                SubtreeLockedError, StoreError, LockTimeout, NodeGroupDown,
                TransactionAborted, RowNotFound, NetworkPartition,
                DeadlineExpired, OverloadShed)
}


def error_for(name: Optional[str], detail: str = "") -> Exception:
    """Typed exception for an outcome's recorded error name."""
    return ERROR_TYPES.get(name or "StoreError", StoreError)(detail or name)


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


class DFSClient:
    """Typed client over a :class:`NamenodeCluster`.

    ``middleware`` defaults to ``[failover(...), subtree_retry(...)]``;
    pass your own stack to change retry policy or to add concerns
    (tracing, circuit breaking) — the terminal handler always picks a live
    namenode per attempt and invokes through the registry.
    """

    def __init__(self, cluster: NamenodeCluster, *, policy: str = "sticky",
                 seed: int = 0, subtree_retries: int = 8,
                 subtree_backoff: float = 0.002,
                 failover_attempts: int = 8,
                 middleware: Optional[Sequence[Middleware]] = None,
                 retry_budget: Any = None, breakers: Any = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.cluster = cluster
        self._selector = Client(cluster, policy=policy, seed=seed,
                                board=breakers)
        self.failover_attempts = failover_attempts
        #: shared token-bucket retry budget (admission.RetryBudget) every
        #: retrying middleware of this client draws from; refilled once
        #: per logical call (``note_call`` in :meth:`call`)
        self.retry_budget = retry_budget
        #: per-namenode circuit breakers (admission.BreakerBoard): the
        #: selector routes around open breakers, and a breaker-recording
        #: middleware wraps every attempt
        self.breakers = breakers
        if middleware is None:
            # deterministic per-client jitter: seeded, so replays
            # reproduce while concurrent clients still de-synchronize
            jitter = random.Random(seed ^ 0x5EED)
            middleware = [
                failover(attempts=failover_attempts,
                         on_failover=self._reset_sticky,
                         sleep=sleep, jitter=jitter,
                         budget=retry_budget),
                subtree_retry(retries=subtree_retries,
                              backoff=subtree_backoff, sleep=sleep,
                              budget=retry_budget),
                txn_retry(sleep=sleep, jitter=jitter,
                          budget=retry_budget),
                # §7.5: timed-out txns aborted, re-run
            ]
            if breakers is not None:
                from .admission import circuit_breaker
                # inside failover, outside the per-error retries: every
                # failover attempt records on the breaker of the
                # namenode that served it
                middleware.insert(1, circuit_breaker(breakers))
        self.middleware: List[Middleware] = list(middleware)
        self._handler: Handler = compose(self.middleware, self._terminal)
        self.retries = 0
        #: the client-side inode hint cache (§5.1 applied to the CLIENT):
        #: warmed from the (parent_id, name) -> inode_id resolutions every
        #: namenode response piggybacks (``OpResult.hints``), invalidated
        #: on destructive ops, and handed to the planned pipeline so the
        #: batch planner resolves against responses this client actually
        #: saw instead of reading namenode caches — see docs/HINTS.md
        self.hint_cache = InodeHintCache()
        #: elastic pool this client follows (None on a static fleet)
        self.pool: Any = None

    # -- plumbing -------------------------------------------------------
    def attach_pool(self, pool: Any) -> None:
        """Follow an :class:`~repro.core.pool.ElasticNamenodePool`: this
        client's hint cache becomes a pre-warm donor for joiners, and a
        ``membership_refresh`` middleware (outermost — it must see every
        attempt) drops the sticky namenode selection whenever the pool's
        membership epoch moves, so calls rebalance onto the new fleet
        without interrupting anything in flight. ``run_trace`` also starts
        ticking the pool per planned window."""
        self.pool = pool
        pool.register_client_cache(self.hint_cache)
        self.middleware.insert(
            0, membership_refresh(pool, self._reset_sticky))
        self._handler = compose(self.middleware, self._terminal)

    def _reset_sticky(self, ctx: CallContext) -> None:
        self._selector._sticky = None

    def _pick(self) -> Namenode:
        return self._selector._pick()

    def _terminal(self, ctx: CallContext) -> OpResult:
        nn = self._pick()
        ctx.namenode = nn
        ctx.attempts += 1
        return nn.invoke(ctx.wop)

    def _absorb(self, wop: WorkloadOp, res: OpResult) -> None:
        """Close the hint loop for one response: invalidate what a
        destructive op removed/moved, then warm the client cache from the
        piggybacked resolutions (the shared
        :func:`~repro.core.hint_cache.absorb_response` rule)."""
        absorb_response(self.hint_cache, wop, REGISTRY.get(wop.op),
                        res.hints)

    def call(self, op: str, path: str = "", path2: Optional[str] = None,
             **args: Any) -> OpResult:
        """Execute any registered op through the middleware stack.  The
        named methods below are typed wrappers over this."""
        if op not in REGISTRY:
            raise KeyError(f"unknown op {op!r}; registered: "
                           f"{sorted(REGISTRY.names())}")
        deadline = args.pop("deadline", None)
        tenant = args.pop("tenant", None)
        wop = WorkloadOp(op, path, path2, args=args,
                         deadline=deadline, tenant=tenant)
        ctx = CallContext(op=op, wop=wop, deadline=deadline)
        if self.retry_budget is not None:
            self.retry_budget.note_call()
        try:
            res = self._handler(ctx)
            self._absorb(wop, res)
            return res
        finally:
            self.retries += ctx.retries

    # -- namespace ------------------------------------------------------
    def mkdir(self, path: str, perm: int = 0o755) -> int:
        return self.call("mkdir", path, perm=perm).value

    def mkdirs(self, path: str, perm: int = 0o755) -> Optional[int]:
        return self.call("mkdirs", path, perm=perm).value

    def create(self, path: str, *, repl: int = 3, client: str = "client",
               overwrite: bool = False) -> int:
        return self.call("create", path, repl=repl, client=client,
                         overwrite=overwrite).value

    def stat(self, path: str) -> FileStatus:
        v = self.call("stat", path).value
        return FileStatus(path=path, inode_id=v["id"], is_dir=v["is_dir"],
                          perm=v["perm"], owner=v["owner"], group=v["group"],
                          size=v["size"], repl=v["repl"], mtime=v["mtime"])

    def exists(self, path: str) -> bool:
        try:
            self.call("stat", path)
            return True
        except FileNotFound:
            return False

    def ls(self, path: str) -> Tuple[str, ...]:
        return tuple(self.call("ls", path).value)

    def open(self, path: str) -> Tuple[BlockLocation, ...]:
        """getBlockLocations — the dominant op of the Spotify mix."""
        return tuple(BlockLocation(b["block"], b["size"],
                                   tuple(b["locations"]))
                     for b in self.call("read", path).value)

    def rename(self, src: str, dst: str) -> None:
        """mv: routes to the subtree protocol (§6) for directories."""
        op = "rename_subtree" if self.stat(src).is_dir else "rename_file"
        self.call(op, src, dst)

    def delete(self, path: str, recursive: bool = False) -> DeleteSummary:
        st = self.stat(path)
        if st.is_dir:
            if not recursive:
                raise FSError(f"directory {path} (use recursive=True)")
            v = self.call("delete_subtree", path).value
            return DeleteSummary(path, v["deleted"], True)
        self.call("delete_file", path)
        return DeleteSummary(path, 1, False)

    # -- attributes -----------------------------------------------------
    def chmod(self, path: str, perm: int) -> None:
        op = "chmod_subtree" if self.stat(path).is_dir else "chmod_file"
        self.call(op, path, perm=perm)

    def chown(self, path: str, owner: str) -> None:
        op = "chown_subtree" if self.stat(path).is_dir else "chown_file"
        self.call(op, path, owner=owner)

    def set_replication(self, path: str, repl: int) -> None:
        self.call("set_replication", path, repl=repl)

    def set_quota(self, path: str, *, ns_quota: int = -1,
                  ss_quota: int = -1) -> None:
        self.call("set_quota", path, ns_quota=ns_quota, ss_quota=ss_quota)

    def content_summary(self, path: str) -> ContentSummary:
        v = self.call("content_summary", path).value
        return ContentSummary(path, v["children"], v["size"])

    # -- block protocol -------------------------------------------------
    def append(self, path: str, *, client: str = "client") -> int:
        """Reopen a file for append: takes the lease over for ``client``.
        Raises :class:`~repro.core.fs.LeaseConflict` while another
        client's live lease covers the file."""
        return self.call("append", path, client=client).value

    def add_block(self, path: str, *, client: str = "client") -> int:
        return self.call("add_block", path, client=client).value

    def complete_block(self, path: str, block_id: int = -1, *,
                       size: int, client: str = "client") -> None:
        """Finalize a block (``block_id=-1`` means the file's last
        allocated block)."""
        self.call("complete_block", path, block_id=block_id, size=size,
                  client=client)

    def renew_lease(self, *, client: str = "client") -> None:
        """Client heartbeat: keeps ``client``'s lease live so the leader's
        lease recovery does not reclaim its files under construction."""
        self.call("renew_lease", client=client)

    def recover_lease(self, path: str, *, client: str = "client") -> bool:
        """HDFS ``recoverLease``: force recovery of ``path``'s lease for a
        new writer once the holder outlived the SOFT lease limit, instead
        of waiting for the leader's hard-limit sweep. Returns True when a
        lease was recovered, False when there was nothing to recover; a
        holder still inside the soft limit raises
        :class:`~repro.core.fs.LeaseConflict`."""
        return bool(self.call("recover_lease", path, client=client).value)

    def truncate(self, path: str, new_size: int = 0) -> TruncateSummary:
        v = self.call("truncate", path, new_size=new_size).value
        return TruncateSummary(path, v["size"], v["removed_blocks"])

    def concat(self, target: str, srcs: Sequence[str]) -> ConcatSummary:
        v = self.call("concat", target, srcs=list(srcs)).value
        return ConcatSummary(target, v["blocks_moved"], v["size"])

    # -- batching -------------------------------------------------------
    def batch(self) -> "BatchCall":
        """Defer calls and flush them as ONE pulled batch through
        :meth:`Namenode.execute_batch` (runs of same-type reads validated
        with one grouped PK exchange per partition, §5.1)::

            with client.batch() as b:
                h1, h2 = b.stat("/a"), b.open("/a/f")
            print(h1.result().owner, h2.result()[0].block_id)
        """
        return BatchCall(self)

    def run_trace(self, wops: Sequence[WorkloadOp], *, batch_size: int = 16,
                  concurrent: bool = False, planned: bool = False,
                  window: Optional[int] = None,
                  adaptive: bool = True,
                  hint_routing: Optional[bool] = None,
                  admission: Any = None) -> PipelineStats:
        """Replay a trace through the batched request pipeline over this
        client's cluster (the Fig 7 methodology). ``planned=True`` routes
        through the client-side columnar batch planner
        (:mod:`~repro.core.batch_planner`): partition-aligned, type-sorted
        batches with client-side path resolutions attached, instead of
        reactive FIFO dealing. The planned pipeline is closed-loop: it
        plans against THIS client's ``hint_cache`` (warmed by response
        piggybacking, shared with the facade's own calls) and resizes its
        planning window adaptively (``adaptive=False`` pins the window).
        With a pool attached (:meth:`attach_pool`) the pipeline ticks it
        once per executed window and routes batches hint-aware — override
        with ``hint_routing`` either way."""
        if planned:
            from .batch_planner import PlannedRequestPipeline
            return PlannedRequestPipeline(self.cluster,
                                          batch_size=batch_size,
                                          concurrent=concurrent,
                                          window=window,
                                          client_cache=self.hint_cache,
                                          adaptive=adaptive,
                                          pool=self.pool,
                                          hint_routing=hint_routing,
                                          admission=admission,
                                          breakers=self.breakers).run(
                                              wops)
        return RequestPipeline(self.cluster, batch_size=batch_size,
                               concurrent=concurrent).run(wops)


# ---------------------------------------------------------------------------
# deferred-batch plumbing
# ---------------------------------------------------------------------------


class BatchHandle:
    """Future-like handle for one deferred call in a :class:`BatchCall`."""

    __slots__ = ("_value", "_error", "_done")

    def __init__(self) -> None:
        self._value: Any = None
        self._error: Optional[Exception] = None
        self._done = False

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("batch not flushed yet (exit the context)")
        if self._error is not None:
            raise self._error
        return self._value


class BatchCall:
    """Collects deferred ops; the context exit flushes them as one batch
    on a single namenode (with dead-namenode failover)."""

    def __init__(self, client: DFSClient):
        self._client = client
        self._wops: List[WorkloadOp] = []
        self._handles: List[BatchHandle] = []
        self._mappers: List[Callable[[Any], Any]] = []

    def submit(self, op: str, path: str = "", path2: Optional[str] = None,
               _mapper: Callable[[Any], Any] = lambda v: v,
               **args: Any) -> BatchHandle:
        if op not in REGISTRY:
            raise KeyError(f"unknown op {op!r}")
        h = BatchHandle()
        self._wops.append(WorkloadOp(op, path, path2, args=args))
        self._handles.append(h)
        self._mappers.append(_mapper)
        return h

    # typed sugar for the batchable reads
    def stat(self, path: str) -> BatchHandle:
        return self.submit(
            "stat", path,
            _mapper=lambda v: FileStatus(
                path=path, inode_id=v["id"], is_dir=v["is_dir"],
                perm=v["perm"], owner=v["owner"], group=v["group"],
                size=v["size"], repl=v["repl"], mtime=v["mtime"]))

    def open(self, path: str) -> BatchHandle:
        return self.submit(
            "read", path,
            _mapper=lambda v: tuple(
                BlockLocation(b["block"], b["size"], tuple(b["locations"]))
                for b in v))

    def ls(self, path: str) -> BatchHandle:
        return self.submit("ls", path, _mapper=tuple)

    def flush(self) -> None:
        """Execute the deferred ops on one namenode; ops in flight when a
        namenode dies (§7.6.1) — whether the whole batch call raised or
        individual outcomes recorded the death — are retried on a
        survivor. The batch is reusable after flush."""
        todo = list(zip(self._wops, self._handles, self._mappers))
        self._wops, self._handles, self._mappers = [], [], []
        last: Optional[Exception] = None
        for _ in range(max(1, self._client.failover_attempts)):
            if not todo:
                return
            nn = self._client._pick()
            try:
                outcomes = nn.execute_batch([w for w, _, _ in todo])
            except StoreError as e:
                # died holding the batch, or unreachable: nothing executed
                if not nn.alive or isinstance(e, NetworkPartition):
                    last = e
                    self._client.retries += 1
                    self._client._reset_sticky(CallContext(op="batch"))
                    continue
                raise
            retry = []
            for (w, h, mapper), oc in zip(todo, outcomes):
                if not oc.ok and oc.error == "StoreError" and not nn.alive:
                    retry.append((w, h, mapper))   # in-flight death: redo
                    continue
                h._done = True
                if oc.ok:
                    h._value = mapper(oc.result.value)
                    self._client._absorb(w, oc.result)
                else:
                    h._error = error_for(oc.error)
            if not retry:
                return
            todo = retry
            self._client.retries += 1
            self._client._reset_sticky(CallContext(op="batch"))
            last = StoreError("namenode died mid-batch")
        raise last  # type: ignore[misc]

    def __enter__(self) -> "BatchCall":
        return self

    def __exit__(self, et, ev, tb) -> bool:
        if et is None:
            self.flush()
        return False
