"""Inode hint cache (paper §5.1).

Each namenode caches **only the primary keys** of inodes: for path component
``name`` under parent ``parent_id`` it remembers the child's inode id. Given
``/a/b/c`` and hits for every component, the namenode knows the composite PK
``(parent_id, name)`` of every component and can read them all **in one
batched PK operation** instead of N sequential round trips.

Cache entries are validated by the batch read itself (§5.1.1): if a hinted PK
misses (row moved by a rename) the namenode falls back to recursive
resolution and repairs the cache. Entries go stale rarely — rename/move are
<2% of typical workloads (Table 1).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

from .tables import ROOT_ID


class InodeHintCache:
    """LRU of (parent_id, name) -> inode_id."""

    def __init__(self, capacity: int = 1_000_000):
        self.capacity = capacity
        self._lru: "OrderedDict[Tuple[int, str], int]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def get(self, parent_id: int, name: str) -> Optional[int]:
        key = (parent_id, name)
        v = self._lru.get(key)
        if v is None:
            self.misses += 1
            return None
        self._lru.move_to_end(key)
        self.hits += 1
        return v

    def put(self, parent_id: int, name: str, inode_id: int) -> None:
        key = (parent_id, name)
        self._lru[key] = inode_id
        self._lru.move_to_end(key)
        if len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    def peek(self, parent_id: int, name: str) -> Optional[int]:
        """Probe without touching LRU order or hit/miss counters — the
        client-side batch planner reads namenode caches through this so
        planning never skews a namenode's own cache statistics."""
        return self._lru.get((parent_id, name))

    def invalidate(self, parent_id: int, name: str) -> None:
        if self._lru.pop((parent_id, name), None) is not None:
            self.invalidations += 1

    def clear(self) -> None:
        self._lru.clear()

    # ------------------------------------------------------------------
    def resolve_pks(self, components: Sequence[str]
                    ) -> Optional[List[Tuple[int, str]]]:
        """Given path components (excluding root), return the composite PKs
        [(parent_id, name), ...] for every component **iff every lookup
        hits**. The root inode (id=ROOT_ID) is always known (§5.1).
        Returns None on any miss (caller falls back to recursive resolve).
        """
        pks: List[Tuple[int, str]] = []
        parent = ROOT_ID
        for i, name in enumerate(components):
            pks.append((parent, name))
            if i == len(components) - 1:
                break  # last component's own id is not needed to know its PK
            child = self.get(parent, name)
            if child is None:
                return None
            parent = child
        return pks

    def resolve_pks_and_id(self, components: Sequence[str]
                           ) -> Optional[Tuple[List[Tuple[int, str]], int]]:
        """Full-chain resolution for the batched pipeline: the composite PK
        of every component **plus the target's inode id**, iff every lookup
        (including the target itself) hits. The target id is what the
        batched executor feeds to the vectorized partition hash to group
        same-partition ops; a miss anywhere returns None and the op falls
        back to the sequential path (which repairs the cache)."""
        pks: List[Tuple[int, str]] = []
        parent = ROOT_ID
        for name in components:
            pks.append((parent, name))
            child = self.get(parent, name)
            if child is None:
                return None
            parent = child
        return pks, parent

    def last_resolved_id(self, components: Sequence[str]) -> Optional[int]:
        parent = ROOT_ID
        for name in components:
            child = self.get(parent, name)
            if child is None:
                return None
            parent = child
        return parent
