"""Inode hint cache (paper §5.1) — namenode-side AND client-side.

Each namenode caches **only the primary keys** of inodes: for path component
``name`` under parent ``parent_id`` it remembers the child's inode id. Given
``/a/b/c`` and hits for every component, the namenode knows the composite PK
``(parent_id, name)`` of every component and can read them all **in one
batched PK operation** instead of N sequential round trips.

Cache entries are validated by the batch read itself (§5.1.1): if a hinted PK
misses (row moved by a rename) the namenode falls back to recursive
resolution and repairs the cache. Entries go stale rarely — rename/move are
<2% of typical workloads (Table 1).

The same class backs the **client-side** hint cache of the closed-loop
planned pipeline: namenode responses piggyback the ``(parent_id, name) ->
inode_id`` resolutions they touched (``OpResult.hints``), clients absorb
them (:meth:`InodeHintCache.absorb`) and invalidate on destructive ops
(:meth:`InodeHintCache.invalidate_path`). ``stale_overwrites`` counts
absorbed entries that CONTRADICTED a cached id — direct evidence of
hint staleness (rename/delete+recreate), the telemetry
``docs/HINTS.md`` documents.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Iterable, List, Optional, Sequence, Tuple

from .tables import ROOT_ID, split_path

#: parent-id tag of the epoch entries piggybacked inside ``OpResult.hints``
#: (real parent ids are always >= ROOT_ID, so -1 can never collide with a
#: genuine (parent_id, name, inode_id) resolution). Two shapes ride under
#: it: ``(-1, "", epoch)`` — the store's current hint epoch — and
#: ``(-1, "/a/b", epoch)`` — a path invalidated at that epoch. Producers:
#: ``MetadataStore.hint_piggyback``; consumer: :func:`absorb_response`.
EPOCH_TAG = -1


def split_epoch_entries(hints: Iterable[Tuple[int, str, int]]
                        ) -> Tuple[List[Tuple[int, str, int]],
                                   List[Tuple[int, str, int]]]:
    """Partition a response's hints into (resolutions, epoch entries)."""
    res: List[Tuple[int, str, int]] = []
    epochs: List[Tuple[int, str, int]] = []
    for h in hints:
        (epochs if h[0] == EPOCH_TAG else res).append(h)
    return res, epochs


class InodeHintCache:
    """LRU of (parent_id, name) -> inode_id."""

    def __init__(self, capacity: int = 1_000_000):
        self.capacity = capacity
        self._lru: "OrderedDict[Tuple[int, str], int]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.stale_overwrites = 0   # puts that contradicted a cached id
        #: cross-client invalidation-push state: highest store hint epoch
        #: this cache has observed, and the count of wholesale clears a
        #: coverage gap forced (the bounded invalidation log aged out
        #: epochs this cache never saw)
        self.seen_epoch = 0
        self.epoch_resets = 0

    def get(self, parent_id: int, name: str) -> Optional[int]:
        key = (parent_id, name)
        v = self._lru.get(key)
        if v is None:
            self.misses += 1
            return None
        self._lru.move_to_end(key)
        self.hits += 1
        return v

    def put(self, parent_id: int, name: str, inode_id: int) -> None:
        key = (parent_id, name)
        prev = self._lru.get(key)
        if prev is not None and prev != inode_id:
            self.stale_overwrites += 1
        self._lru[key] = inode_id
        self._lru.move_to_end(key)
        if len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    def peek(self, parent_id: int, name: str) -> Optional[int]:
        """Probe without touching LRU order or hit/miss counters — the
        client-side batch planner reads namenode caches through this so
        planning never skews a namenode's own cache statistics."""
        return self._lru.get((parent_id, name))

    def invalidate(self, parent_id: int, name: str) -> None:
        if self._lru.pop((parent_id, name), None) is not None:
            self.invalidations += 1

    def invalidate_path(self, components: Sequence[str]) -> bool:
        """Client-side invalidation on a destructive op (rename/delete/
        subtree move): walk the cached chain and drop the LEAF entry.
        Dropping the leaf suffices for reachable entries — descendants of
        a removed directory become unreachable through the cache (every
        resolution walks from the root, and inode ids are never reused),
        so they age out of the LRU. Best-effort, not airtight: if an
        intermediate entry was LRU-evicted the walk stops early and a
        stale leaf may survive (and become reachable again once the
        intermediate is re-warmed) — harmless, because hints are never
        trusted: the namenode's in-transaction validation misses on the
        stale PK and falls back to sequential resolution (§5.1.1)."""
        parent = ROOT_ID
        for i, name in enumerate(components):
            if i == len(components) - 1:
                if (parent, name) in self._lru:
                    self.invalidate(parent, name)
                    return True
                return False
            child = self.peek(parent, name)
            if child is None:
                return False
            parent = child
        return False

    def absorb(self, hints: Iterable[Tuple[int, str, int]]) -> None:
        """Warm the cache from response-piggybacked resolutions
        (``OpResult.hints``): each entry is (parent_id, name, inode_id).
        Tagged epoch entries (:data:`EPOCH_TAG`) are skipped — they are
        :meth:`observe_epoch`'s business, not cache content."""
        for parent_id, name, inode_id in hints:
            if parent_id == EPOCH_TAG:
                continue
            self.put(parent_id, name, inode_id)

    def observe_epoch(self, entries: Iterable[Tuple[int, str, int]]) -> None:
        """Apply a response's piggybacked invalidation-epoch entries (the
        cross-client push): invalidate every logged path newer than
        :attr:`seen_epoch`; if the log tail starts AFTER the first epoch
        this cache missed (the bounded log aged it out), fall back to a
        wholesale :meth:`clear` — correctness over retention. Advances
        ``seen_epoch`` to the piggybacked current epoch either way."""
        current = self.seen_epoch
        min_logged = None
        todo: List[Tuple[int, str]] = []
        for _tag, payload, e in entries:
            if payload:
                if min_logged is None or e < min_logged:
                    min_logged = e
                todo.append((e, payload))
            elif e > current:
                current = e
        if current <= self.seen_epoch:
            return
        if min_logged is not None and min_logged > self.seen_epoch + 1:
            # epochs (seen, min_logged) were invalidations we never saw
            self.clear()
            self.epoch_resets += 1
        else:
            for e, path in todo:
                if e > self.seen_epoch:
                    self.invalidate_path(split_path(path))
        self.seen_epoch = current

    def export_entries(self, limit: Optional[int] = None
                       ) -> List[Tuple[int, str, int]]:
        """The cache contents as absorbable (parent_id, name, inode_id)
        hints, oldest-first so :meth:`absorb` on the receiver reproduces
        the LRU recency order. With ``limit``, only the NEWEST ``limit``
        entries — the warm working set a retiring namenode migrates to its
        successors (and a joining one is pre-warmed with)."""
        items = [(p, n, v) for (p, n), v in self._lru.items()]
        if limit is not None and len(items) > limit:
            items = items[-limit:]
        return items

    def clear(self) -> None:
        self._lru.clear()

    # deliberately NOT __len__: fs.py/namenode.py guard the optional cache
    # with `if self.cache:` (identity semantics), and a __len__ would make
    # an EMPTY cache falsy — disabling cache repair before the first entry
    @property
    def entries(self) -> int:
        """Current cache population."""
        return len(self._lru)

    # ------------------------------------------------------------------
    def resolve_pks(self, components: Sequence[str]
                    ) -> Optional[List[Tuple[int, str]]]:
        """Given path components (excluding root), return the composite PKs
        [(parent_id, name), ...] for every component **iff every lookup
        hits**. The root inode (id=ROOT_ID) is always known (§5.1).
        Returns None on any miss (caller falls back to recursive resolve).
        """
        pks: List[Tuple[int, str]] = []
        parent = ROOT_ID
        for i, name in enumerate(components):
            pks.append((parent, name))
            if i == len(components) - 1:
                break  # last component's own id is not needed to know its PK
            child = self.get(parent, name)
            if child is None:
                return None
            parent = child
        return pks

    def resolve_pks_and_id(self, components: Sequence[str]
                           ) -> Optional[Tuple[List[Tuple[int, str]], int]]:
        """Full-chain resolution for the batched pipeline: the composite PK
        of every component **plus the target's inode id**, iff every lookup
        (including the target itself) hits. The target id is what the
        batched executor feeds to the vectorized partition hash to group
        same-partition ops; a miss anywhere returns None and the op falls
        back to the sequential path (which repairs the cache)."""
        pks: List[Tuple[int, str]] = []
        parent = ROOT_ID
        for name in components:
            pks.append((parent, name))
            child = self.get(parent, name)
            if child is None:
                return None
            parent = child
        return pks, parent

    def last_resolved_id(self, components: Sequence[str]) -> Optional[int]:
        parent = ROOT_ID
        for name in components:
            child = self.get(parent, name)
            if child is None:
                return None
            parent = child
        return parent


def absorb_response(cache: InodeHintCache, wop: Any, spec: Any,
                    hints: Iterable[Tuple[int, str, int]]) -> None:
    """THE closed-loop absorb rule for one response, shared by the
    ``DFSClient`` facade and the planned pipeline so the two cannot
    diverge: drop what a destructive op (``OpSpec.destructive``)
    removed/moved — the primary path, rename's destination (an
    overwriting rename replaces the old mapping; the fresh one arrives
    with the hints), and concat's ``srcs`` — then warm the cache from the
    response's piggybacked hints (``OpResult.hints``). ``wop`` is the
    executed :class:`~repro.core.ops_registry.WorkloadOp`, ``spec`` its
    OpSpec (or None for unregistered ops).

    Since the cross-client invalidation push, responses also carry tagged
    epoch entries (:data:`EPOCH_TAG`): the store's current hint epoch plus
    the recently invalidated paths. Those are applied FIRST
    (:meth:`InodeHintCache.observe_epoch` — they describe world state
    older than this response), then the op's own destructive
    invalidation, then the fresh post-execution resolutions."""
    hints, epochs = split_epoch_entries(hints)
    if epochs:
        cache.observe_epoch(epochs)
    if spec is not None and spec.destructive:
        # OpSpec.path_args applies rename's implicit ".mv" destination —
        # the same canonical rule the planner's conflict analysis uses
        for p in spec.path_args(wop):
            cache.invalidate_path(split_path(p))
        for src in (wop.args or {}).get("srcs", ()) or ():
            cache.invalidate_path(split_path(str(src)))
    cache.absorb(hints)
