"""Apache HDFS baseline (paper §2.1) — the system HopsFS is compared against.

A faithful functional model of the HDFS v2.x namenode architecture:

  * the whole namespace lives in one process' memory (dict-based, like the
    JVM heap object graph);
  * a **single global readers-writer lock** serializes metadata operations
    (single-writer / multiple-readers semantics);
  * high availability = Active NN + Standby NN + quorum journal: edits are
    logged to 2f+1 journal nodes; the standby tails the log and checkpoints;
    failover requires the standby to catch up + fencing via ZooKeeper —
    modelled as a downtime window proportional to untailed edits (§7.6.1:
    8-10 s in the paper's small-metadata tests; minutes at Spotify scale);
  * large deletes are executed in multiple phases and are NOT atomic (§2.1);
  * memory cost per file: 448 + len(name) bytes (Table 2).

The functional layer is used by correctness tests; the DES
(`cluster_sim.py`) layers queueing/timing on top for Figs 6-11.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

from .tables import HDFS_FILE_BYTES_BASE


class HDFSError(Exception):
    pass


class _RWLock:
    """Single global namespace lock: single writer, multiple readers."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._readers = 0
        self._rcond = threading.Condition(self._mu)

    def acquire_read(self):
        with self._mu:
            self._readers += 1

    def release_read(self):
        with self._mu:
            self._readers -= 1
            self._rcond.notify_all()

    def acquire_write(self):
        self._mu.acquire()
        while self._readers:
            self._rcond.wait()
        # hold _mu as the write lock

    def release_write(self):
        self._mu.release()


@dataclass
class _INode:
    id: int
    name: str
    is_dir: bool
    perm: int = 0o755
    owner: str = "hdfs"
    size: int = 0
    blocks: List[int] = field(default_factory=list)
    children: Dict[str, "_INode"] = field(default_factory=dict)


class HDFSNamenode:
    """Functional single-namenode HDFS."""

    READ_OPS = {"read", "ls", "stat", "content_summary"}

    def __init__(self) -> None:
        self.root = _INode(1, "", True)
        self.lock = _RWLock()
        self._next_id = 2
        self._next_blk = 1
        self.n_files = 0
        self.n_dirs = 1
        self.edits_logged = 0          # journal length since last checkpoint
        self.block_map: Dict[int, List[int]] = {}

    # -- path helpers (recursive in-heap resolution) --------------------
    def _walk(self, path: str, *, parent: bool = False) -> _INode:
        comps = [c for c in path.split("/") if c]
        if parent:
            comps = comps[:-1]
        node = self.root
        for c in comps:
            nxt = node.children.get(c)
            if nxt is None:
                raise HDFSError(f"not found: {path}")
            node = nxt
        return node

    # -- operations ------------------------------------------------------
    def mkdir(self, path: str) -> int:
        self.lock.acquire_write()
        try:
            comps = [c for c in path.split("/") if c]
            node = self.root
            for c in comps:
                if c not in node.children:
                    node.children[c] = _INode(self._next_id, c, True)
                    self._next_id += 1
                    self.n_dirs += 1
                    self.edits_logged += 1
                node = node.children[c]
            return node.id
        finally:
            self.lock.release_write()

    def create(self, path: str) -> int:
        self.lock.acquire_write()
        try:
            parent = self._walk(path, parent=True)
            name = path.rstrip("/").rsplit("/", 1)[-1]
            if name in parent.children:
                raise HDFSError(f"exists: {path}")
            f = _INode(self._next_id, name, False)
            self._next_id += 1
            parent.children[name] = f
            self.n_files += 1
            self.edits_logged += 1
            return f.id
        finally:
            self.lock.release_write()

    def add_block(self, path: str) -> int:
        self.lock.acquire_write()
        try:
            f = self._walk(path)
            bid = self._next_blk
            self._next_blk += 1
            f.blocks.append(bid)
            self.block_map[bid] = [0, 1, 2]
            self.edits_logged += 1
            return bid
        finally:
            self.lock.release_write()

    def read(self, path: str) -> List[Tuple[int, List[int]]]:
        self.lock.acquire_read()
        try:
            f = self._walk(path)
            return [(b, self.block_map.get(b, [])) for b in f.blocks]
        finally:
            self.lock.release_read()

    def ls(self, path: str) -> List[str]:
        self.lock.acquire_read()
        try:
            return sorted(self._walk(path).children.keys())
        finally:
            self.lock.release_read()

    def stat(self, path: str) -> Dict[str, Any]:
        self.lock.acquire_read()
        try:
            n = self._walk(path)
            return {"id": n.id, "is_dir": n.is_dir, "perm": n.perm,
                    "owner": n.owner, "size": n.size}
        finally:
            self.lock.release_read()

    def chmod(self, path: str, perm: int) -> None:
        """In-heap subtree ops are fast: everything is local (Fig 6/7)."""
        self.lock.acquire_write()
        try:
            def rec(n: _INode):
                n.perm = perm
                for c in n.children.values():
                    rec(c)
            rec(self._walk(path))
            self.edits_logged += 1
        finally:
            self.lock.release_write()

    def rename(self, src: str, dst: str) -> None:
        self.lock.acquire_write()
        try:
            sp = self._walk(src, parent=True)
            name = src.rstrip("/").rsplit("/", 1)[-1]
            node = sp.children.pop(name)
            dp = self._walk(dst, parent=True)
            dname = dst.rstrip("/").rsplit("/", 1)[-1]
            node.name = dname
            dp.children[dname] = node
            self.edits_logged += 1
        finally:
            self.lock.release_write()

    def delete(self, path: str) -> int:
        """Large deletes happen in phases and are not atomic (§2.1): inodes
        first, then blocks in small batches (we count both phases)."""
        self.lock.acquire_write()
        try:
            parent = self._walk(path, parent=True)
            name = path.rstrip("/").rsplit("/", 1)[-1]
            node = parent.children.pop(name)
        finally:
            self.lock.release_write()
        # phase 2+: incremental block deletion outside the big lock
        removed = 0

        def rec(n: _INode) -> int:
            cnt = 1
            for b in n.blocks:
                self.block_map.pop(b, None)
            for c in list(n.children.values()):
                cnt += rec(c)
            return cnt
        removed = rec(node)
        self.edits_logged += removed
        return removed

    # -- capacity (Table 2) ------------------------------------------------
    def metadata_bytes(self, avg_name_len: int = 10) -> int:
        return (self.n_files + self.n_dirs) * \
            (HDFS_FILE_BYTES_BASE + avg_name_len)


@dataclass
class HDFSHACluster:
    """ANN + SbNN + journal quorum + ZooKeeper (Fig 1, 5-8 servers).

    Failover model (§7.6.1): ZK detects failure after `detect_s`; the standby
    must replay untailed edits (`replay_rate` edits/s) and assume active
    duty. During that window *no* metadata op can be served.
    """
    n_journal: int = 3
    detect_s: float = 2.0
    replay_rate: float = 50_000.0
    standby_lag_edits: int = 300_000   # checkpoint lag at failure time

    def __post_init__(self) -> None:
        self.active = HDFSNamenode()
        self.journal_alive = self.n_journal

    def failover_downtime_s(self) -> float:
        return self.detect_s + self.standby_lag_edits / self.replay_rate

    def journal_quorum_ok(self) -> bool:
        return self.journal_alive > self.n_journal // 2

    def fail_journal_node(self) -> None:
        self.journal_alive -= 1
        if not self.journal_quorum_ok():
            raise HDFSError("journal quorum lost: namenode shuts down")
