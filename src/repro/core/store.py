"""Shared-nothing, partitioned, transactional in-memory store (paper §2.2).

This is the NDB-equivalent storage engine: tables are hash-partitioned on an
application-defined partition key (ADP, §4.2) across a fixed set of
partitions; partitions are assigned to *node groups* of ``replication``
datanodes each (§2.2.1). Transaction coordinators live on every datanode;
a transaction started with a *partition hint* runs its coordinator on the
primary datanode of that partition's node group (DAT, §2.2) so that reads of
co-located rows are node-local.

Access-path cost hierarchy (paper Fig 2a), tracked per-transaction by
:class:`OpCost`:

    PK read  <  batched PK read  <  partition-pruned index scan (PPIS)
             <<  index scan (IS, hits all shards)  <  full table scan (FTS)

Isolation: read-committed plus explicit row locks (shared / exclusive),
exactly the primitives NDB exposes (§2.2.2). Lock waits block (thread mode)
with timeout-abort; HopsFS-level deadlock freedom comes from total-order
acquisition in the FS layer (§5, "Cyclic Deadlocks").
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .tables import ALL_TABLES, TableSchema, pk_of

# ---------------------------------------------------------------------------
# Errors
# ---------------------------------------------------------------------------


class StoreError(Exception):
    pass


class RowNotFound(StoreError):
    pass


class LockTimeout(StoreError):
    """Raised when a row lock cannot be acquired within the transaction
    inactive timeout (paper §7.5: NDB default 1.2 s; retried by namenode)."""


class TransactionAborted(StoreError):
    pass


class NodeGroupDown(StoreError):
    """All replicas of a node group failed => cluster unavailable (§7.6.2)."""


class NetworkPartition(StoreError):
    """The client could not reach the namenode (the namenode itself may be
    perfectly alive).  Raised by the chaos injector on partitioned
    exchanges; the ``failover`` middleware treats it as retryable on
    another namenode (§7.6.1 — to the client, an unreachable namenode and
    a dead one are indistinguishable)."""


# ---------------------------------------------------------------------------
# Lock manager
# ---------------------------------------------------------------------------

READ_COMMITTED = "rc"
SHARED = "S"
EXCLUSIVE = "X"


class _RowLock:
    __slots__ = ("holders", "mode", "cond", "waiters")

    def __init__(self, cond_factory):
        self.holders: Set[int] = set()
        self.mode: Optional[str] = None
        self.cond = cond_factory()
        self.waiters = 0          # threads blocked in acquire() on this row


_LockKey = Tuple[str, Tuple[Any, ...]]


class LockManager:
    """Row-level shared/exclusive locks keyed by (table, pk), striped.

    The lock table is sharded into ``n_stripes`` independently-mutexed
    stripes (like NDB's LQH lock fragments), so unrelated rows never
    contend on one global mutex — the concurrent request pipeline runs one
    thread per namenode against this table. A per-transaction held-locks
    index makes :meth:`release_all` O(locks held by the txn) instead of
    O(all locks currently held cluster-wide).
    """

    def __init__(self, timeout: float = 1.2, n_stripes: int = 64):
        self.timeout = timeout
        self.n_stripes = max(1, n_stripes)
        self._mus = [threading.Lock() for _ in range(self.n_stripes)]
        self._locks: List[Dict[_LockKey, _RowLock]] = [
            {} for _ in range(self.n_stripes)]
        # txn_id -> keys it holds; guarded by its own (O(1)-hold) mutex
        self._held_mu = threading.Lock()
        self._held: Dict[int, Set[_LockKey]] = {}
        #: cumulative contention telemetry: every non-READ_COMMITTED
        #: acquire counts once; acquires that found a conflicting holder
        #: (and therefore waited — possibly timing out) count once more.
        #: wait_count/acquire_count is the LOCK-WAIT FRACTION the elastic
        #: pool samples and the WindowController's batch-size knob feeds
        #: on. Best-effort under concurrency (plain ints), exact on the
        #: deterministic pipelines.
        self.acquire_count = 0
        self.wait_count = 0

    def _stripe(self, key: _LockKey) -> int:
        return hash(key) % self.n_stripes

    def acquire(self, txn_id: int, table: str, pk: Tuple[Any, ...],
                mode: str) -> None:
        if mode == READ_COMMITTED:
            return
        key = (table, pk)
        s = self._stripe(key)
        mu = self._mus[s]
        with mu:
            lk = self._locks[s].get(key)
            if lk is None:
                lk = self._locks[s][key] = _RowLock(
                    lambda: threading.Condition(mu))
            # deadline computed once, outside the wait loop (hot path)
            deadline = time.monotonic() + self.timeout
            lk.waiters += 1
            self.acquire_count += 1
            waited = False
            try:
                while True:
                    if not lk.holders or lk.holders == {txn_id}:
                        break
                    if mode == SHARED and lk.mode == SHARED:
                        break
                    if not waited:
                        waited = True
                        self.wait_count += 1
                    # conflicting: wait (bounded by NDB txn-inactive timeout)
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or not lk.cond.wait(remaining):
                        raise LockTimeout(
                            f"lock timeout on {table}{pk} ({mode})")
            except LockTimeout:
                lk.waiters -= 1
                if not lk.holders and not lk.waiters:
                    del self._locks[s][key]   # entry we created, now orphaned
                raise
            else:
                lk.waiters -= 1
            lk.holders.add(txn_id)
            if lk.mode == EXCLUSIVE or mode == EXCLUSIVE:
                lk.mode = EXCLUSIVE
            else:
                lk.mode = SHARED
        with self._held_mu:
            self._held.setdefault(txn_id, set()).add(key)

    def release_all(self, txn_id: int) -> None:
        with self._held_mu:
            keys = self._held.pop(txn_id, None)
        if not keys:
            return
        by_stripe: Dict[int, List[_LockKey]] = {}
        for key in keys:
            by_stripe.setdefault(self._stripe(key), []).append(key)
        for s, stripe_keys in by_stripe.items():
            with self._mus[s]:
                locks = self._locks[s]
                for key in stripe_keys:
                    lk = locks.get(key)
                    if lk is None or txn_id not in lk.holders:
                        continue
                    lk.holders.discard(txn_id)
                    if not lk.holders:
                        lk.mode = None
                    lk.cond.notify_all()
                    # reclaim the entry only when nobody still waits on its
                    # condition — a waiter woken after the entry was dropped
                    # would otherwise mutate an orphaned lock object
                    if not lk.holders and not lk.waiters:
                        del locks[key]

    def held(self, table: str, pk: Tuple[Any, ...]) -> Optional[str]:
        key = (table, pk)
        with self._mus[self._stripe(key)]:
            lk = self._locks[self._stripe(key)].get(key)
            return lk.mode if lk and lk.holders else None

    def held_count(self, txn_id: int) -> int:
        """Number of row locks the transaction currently holds (the index
        the O(held) release walks)."""
        with self._held_mu:
            return len(self._held.get(txn_id, ()))


# ---------------------------------------------------------------------------
# Op-cost accounting (Fig 2a + Table 3 round-trip model)
# ---------------------------------------------------------------------------


@dataclass
class OpCost:
    """Round trips + row ops for one transaction, in Table 3's vocabulary.

    One *round trip* is one network exchange between the namenode's DAL and
    the database: a single PK op, one batch (regardless of rows inside), one
    PPIS, one IS (which fans out to every shard but is still one client
    round trip with higher cost weight), or one FTS.
    """
    pk_rc: int = 0        # PK read, read-committed (no lock)
    pk_r: int = 0         # PK read, shared lock
    pk_w: int = 0         # PK read-for-update / write, exclusive lock
    batches: int = 0      # batched PK operations
    batch_rows: int = 0   # total rows across batches
    ppis: int = 0         # partition-pruned index scans
    is_scans: int = 0     # index scans hitting all shards
    fts: int = 0          # full table scans
    # locality: round trips answered by the hinted (coordinator-local)
    # node group vs remote node groups (DAT effectiveness, §7.7)
    local_rt: int = 0
    remote_rt: int = 0
    rows_touched: int = 0

    @property
    def round_trips(self) -> int:
        return (self.pk_rc + self.pk_r + self.pk_w + self.batches
                + self.ppis + self.is_scans + self.fts)

    _FIELDS = ("pk_rc", "pk_r", "pk_w", "batches", "batch_rows", "ppis",
               "is_scans", "fts", "local_rt", "remote_rt", "rows_touched")

    def copy(self) -> "OpCost":
        return OpCost(**{f: getattr(self, f) for f in self._FIELDS})

    def diff(self, earlier: "OpCost") -> "OpCost":
        """Cost accrued since the `earlier` snapshot (batched pipeline uses
        this to attribute per-op shares of a shared transaction)."""
        return OpCost(**{f: getattr(self, f) - getattr(earlier, f)
                         for f in self._FIELDS})

    def merge(self, other: "OpCost") -> None:
        for f in self._FIELDS:
            setattr(self, f, getattr(self, f) + getattr(other, f))

    def as_dict(self) -> Dict[str, int]:
        d = {f: getattr(self, f) for f in self._FIELDS}
        d["round_trips"] = self.round_trips
        return d


# ---------------------------------------------------------------------------
# Partitioned table
# ---------------------------------------------------------------------------


def _hash_key(value: Any) -> int:
    """Deterministic partition hash (NDB uses MD5 of the partition key; we
    use crc32 of the repr — stable across runs, cheap, well-mixed for ids)."""
    if isinstance(value, int):
        # avoid trivial modulo patterns on sequential ids
        v = value * 0x9E3779B1 & 0xFFFFFFFF
        return v ^ (v >> 16)
    return zlib.crc32(repr(value).encode())


class Table:
    def __init__(self, schema: TableSchema, n_partitions: int):
        self.schema = schema
        self.n_partitions = n_partitions
        self.parts: List[Dict[Tuple[Any, ...], Dict[str, Any]]] = [
            {} for _ in range(n_partitions)]
        # secondary indexes: col -> value -> set of pks
        self.idx: Dict[str, Dict[Any, Set[Tuple[Any, ...]]]] = {
            c: {} for c in schema.indexes}
        self.n_rows = 0
        # pk -> partition, maintained only for tables whose partition key
        # is NOT part of the PK (block/replica/...): NDB resolves such PKs
        # through its distribution hash; here an O(1) map replaces the
        # all-partition search on get/delete and detects partition-key
        # updates on put
        self._pk_loc: Optional[Dict[Tuple[Any, ...], int]] = (
            None if schema.partition_key in schema.pk else {})

    # -- placement -----------------------------------------------------
    def partition_of(self, partition_key_value: Any) -> int:
        return _hash_key(partition_key_value) % self.n_partitions

    def partition_of_pk(self, pk: Tuple[Any, ...]) -> int:
        # partition key is always a PK column prefix or derivable from a row;
        # for PKs we locate via the partition-key column position if it is in
        # the PK, else via the pk-location map.
        s = self.schema
        if s.partition_key in s.pk:
            return self.partition_of(pk[s.pk.index(s.partition_key)])
        p = self._pk_loc.get(pk)  # type: ignore[union-attr]
        return p if p is not None else self.partition_of(pk)

    # -- row ops (no locking here; engine layer handles locks/costs) ----
    def get(self, pk: Tuple[Any, ...], part_hint: Optional[int] = None
            ) -> Optional[Dict[str, Any]]:
        if part_hint is not None:
            return self.parts[part_hint].get(pk)
        return self.parts[self.partition_of_pk(pk)].get(pk)

    def put(self, row: Dict[str, Any]) -> None:
        pk = pk_of(self.schema, row)
        p = self.partition_of(row[self.schema.partition_key])
        part = self.parts[p]
        old = part.get(pk)
        if old is not None:
            self._unindex(old, pk)
        elif self._pk_loc is not None:
            # A partition-key UPDATE (e.g. concat re-owning block/replica
            # rows to the target file's inode id) moves the row between
            # shards — NDB performs an internal delete+insert.  Evict the
            # copy on the old shard so the PK stays unique cluster-wide.
            old_p = self._pk_loc.get(pk)
            if old_p is not None and old_p != p:
                old = self.parts[old_p].pop(pk, None)
                if old is not None:
                    self._unindex(old, pk)
        if old is None:
            self.n_rows += 1
        part[pk] = row
        if self._pk_loc is not None:
            self._pk_loc[pk] = p
        self._index(row, pk)

    def delete(self, pk: Tuple[Any, ...]) -> bool:
        p = self.partition_of_pk(pk)
        row = self.parts[p].pop(pk, None)
        if self._pk_loc is not None:
            self._pk_loc.pop(pk, None)
        if row is None:
            return False
        self._unindex(row, pk)
        self.n_rows -= 1
        return True

    def _index(self, row, pk):
        for c, ix in self.idx.items():
            ix.setdefault(row[c], set()).add(pk)

    def _unindex(self, row, pk):
        for c, ix in self.idx.items():
            s = ix.get(row[c])
            if s is not None:
                s.discard(pk)
                if not s:
                    del ix[row[c]]

    # -- scans ----------------------------------------------------------
    def scan_index(self, col: str, value: Any) -> List[Dict[str, Any]]:
        pks = self.idx.get(col, {}).get(value, ())
        out = []
        for pk in pks:
            r = self.get(pk)
            if r is not None:
                out.append(r)
        return out

    def scan_partition(self, part: int, pred: Callable[[Dict[str, Any]], bool]
                       ) -> List[Dict[str, Any]]:
        return [r for r in self.parts[part].values() if pred(r)]

    def scan_all(self, pred: Callable[[Dict[str, Any]], bool]
                 ) -> List[Dict[str, Any]]:
        out = []
        for part in self.parts:
            out.extend(r for r in part.values() if pred(r))
        return out


# ---------------------------------------------------------------------------
# Node groups / cluster topology (paper §2.2.1)
# ---------------------------------------------------------------------------


@dataclass
class NodeGroup:
    gid: int
    datanodes: List[int]
    alive: Set[int] = field(default_factory=set)

    def available(self) -> bool:
        return bool(self.alive)


class MetadataStore:
    """The NDB-equivalent cluster: tables + partitions + node groups + locks.

    ``n_datanodes`` NDB datanodes, ``replication`` copies per node group
    (default 2 as in the paper). Partition ``p`` of every table lives on node
    group ``p % n_groups``; the *primary* replica rotates by partition for
    balance. Failing a datanode keeps the store available while its node
    group has a survivor; failing an entire node group raises
    :class:`NodeGroupDown` on access (paper §7.6.2: "namenodes shutdown").
    """

    def __init__(self, n_datanodes: int = 4, replication: int = 2,
                 n_partitions: int = 64, lock_timeout: float = 1.2):
        if n_datanodes % replication:
            raise ValueError("n_datanodes must be a multiple of replication")
        self.n_datanodes = n_datanodes
        self.replication = replication
        self.n_groups = n_datanodes // replication
        self.node_groups = [
            NodeGroup(g, list(range(g * replication, (g + 1) * replication)),
                      set(range(g * replication, (g + 1) * replication)))
            for g in range(self.n_groups)]
        self.n_partitions = n_partitions
        self.tables: Dict[str, Table] = {
            s.name: Table(s, n_partitions) for s in ALL_TABLES}
        self.locks = LockManager(timeout=lock_timeout)
        self._txn_seq = 0
        self._mu = threading.Lock()
        self.epoch = 0            # global checkpoint epoch (§2.2.1)
        self.total_row_ops = 0    # lifetime row ops (DES capacity feed)
        # cross-client hint invalidation push (the store-level analogue of
        # NDB's event API the real HopsFS uses for cache invalidation):
        # every destructive op bumps hint_epoch and logs the invalidated
        # paths; namenodes piggyback the epoch plus the recent log tail on
        # EVERY response, so clients that never saw the destructive op
        # still drop their stale InodeHintCache entries
        self.hint_epoch = 0
        self._hint_log: deque = deque(maxlen=self.HINT_LOG_MAX)
        self._hint_mu = threading.Lock()
        self._hint_pb: Tuple[int, Tuple] = (0, ())   # memoized piggyback

    #: bounded invalidation log: epochs older than hint_epoch-HINT_LOG_TAIL
    #: are no longer piggybacked — a client that far behind clears its
    #: cache wholesale instead of replaying individual invalidations
    HINT_LOG_MAX = 64
    HINT_LOG_TAIL = 8

    # -- cross-client hint invalidation (epoch push) ---------------------
    def record_hint_invalidation(self, paths) -> int:
        """One destructive op committed: bump the hint epoch and log its
        invalidated paths (one epoch per op, every path tagged with it).
        Returns the new epoch."""
        with self._hint_mu:
            self.hint_epoch += 1
            e = self.hint_epoch
            for p in paths:
                if p:
                    self._hint_log.append((e, str(p)))
            return e

    def hint_piggyback(self) -> Tuple:
        """The tagged entries every response appends to ``OpResult.hints``:
        a ``(-1, "", epoch)`` marker carrying the store's current hint
        epoch, then one ``(-1, path, epoch)`` entry per recently
        invalidated path (epochs within :data:`HINT_LOG_TAIL` of current).
        Empty while no destructive op has ever run — read-only workloads
        pay nothing. Memoized per epoch: the hot path is one lock-free
        tuple reuse."""
        cur = self.hint_epoch
        if cur == 0:
            return ()
        memo_epoch, memo = self._hint_pb
        if memo_epoch == cur:
            return memo
        with self._hint_mu:
            cur = self.hint_epoch
            floor = cur - self.HINT_LOG_TAIL
            out = ((-1, "", cur),) + tuple(
                (-1, p, e) for e, p in self._hint_log if e > floor)
            self._hint_pb = (cur, out)
            return out

    # -- topology --------------------------------------------------------
    def group_of_partition(self, part: int) -> NodeGroup:
        return self.node_groups[part % self.n_groups]

    def primary_datanode(self, part: int) -> int:
        g = self.group_of_partition(part)
        if not g.alive:
            raise NodeGroupDown(f"node group {g.gid} has no live datanode")
        # rotate primary across partitions for balance
        members = [d for d in g.datanodes if d in g.alive]
        return members[(part // self.n_groups) % len(members)]

    def fail_datanode(self, dn: int) -> None:
        for g in self.node_groups:
            g.alive.discard(dn)

    def recover_datanode(self, dn: int) -> None:
        for g in self.node_groups:
            if dn in g.datanodes:
                g.alive.add(dn)

    def available(self) -> bool:
        return all(g.available() for g in self.node_groups)

    def check_available(self, part: int) -> None:
        g = self.group_of_partition(part)
        if not g.available():
            raise NodeGroupDown(f"node group {g.gid} down")

    # -- transactions ------------------------------------------------------
    def next_txn_id(self) -> int:
        with self._mu:
            self._txn_seq += 1
            return self._txn_seq

    # -- memory accounting (Table 2) ---------------------------------------
    def memory_bytes(self) -> int:
        total = 0
        for t in self.tables.values():
            total += t.n_rows * t.schema.row_bytes * self.replication
        return total

    def table(self, name: str) -> Table:
        return self.tables[name]

    # -- introspection ------------------------------------------------------
    def dump_state(self, *, exclude_cols: Sequence[str] = ()
                   ) -> Dict[str, List[Tuple[Any, Any]]]:
        """Deterministic snapshot of every table (rows sorted by PK).

        Used by the batched-pipeline tests to assert that batched execution
        leaves the store in exactly the state sequential execution does.
        ``exclude_cols`` drops columns that legitimately differ between runs
        with different namenode counts (e.g. per-namenode mtime clocks)."""
        ex = set(exclude_cols)
        out: Dict[str, List[Tuple[Any, Any]]] = {}
        for name, t in self.tables.items():
            rows = []
            for part in t.parts:
                for pk, row in part.items():
                    rows.append((pk, tuple(sorted(
                        (k, v) for k, v in row.items() if k not in ex))))
            out[name] = sorted(rows, key=lambda r: repr(r[0]))
        return out
