"""HopsFS inode operations (paper §5) as three-phase transactions.

Every operation follows the Figure-4 template and reproduces Table 3's
round-trip profile. Two resolution regimes exist per op:

  * **cache hit**  — the inode hint cache supplies the composite PK of every
    path component, so ancestors are validated with one *batched* PK read
    (1 round trip) at read-committed, and the target (+parent for mutating
    ops) is lock-read in one more batch. Cost is **independent of depth**.
  * **cache miss** — recursive resolution: one read-committed PK read per
    component (≈N round trips), repairing the cache along the way; mutating
    ops additionally re-validate the path under lock (≈2N total).

Round-trip accounting conventions (checked against Table 3 by
``benchmarks/bench_table3_costmodel.py``; deltas ≤1 RT are documented there):

  - one batch = one round trip irrespective of rows inside;
  - single PK reads count as PK_rc/PK_r/PK_w by lock mode;
  - commit flushes ≤8 dirty rows as per-row PK_w ops, larger sets as batches
    (Fig 4 line 8, "transfer the changes in batches");
  - file-related metadata (block/replica/URB/PRB/RUC/CR/ER/Inv) is read via
    partition-pruned index scans on the file's inode id (§4.2), 1 RT each;
    with ADP disabled (Fig 12/13 ablation) these degrade to all-shard IS.

Subtree-lock interaction (§6.3): resolution aborts with
:class:`SubtreeLockedError` when any path component carries a live subtree
lock; locks owned by dead namenodes are reclaimed in-line (§6.2).
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .hint_cache import InodeHintCache
from .store import (EXCLUSIVE, READ_COMMITTED, SHARED, MetadataStore, OpCost,
                    StoreError)
from .tables import (ROOT_ID, make_block, make_inode, make_replica,
                     split_path)
from .transactions import Transaction


class FSError(StoreError):
    pass


class FileNotFound(FSError):
    pass


class FileAlreadyExists(FSError):
    pass


class SubtreeLockedError(FSError):
    """Path crosses a subtree currently locked by another namenode (§6.3).
    Callers voluntarily abort and retry after the lock is released."""


class LeaseConflict(FSError):
    """Block write (add_block/append/complete_block) on a file under
    construction by ANOTHER client. ``append`` — which acquires the lease
    itself — may take over once the holder's lease expired; the other
    block ops must wait for the holder to finish or for the leader to
    reclaim the lease once the holder stops renewing (the client analogue
    of §6.2's dead-namenode lock reclaim)."""


@dataclass
class OpResult:
    """Return value of every FS op: payload + measured cost profile.

    ``hints`` is the response-piggybacked hint set (§5.1 applied to the
    CLIENT side of the metadata path): the ``(parent_id, name, inode_id)``
    resolutions the serving namenode's hint cache holds for the op's
    path(s) after execution. Clients absorb them into their own
    :class:`~repro.core.hint_cache.InodeHintCache` so client-side planning
    warms from responses instead of reading namenode caches — see
    ``docs/HINTS.md``. Attached by the namenode RPC layer
    (:meth:`~repro.core.namenode.Namenode.invoke`), charge-free (pure
    in-memory peeks, no ``OpCost`` round trips)."""
    value: Any
    cost: OpCost
    hints: Tuple[Tuple[int, str, int], ...] = ()
    #: election-clock tick at which the serving namenode finished the op
    #: (stamped by the RPC layer, ``Namenode._finish_op`` /
    #: ``_commit_group``) — the admission layer's goodput measure is
    #: ``completed_at <= WorkloadOp.deadline``. None outside a namenode.
    completed_at: Optional[int] = None


@dataclass
class ResolvedPath:
    """Outcome of the lock phase: ancestor rows, parent row, target row
    (None when the target does not exist), and whether hints hit."""
    ancestors: List[Dict[str, Any]]
    parent: Dict[str, Any]
    target: Optional[Dict[str, Any]]
    cache_hit: bool


# canonical splitter lives in tables.py (shared with hint_cache and the
# planner); re-exported here for the many `from .fs import split_path` users

def format_fs(store: MetadataStore) -> None:
    """Create the root inode and the id sequence rows."""
    store.table("inode").put(make_inode(ROOT_ID, 0, "", True))
    store.table("id_seq").put({"seq_name": "inode", "next": ROOT_ID + 1})
    store.table("id_seq").put({"seq_name": "block", "next": 1})


class IdAllocator:
    """Namenodes grab id blocks from the DB (one write per `block` ids), so
    id allocation is neither a bottleneck nor a source of txn conflicts."""

    def __init__(self, store: MetadataStore, seq: str, block: int = 1000):
        self.store, self.seq, self.block = store, seq, block
        self._next = 0
        self._limit = 0
        self._mu = threading.Lock()

    def next_id(self) -> int:
        with self._mu:
            if self._next >= self._limit:
                t = self.store.table("id_seq")
                row = dict(t.get((self.seq,)))
                self._next = row["next"]
                self._limit = row["next"] + self.block
                row["next"] = self._limit
                t.put(row)
            v = self._next
            self._next += 1
            return v


# file-related table groups per op (Table 3's ``f_s == 0 ? a : b`` PPIS sets)
_PPIS_CREATE_EMPTY = ("block", "inv")
_PPIS_CREATE_FULL = ("block", "replica", "urb", "prb", "ruc", "cr", "er", "inv")
_PPIS_READ_EMPTY = ("block",)
_PPIS_READ_FULL = ("block", "replica", "cr", "ruc", "er")
_PPIS_DEL_EMPTY = ("block", "inv")
_PPIS_DEL_FULL = ("block", "replica", "urb", "prb", "ruc", "cr", "inv")
_PPIS_ADDBLK_EMPTY = ("block", "ruc")
_PPIS_ADDBLK_FULL = ("block", "replica", "urb", "prb", "ruc", "inv")
_PPIS_TRUNC = ("block", "replica", "ruc", "inv")


class HopsFSOps:
    """Inode (single-file/dir) operations for one namenode.

    ``use_cache`` / ``distribution_aware`` / ``adp`` toggles reproduce the
    Fig 12/13 ablations (ADP off => file-related scans cannot be pruned and
    degrade to all-shard index scans).
    """

    def __init__(self, store: MetadataStore, namenode_id: int = 0, *,
                 use_cache: bool = True, distribution_aware: bool = True,
                 adp: bool = True,
                 is_nn_alive: Optional[Callable[[int], bool]] = None,
                 lease_now: Optional[Callable[[], int]] = None,
                 lease_limit: int = 3,
                 lease_soft_limit: Optional[int] = None):
        self.store = store
        self.nn_id = namenode_id
        self.cache: Optional[InodeHintCache] = (
            InodeHintCache() if use_cache else None)
        self.dat = distribution_aware
        self.adp = adp
        self.inode_ids = IdAllocator(store, "inode")
        self.block_ids = IdAllocator(store, "block")
        self.clock = itertools.count(1)
        # liveness oracle for subtree-lock reclaim (§6.2); defaults to
        # "only me is alive" for single-NN tests
        self._is_nn_alive = is_nn_alive or (lambda nn: nn == self.nn_id)
        # lease clock: client liveness is measured against the SAME logical
        # clock the leader election uses (a Namenode wires this to
        # election.now); a lease not renewed for > lease_limit ticks is
        # expired and reclaimable by the leader. The standalone default
        # (constant 0) never expires leases, keeping single-NN tests inert.
        self._lease_now = lease_now or (lambda: 0)
        self.lease_limit = lease_limit
        # HDFS recoverLease semantics: past the SOFT limit a NEW writer
        # may force takeover (append / recover_lease); only past the HARD
        # limit (lease_limit) does the leader's sweep reclaim. Defaults to
        # the hard limit, i.e. no takeover window, the pre-soft behaviour.
        self.lease_soft_limit = (lease_limit if lease_soft_limit is None
                                 else min(lease_soft_limit, lease_limit))
        # treeagg kernel telemetry: fused du/content aggregation launches
        # on the columnar backend (dict stores never launch)
        self.treeagg_launches = 0
        self.treeagg_demotions = 0

    # ------------------------------------------------------------------
    # transaction / lock-phase helpers
    # ------------------------------------------------------------------
    def _begin(self, pkey: Any) -> Transaction:
        return Transaction(self.store, partition_hint=("inode", pkey),
                           distribution_aware=self.dat)

    def _hint_for(self, comps: Sequence[str], *, parent: bool) -> Any:
        """Partition-key hint for the transaction (Fig 4 line 2): the
        file's inode id for file ops (file-related rows live there), the
        parent's id for namespace-mutating ops."""
        if self.cache is None:
            return ROOT_ID
        v = self.cache.last_resolved_id(comps[:-1] if parent else comps)
        return v if v is not None else ROOT_ID

    def _file_scan(self, txn: Transaction, tables: Sequence[str],
                   inode_id: int, lock: str = READ_COMMITTED
                   ) -> Dict[str, List[Dict[str, Any]]]:
        out = {}
        for tname in tables:
            if self.adp:
                out[tname] = txn.ppis(tname, "inode_id", inode_id, lock)
            else:
                out[tname] = txn.index_scan(tname, "inode_id", inode_id, lock)
        return out

    def _children(self, txn: Transaction, dir_id: int,
                  lock: str = READ_COMMITTED) -> List[Dict[str, Any]]:
        """Directory listing scan: partition-pruned because inodes are
        partitioned by parent_id (the paper's headline ADP win, §4.2)."""
        if self.adp:
            return txn.ppis("inode", "parent_id", dir_id, lock)
        return txn.index_scan("inode", "parent_id", dir_id, lock)

    def _check_subtree_lock(self, row: Dict[str, Any],
                            txn: Transaction) -> None:
        owner = row.get("subtree_lock")
        if owner is None:
            return
        if self._is_nn_alive(owner) and owner != self.nn_id:
            raise SubtreeLockedError(
                f"inode {row['id']} subtree-locked by NN {owner}")
        if owner != self.nn_id:
            fixed = dict(row)
            fixed["subtree_lock"] = None          # reclaim from dead NN §6.2
            txn.write("inode", fixed)
            row["subtree_lock"] = None

    # ------------------------------------------------------------------
    # lease table helpers (§4.1 lease/lease_path; HDFS single-writer rule)
    # ------------------------------------------------------------------
    def lease_write(self, txn: Transaction, client: str,
                    inode_id: int) -> None:
        """Acquire/renew ``client``'s lease on a file inside the current
        transaction: one lease row per holder (renewal timestamp against
        the shared liveness clock) plus one lease_path row per file under
        construction. Shared by the sequential handlers AND the grouped
        write path (create/append), so the two cannot diverge."""
        txn.write("lease", {"holder": client,
                            "last_renewed": self._lease_now()})
        txn.write("lease_path", {"inode_id": inode_id, "holder": client})

    def _lease_live(self, row: Optional[Dict[str, Any]]) -> bool:
        """A lease is live iff it exists and was renewed within
        ``lease_limit`` liveness ticks — the client analogue of the
        namenode heartbeat rule (leader.py)."""
        return (row is not None
                and self._lease_now() - row.get("last_renewed", 0)
                <= self.lease_limit)

    def _lease_live_soft(self, row: Optional[Dict[str, Any]]) -> bool:
        """Soft-limit liveness: within ``lease_soft_limit`` ticks the
        holder is protected even from takeover ops; between the soft and
        hard limits a NEW writer may force recovery (append's takeover,
        :meth:`recover_lease`) while the leader's sweep still waits for
        the hard limit — HDFS's soft/hard lease split."""
        return (row is not None
                and self._lease_now() - row.get("last_renewed", 0)
                <= self.lease_soft_limit)

    def _check_lease(self, txn: Transaction, target: Dict[str, Any],
                     client: str, path: str, *,
                     takeover: bool = False) -> None:
        """Block-write admission: a file under construction by ANOTHER
        client conflicts. Only a ``takeover`` op (append, which acquires
        the lease itself via :meth:`lease_write`) may proceed once the
        holder's lease expired; non-takeover block ops (add_block/
        complete_block) never write under another client's inode — they
        wait for the leader's recovery sweep to clear the holder, so an
        expired lease can't silently admit two concurrent writers. Reads
        go through the transaction cache (charge-free peek), so grouped
        and sequential execution observe identical lease state."""
        holder = target.get("client")
        if not target.get("under_construction") or holder in (None, client):
            return
        if not takeover \
                or self._lease_live_soft(txn.peek("lease", (holder,))):
            raise LeaseConflict(f"{path}: lease held by {holder!r}")

    def renew_lease(self, *, client: str = "client") -> OpResult:
        """Client heartbeat: one bounded-time lease-row write, exactly the
        namenode liveness pattern of leader.py applied to writers."""
        with Transaction(self.store, partition_hint=("lease", client),
                         distribution_aware=self.dat) as txn:
            txn.read("lease", (client,), EXCLUSIVE)
            txn.write("lease", {"holder": client,
                                "last_renewed": self._lease_now()})
            cost = txn.commit()
        return OpResult(None, cost)

    def touch_lease(self, client: str) -> bool:
        """Piggybacked lease renewal (the HDFS lease-manager semantics,
        ROADMAP PR-4 follow-up): ANY registered op executed by a live
        lease holder refreshes its stamp, so a steadily-writing client
        never needs a bare ``renew_lease`` heartbeat to survive the
        leader's recovery sweep. Renewal rides the RPC, not the op's
        transaction — a charge-free row touch (Table-3 round-trip
        profiles unchanged) — but it DOES take the lease row's exclusive
        lock, so it serializes against :meth:`lease_recover`'s
        under-lock liveness re-check: a touch either lands before the
        reclaim (recovery then sees a live stamp and skips) or waits
        until the reclaim committed (the row is gone and the touch is a
        no-op — the holder's next create/append re-leases). Returns
        False when ``client`` holds no lease."""
        t = self.store.table("lease")
        if t.get((client,)) is None:
            return False
        txn_id = self.store.next_txn_id()
        try:
            try:
                self.store.locks.acquire(txn_id, "lease", (client,),
                                         EXCLUSIVE)
            except StoreError:
                # renewal is best-effort: the op itself already succeeded,
                # so a lock-wait timeout must not convert it into an error
                # — the holder's next op (or bare renew_lease) renews
                return False
            row = t.get((client,))       # re-read under the lock
            if row is None:
                return False             # reclaimed while we waited
            row = dict(row)
            row["last_renewed"] = self._lease_now()
            t.put(row)
            return True
        finally:
            self.store.locks.release_all(txn_id)

    def expired_lease_holders(self) -> List[str]:
        """Holders whose lease outlived ``lease_limit`` liveness ticks —
        the leader's lease-recovery work list."""
        rows = self.store.table("lease").scan_all(
            lambda r: not self._lease_live(r))
        return sorted(r["holder"] for r in rows)

    def lease_recover(self, holder: str) -> OpResult:
        """Reclaim one dead client's lease (leader housekeeping; the lease
        analogue of §6.2's subtree-lock reclaim): clear under-construction
        state on every file the holder leased, drop its lease_path rows
        (partition-pruned — lease_path is partitioned by holder), then
        drop the lease row itself. Liveness is RE-CHECKED under the lease
        row's exclusive lock immediately before the reclaim commits: a
        holder that renewed between the leader's ``expired_lease_holders``
        scan and this transaction (e.g. a piggybacked ``touch_lease`` from
        an in-flight op) keeps its lease — the transaction ABORTS,
        discarding every cached write. The lease lock is taken LAST, after
        the inode rows, preserving the FS layer's inode-before-lease
        acquisition order (``lease_write`` in every writer's txn), so the
        re-check cannot deadlock against an in-flight create/append."""
        with Transaction(self.store, partition_hint=("lease_path", holder),
                         distribution_aware=self.dat) as txn:
            lps = txn.ppis("lease_path", "holder", holder, EXCLUSIVE)
            for lp in lps:
                for row in txn.index_scan("inode", "id", lp["inode_id"],
                                          EXCLUSIVE):
                    if row.get("client") == holder:
                        fixed = dict(row)
                        fixed["under_construction"] = False
                        fixed["client"] = None
                        txn.write("inode", fixed)
                txn.delete("lease_path", (lp["inode_id"],))
            row = txn.read("lease", (holder,), EXCLUSIVE)
            if self._lease_live(row):
                # renewed since the scan: abort (writes above were only
                # cached, nothing flushed) — not reclaimed (value None)
                cost = txn.cost.copy()
                txn.abort()
                return OpResult(None, cost)
            txn.delete("lease", (holder,))
            cost = txn.commit()
        return OpResult(len(lps), cost)

    def scrub_leases(self) -> OpResult:
        """Leader housekeeping: drop lease_path rows whose file is gone.
        The HDFS LeaseManager removes a path entry the moment its file is
        deleted; this model defers the removal to a housekeeping sweep so
        the delete transaction keeps its Table-3 round-trip profile.
        Returns the number of rows scrubbed."""
        with Transaction(self.store, partition_hint=("lease_path", "client"),
                         distribution_aware=self.dat) as txn:
            scrubbed = 0
            for lp in txn.full_scan("lease_path", lambda r: True):
                if txn.index_scan("inode", "id", lp["inode_id"]):
                    continue                      # file still exists
                txn.read("lease_path", (lp["inode_id"],), EXCLUSIVE)
                txn.delete("lease_path", (lp["inode_id"],))
                scrubbed += 1
            cost = txn.commit()
        return OpResult(scrubbed, cost)

    def recover_lease(self, path: str, *, client: str = "client"
                      ) -> OpResult:
        """Client-initiated lease recovery (the HDFS ``recoverLease`` RPC):
        a NEW writer forces recovery of ``path``'s expired lease instead
        of waiting for the leader's sweep.  Admission mirrors ``append``'s
        takeover rule — the holder's lease must have outlived the SOFT
        limit (``lease_soft_limit`` liveness ticks without renewal, which
        may be shorter than the hard ``lease_limit`` the leader's sweep
        honours); a holder inside the soft limit raises
        :class:`LeaseConflict`.  Lock order matches every
        other writer (inode first, the holder's lease row LAST), so the
        under-lock liveness re-check serializes against the holder's own
        piggybacked renewals exactly like ``lease_recover``.  Returns True
        when the lease was recovered, False when there was nothing to
        recover (not under construction, or already ours)."""
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=EXCLUSIVE, path=path,
                aux=(("lease", lambda p, t:
                      ((t.get("client") or client,) if t else None),
                      READ_COMMITTED),))
            target = rp.target
            if target is None or target["is_dir"]:
                raise FileNotFound(path)
            holder = target.get("client")
            if not target.get("under_construction") \
                    or holder in (None, client):
                cost = txn.commit()
                return OpResult(False, cost)
            # clear the file's writer state (cached until commit)
            fixed = dict(target)
            fixed["under_construction"] = False
            fixed["client"] = None
            txn.write("inode", fixed)
            txn.delete("lease_path", (target["id"],))
            # holder's lease row X-locked LAST: the soft-limit check runs
            # under the lock, so a concurrent renewal wins cleanly
            row = txn.read("lease", (holder,), EXCLUSIVE)
            if self._lease_live_soft(row):
                cost = txn.cost.copy()
                txn.abort()
                raise LeaseConflict(
                    f"{path}: lease held by {holder!r} is still live")
            others = [lp for lp in txn.ppis("lease_path", "holder", holder,
                                            READ_COMMITTED)
                      if lp["inode_id"] != target["id"]]
            if row is not None and not others:
                txn.delete("lease", (holder,))    # last path: drop holder
            cost = txn.commit()
        return OpResult(True, cost)

    def _resolve(self, txn: Transaction, comps: Sequence[str], *,
                 last_lock: str, lock_parent: bool = False,
                 revalidate: bool = False, lock_last_in_batch: bool = False,
                 aux: Sequence[Tuple[str, Callable[[int, Optional[Dict]],
                                                   Optional[Tuple]], str]] = (),
                 path: str = "") -> ResolvedPath:
        """Lock phase (Fig 4 lines 1-5) with Table-3 batching conventions.

        cache hit : one batch validates the ancestors at read-committed
                    (optionally locking the target in the same batch for
                    ``lock_last_in_batch`` ops like addBlock); mutating ops
                    lock (parent, target) in a second batch; every ``aux``
                    read (lease/quota checks) is its own batch.
        cache miss: recursive single reads over the path (+ an under-lock
                    revalidation pass for mutating ops); all ``aux`` reads
                    fold into ONE batch (together with the target lock for
                    ``lock_last_in_batch``); the (parent, target) lock batch
                    stays separate.

        ``aux`` entries are (table, pk_fn(parent_id, target_row) -> pk|None,
        lock); a None pk skips the read.
        """
        path = path or "/" + "/".join(comps)
        if not comps:
            row = txn.read("inode", (0, ""), last_lock or SHARED)
            if row is None:
                raise FileNotFound("/")
            for tname, pk_fn, lk in aux:
                pk = pk_fn(ROOT_ID, row)
                if pk is not None:
                    with txn.batch() as b:
                        b.read(tname, pk, lk)
            return ResolvedPath([], row, row, True)

        pks = self.cache.resolve_pks(comps) if self.cache else None
        ancestors: List[Dict[str, Any]] = []
        hit = False
        parent_pk: Tuple[int, str] = (0, "")     # PK of the parent inode
        parent_id = ROOT_ID
        target: Optional[Dict[str, Any]] = None
        target_read = False
        if pks is not None:
            anc_pks = pks[:-1]
            with txn.batch() as b:
                got = [b.read("inode", pk, READ_COMMITTED)
                       for pk in anc_pks]
                ok = all(g is not None for g in got)
                if ok:
                    parent = ROOT_ID
                    for pk, g in zip(anc_pks, got):
                        if pk[0] != parent:
                            ok = False
                            break
                        parent = g["id"]
                if ok and lock_last_in_batch:
                    pid = got[-1]["id"] if got else ROOT_ID
                    target = b.read("inode", (pid, comps[-1]), last_lock)
                    target_read = True
            if ok:
                ancestors = list(got)
                hit = True
                for row in ancestors:
                    self._check_subtree_lock(row, txn)
                if ancestors:
                    parent_pk = anc_pks[-1]
                    parent_id = ancestors[-1]["id"]
            else:
                for pk in anc_pks:
                    self.cache.invalidate(*pk)
                pks = None
                target, target_read = None, False
        if pks is None:
            # Recursive resolution, repairing the cache. Mutating ops
            # (revalidate=True) re-read the chain once more under the
            # protection of the locks they are about to take; when the lock
            # batch itself re-reads the parent (lock_parent), the final pass
            # stops one component earlier.
            chain1 = comps[:-2] if (lock_parent and not revalidate) \
                else comps[:-1]
            parent = ROOT_ID
            for name in chain1:
                row = txn.read("inode", (parent, name), READ_COMMITTED)
                if row is None:
                    raise FileNotFound(path)
                self._check_subtree_lock(row, txn)
                if self.cache:
                    self.cache.put(parent, name, row["id"])
                ancestors.append(row)
                parent = row["id"]
            if revalidate:
                chain2 = comps[:-2] if lock_parent else comps[:-1]
                p2 = ROOT_ID
                for name in chain2:
                    row = txn.read("inode", (p2, name), READ_COMMITTED)
                    if row is None:
                        raise FileNotFound(path)
                    p2 = row["id"]
            # derive the parent PK from what was resolved
            if len(comps) == 1:
                parent_pk, parent_id = (0, ""), ROOT_ID
            elif lock_parent:
                gp = ancestors[len(comps) - 3]["id"] if len(comps) >= 3 \
                    else ROOT_ID
                parent_pk = (gp, comps[-2])
                existing = self.store.table("inode").get(parent_pk)
                if existing is None:
                    raise FileNotFound(path)
                parent_id = existing["id"]
            else:
                parent_pk = (ancestors[-1]["parent_id"],
                             ancestors[-1]["name"])
                parent_id = ancestors[-1]["id"]

        # ---- lock batch(es) + aux reads ---------------------------------
        parent_row: Optional[Dict[str, Any]] = None
        if lock_parent:
            got2 = txn.read_batch([("inode", parent_pk, EXCLUSIVE),
                                   ("inode", (parent_id, comps[-1]),
                                    last_lock)])
            parent_row, target = got2[0], got2[1]
            target_read = True
            if parent_row is None:
                raise FileNotFound(path)
        elif not target_read:
            if hit:
                target = txn.read("inode", (parent_id, comps[-1]), last_lock)
                target_read = True
            # miss + lock_last_in_batch: target joins the folded aux batch
        if parent_row is None:
            parent_row = (ancestors[-1] if len(comps) >= 2
                          else self.store.table("inode").get((0, "")))

        if hit:
            if aux:
                for tname, pk_fn, lk in aux:
                    pk = pk_fn(parent_id, target)
                    if pk is not None:
                        with txn.batch() as b:
                            b.read(tname, pk, lk)
        else:
            fold_target = lock_last_in_batch and not target_read
            if not fold_target and not target_read:
                target = txn.read("inode", (parent_id, comps[-1]), last_lock)
                target_read = True
            if aux or fold_target:
                with txn.batch() as b:
                    if fold_target:
                        target = b.read("inode", (parent_id, comps[-1]),
                                        last_lock)
                        target_read = True
                    for tname, pk_fn, lk in aux:
                        pk = pk_fn(parent_id, target)
                        if pk is not None:
                            b.read(tname, pk, lk)
        if not target_read:
            target = txn.read("inode", (parent_id, comps[-1]), last_lock)

        self._check_subtree_lock(parent_row, txn)
        if target is not None:
            self._check_subtree_lock(target, txn)
            if self.cache:
                self.cache.put(parent_id, comps[-1], target["id"])
        return ResolvedPath(ancestors, parent_row, target, hit)

    # ==================================================================
    # operations
    # ==================================================================
    # -- execute-phase apply helpers, shared with the grouped WRITE path
    # -- (namenode._write_group_txn) so batched and sequential mutations
    # -- cannot diverge: every check precedes the first txn.write, and all
    # -- shared-row reads (quota) go through the cache-aware txn.peek
    def mkdir_apply(self, txn: Transaction, parent: Dict[str, Any],
                    target: Optional[Dict[str, Any]], name: str,
                    path: str, *, perm: int = 0o755) -> int:
        if target is not None:
            raise FileAlreadyExists(path)
        if not parent["is_dir"]:
            raise FSError(f"not a directory: parent of {path}")
        new_id = self.inode_ids.next_id()
        txn.write("inode", make_inode(new_id, parent["id"], name, True,
                                      perm=perm, mtime=next(self.clock)))
        parent = dict(parent)
        parent["mtime"] = next(self.clock)
        txn.write("inode", parent)
        if self.cache:
            self.cache.put(parent["id"], name, new_id)
        return new_id

    def mkdir(self, path: str, *, perm: int = 0o755) -> OpResult:
        comps = split_path(path)
        if not comps:
            raise FileAlreadyExists("/")
        with self._begin(self._hint_for(comps, parent=True)) as txn:
            rp = self._resolve(txn, comps, last_lock=EXCLUSIVE,
                               lock_parent=True, path=path)
            new_id = self.mkdir_apply(txn, rp.parent, rp.target, comps[-1],
                                      path, perm=perm)
            cost = txn.commit()
        return OpResult(new_id, cost)

    def mkdirs(self, path: str, **kw) -> OpResult:
        """mkdir -p; cost = sum of constituent mkdirs."""
        comps = split_path(path)
        agg = OpCost()
        last = None
        for i in range(1, len(comps) + 1):
            sub = "/" + "/".join(comps[:i])
            try:
                r = self.mkdir(sub, **kw)
                agg.merge(r.cost)
                last = r.value
            except FileAlreadyExists:
                continue
        return OpResult(last, agg)

    def create_apply(self, txn: Transaction, parent: Dict[str, Any],
                     target: Optional[Dict[str, Any]], name: str,
                     path: str, *, repl: int = 3, client: str = "client",
                     overwrite: bool = False) -> int:
        if target is not None and not overwrite:
            raise FileAlreadyExists(path)
        if not parent["is_dir"]:
            raise FSError(f"not a directory: parent of {path}")
        fid = (target["id"] if target is not None
               else self.inode_ids.next_id())
        tables = (_PPIS_CREATE_FULL
                  if target is not None and target["size"] > 0
                  else _PPIS_CREATE_EMPTY)
        related = self._file_scan(txn, tables, fid, EXCLUSIVE)
        if target is not None:  # overwrite: clear old file metadata
            for tname, rws in related.items():
                schema = self.store.table(tname).schema
                for r in rws:
                    txn.delete(tname, tuple(r[c] for c in schema.pk))
        txn.write("inode", make_inode(fid, parent["id"], name,
                                      False, repl=repl,
                                      mtime=next(self.clock),
                                      client=client))
        parent2 = dict(parent)
        parent2["mtime"] = next(self.clock)
        txn.write("inode", parent2)
        self.lease_write(txn, client, fid)
        q = txn.peek("quota", (parent["id"],))
        qrow = dict(q) if q else {"inode_id": parent["id"],
                                  "ns_quota": -1, "ns_used": 0,
                                  "ss_quota": -1, "ss_used": 0}
        qrow["ns_used"] = qrow.get("ns_used", 0) + 1
        txn.write("quota", qrow)
        if self.cache:
            self.cache.put(parent["id"], name, fid)
        return fid

    def create(self, path: str, *, repl: int = 3, client: str = "client",
               overwrite: bool = False) -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=True)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=EXCLUSIVE, lock_parent=True,
                revalidate=True, path=path,
                aux=(("lease", lambda p, t: (client,), READ_COMMITTED),
                     ("quota", lambda p, t: (p,), READ_COMMITTED)))
            fid = self.create_apply(txn, rp.parent, rp.target, comps[-1],
                                    path, repl=repl, client=client,
                                    overwrite=overwrite)
            cost = txn.commit()
        return OpResult(fid, cost)

    # -- block-write apply helpers, shared with the grouped WRITE path
    # -- (the lease-ordered block path): every admission check (existence,
    # -- lease conflict) precedes the first txn.write, and lease state is
    # -- read through the charge-free txn.peek so grouped and sequential
    # -- execution observe identical state
    def add_block_apply(self, txn: Transaction,
                        target: Optional[Dict[str, Any]], path: str, *,
                        client: str = "client") -> int:
        if target is None or target["is_dir"]:
            raise FileNotFound(path)
        self._check_lease(txn, target, client, path)
        tables = (_PPIS_ADDBLK_EMPTY if target["size"] == 0
                  else _PPIS_ADDBLK_FULL)
        related = self._file_scan(txn, tables, target["id"], EXCLUSIVE)
        blocks = related.get("block", [])
        # finalize/inspect the penultimate block: 1 PK_r
        prev_pk = (max(blocks, key=lambda b: b["index"])["block_id"],) \
            if blocks else (-1,)
        txn.read("block", prev_pk, SHARED)
        bid = self.block_ids.next_id()
        # only the block row is written here; the replica-under-
        # construction rows appear when the datanode write pipeline
        # starts (complete_block), matching Table 3's single PK_w
        txn.write("block", make_block(bid, target["id"], len(blocks)))
        return bid

    def add_block(self, path: str, *,
                  client: str = "client") -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=EXCLUSIVE, lock_last_in_batch=True,
                path=path,
                aux=(("lease", lambda p, t:
                      ((t.get("client") or "client",) if t else None),
                      READ_COMMITTED),))
            bid = self.add_block_apply(txn, rp.target, path, client=client)
            cost = txn.commit()
        return OpResult(bid, cost)

    def complete_block_apply(self, txn: Transaction,
                             target: Optional[Dict[str, Any]], path: str, *,
                             block_id: int = -1, size: int,
                             datanodes: Sequence[int] = (0, 1, 2),
                             client: str = "client") -> None:
        if target is None or target["is_dir"]:
            raise FileNotFound(path)
        self._check_lease(txn, target, client, path)
        if block_id is None or block_id < 0:
            # "the last allocated block" — lets trace records complete
            # blocks whose ids were allocated at replay time
            blocks = self._file_scan(txn, ("block",), target["id"],
                                     EXCLUSIVE).get("block", [])
            if not blocks:
                raise FileNotFound(f"no block to complete in {path}")
            block_id = max(blocks, key=lambda b: b["index"])["block_id"]
        blk = txn.read("block", (block_id,), EXCLUSIVE)
        if blk is None:
            raise FileNotFound(f"block {block_id}")
        blk = dict(blk)
        blk["size"], blk["state"] = size, "COMPLETE"
        txn.write("block", blk)
        rucs = self._file_scan(txn, ("ruc",), target["id"],
                               EXCLUSIVE)["ruc"]
        for r in rucs:
            if r["block_id"] == block_id:
                txn.delete("ruc", (r["block_id"], r["datanode_id"]))
        for dn in datanodes[:target["repl"]]:
            txn.write("replica", make_replica(block_id, target["id"], dn))
        f = dict(target)
        f["size"] += size
        txn.write("inode", f)
        return None

    def complete_block(self, path: str, block_id: int = -1, *, size: int,
                       datanodes: Sequence[int] = (0, 1, 2),
                       client: str = "client") -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(txn, comps, last_lock=EXCLUSIVE, path=path)
            self.complete_block_apply(txn, rp.target, path,
                                      block_id=block_id, size=size,
                                      datanodes=datanodes, client=client)
            cost = txn.commit()
        return OpResult(None, cost)

    # -- read-op payload phases, shared with the batched pipeline so the
    # -- two execution paths cannot diverge (namenode._complete_read_op)
    def read_payload(self, txn: Transaction,
                     target: Dict[str, Any]) -> List[Dict[str, Any]]:
        tables = (_PPIS_READ_EMPTY if target["size"] == 0
                  else _PPIS_READ_FULL)
        related = self._file_scan(txn, tables, target["id"], READ_COMMITTED)
        blocks = sorted(related.get("block", []), key=lambda b: b["index"])
        reps = related.get("replica", [])
        return [{"block": b["block_id"], "size": b["size"],
                 "locations": [r["datanode_id"] for r in reps
                               if r["block_id"] == b["block_id"]]}
                for b in blocks]

    @staticmethod
    def stat_payload(target: Dict[str, Any]) -> Dict[str, Any]:
        return {k: target[k] for k in ("id", "is_dir", "perm", "owner",
                                       "group", "size", "repl", "mtime")}

    def listing_payload(self, txn: Transaction,
                        target: Dict[str, Any]) -> List[str]:
        if not target["is_dir"]:
            return []
        return sorted(c["name"]
                      for c in self._children(txn, target["id"], SHARED))

    def get_block_locations(self, path: str) -> OpResult:
        """The `read` op of Table 1/3 (68.7% of the Spotify workload)."""
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=SHARED, path=path,
                aux=(("lease", lambda p, t:
                      ((t.get("client") or "client",) if t else None),
                      READ_COMMITTED),))
            f = rp.target
            if f is None:
                raise FileNotFound(path)
            locs = self.read_payload(txn, f)
            cost = txn.commit()
        return OpResult(locs, cost)

    read = get_block_locations

    def listing(self, path: str) -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(txn, comps, last_lock=SHARED, path=path)
            node = rp.target
            if node is None:
                raise FileNotFound(path)
            names = self.listing_payload(txn, node)
            cost = txn.commit()
        return OpResult(names, cost)

    def stat(self, path: str) -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=SHARED, path=path,
                aux=(("lease", lambda p, t:
                      ((t.get("client") or "client",) if t else None),
                      READ_COMMITTED),))
            node = rp.target
            if node is None:
                raise FileNotFound(path)
            info = self.stat_payload(node)
            cost = txn.commit()
        return OpResult(info, cost)

    info = stat

    def setattr_apply(self, txn: Transaction,
                      node: Optional[Dict[str, Any]], path: str,
                      mutate: Callable[[Dict[str, Any]], None]) -> None:
        if node is None:
            raise FileNotFound(path)
        if node["is_dir"]:
            # no active subtree op may exist below: all-shard IS on the
            # subtree-ops table (Table 3: "i is a dir ? IS : PPIS")
            txn.index_scan("ongoing_subtree_ops", "namenode_id",
                           self.nn_id)
        else:
            self._file_scan(txn, ("block",), node["id"], READ_COMMITTED)
        node = dict(node)
        mutate(node)
        node["mtime"] = next(self.clock)
        txn.write("inode", node)
        q = txn.peek("quota", (node["parent_id"],))
        txn.write("quota", dict(q) if q else
                  {"inode_id": node["parent_id"], "ns_quota": -1,
                   "ns_used": 0, "ss_quota": -1, "ss_used": 0})
        return None

    def _simple_update(self, path: str,
                       mutate: Callable[[Dict[str, Any]], None]) -> OpResult:
        """chmod/chown/setrepl on FILES (and the phase-3 root-only update for
        directory subtree ops — see subtree.py)."""
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=EXCLUSIVE, revalidate=True, path=path,
                aux=(("lease", lambda p, t:
                      ((t.get("client") or "client",) if t else None),
                      READ_COMMITTED),
                     ("quota", lambda p, t: (p,), READ_COMMITTED)))
            self.setattr_apply(txn, rp.target, path, mutate)
            cost = txn.commit()
        return OpResult(None, cost)

    def chmod_file(self, path: str, perm: int) -> OpResult:
        return self._simple_update(path, lambda n: n.update(perm=perm))

    def chown_file(self, path: str, owner: str) -> OpResult:
        return self._simple_update(path, lambda n: n.update(owner=owner))

    def set_replication(self, path: str, repl: int) -> OpResult:
        return self._simple_update(path, lambda n: n.update(repl=repl))

    def delete_file(self, path: str) -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=True)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=EXCLUSIVE, lock_parent=True,
                revalidate=True, path=path,
                aux=(("lease", lambda p, t:
                      ((t.get("client") or "client",) if t else None),
                      READ_COMMITTED),
                     ("quota", lambda p, t: (p,), READ_COMMITTED)))
            node = rp.target
            if node is None:
                raise FileNotFound(path)
            if node["is_dir"]:
                raise FSError("use subtree delete for directories")
            tables = _PPIS_DEL_EMPTY if node["size"] == 0 else _PPIS_DEL_FULL
            related = self._file_scan(txn, tables, node["id"], EXCLUSIVE)
            for tname, rws in related.items():
                schema = self.store.table(tname).schema
                for r in rws:
                    txn.delete(tname, tuple(r[c] for c in schema.pk))
            txn.delete("inode", (node["parent_id"], node["name"]))
            parent = dict(rp.parent)
            parent["mtime"] = next(self.clock)
            txn.write("inode", parent)
            if self.cache:
                self.cache.invalidate(node["parent_id"], node["name"])
            cost = txn.commit()
        return OpResult(None, cost)

    def content_summary(self, path: str) -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=SHARED, path=path,
                aux=(("quota", lambda p, t:
                      ((t["id"],) if t else None), READ_COMMITTED),))
            node = rp.target
            if node is None:
                raise FileNotFound(path)
            n_children = 0
            if node["is_dir"]:
                n_children = len(self._children(txn, node["id"]))
            cost = txn.commit()
        return OpResult({"children": n_children, "size": node["size"]}, cost)

    def _expand_wave_fused(self, wave: Sequence[int]) -> Optional[Any]:
        """Columnar-only fused wave expansion for deep aggregation: one
        ``kernels.treeagg`` launch resolves a whole BFS wave's children
        and segment sums.  None on the dict backend / below the gate."""
        try:
            from .columnar import expand_wave
        except Exception:                    # pragma: no cover - import guard
            return None
        exp = expand_wave(self.store, wave)
        if exp is None:
            return None
        if exp.used:
            self.treeagg_launches += 1
        else:
            self.treeagg_demotions += 1
        return exp

    def du(self, path: str) -> OpResult:
        """Deep content summary (HDFS ``du -s``): inode/file/dir counts
        and total size over the WHOLE subtree, not just the immediate
        children :meth:`content_summary` reports.

        The walk is wave-by-wave BFS.  On the dict backend each wave is a
        transaction of READ_COMMITTED partition-pruned child scans (one
        PPIS per directory, §4.2).  On the columnar backend each wave is
        instead ONE fused treeagg launch over the SoA inode columns —
        still charged as the wave's PPIS fan-out plus a single batched
        exchange, with the touched rows mirrored into the store's row-op
        ledger, so cost conservation holds without per-row transactions.
        Results are identical across backends; costs intentionally differ
        (that asymmetry IS the kernel's win)."""
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=SHARED, path=path,
                aux=(("quota", lambda p, t:
                      ((t["id"],) if t else None), READ_COMMITTED),))
            node = rp.target
            if node is None:
                raise FileNotFound(path)
            cost = txn.commit()
        if not node["is_dir"]:
            return OpResult({"inodes": 1, "files": 1, "dirs": 0,
                             "size": node["size"]}, cost)
        inodes, files, dirs, size = 1, 0, 1, 0
        wave: List[int] = [node["id"]]
        while wave:
            exp = self._expand_wave_fused(wave)
            if exp is not None:
                n_children = exp.n_children
                nd = int(exp.dirs.sum())
                inodes += n_children
                dirs += nd
                files += n_children - nd
                size += int(exp.sizes.sum())
                cost.ppis += len(wave)
                cost.batches += 1
                cost.batch_rows += n_children
                cost.remote_rt += 1
                cost.rows_touched += n_children
                self.store.total_row_ops += n_children
                wave = [int(i) for i in exp.child_dir_ids]
            else:
                nxt: List[int] = []
                with Transaction(self.store,
                                 partition_hint=("inode", wave[0]),
                                 distribution_aware=self.dat) as txn:
                    for did in wave:
                        for k in self._children(txn, did):
                            inodes += 1
                            if k["is_dir"]:
                                dirs += 1
                                nxt.append(k["id"])
                            else:
                                files += 1
                                size += k["size"]
                    cost.merge(txn.commit())
                wave = nxt
        return OpResult({"inodes": inodes, "files": files, "dirs": dirs,
                         "size": size}, cost)

    def set_quota(self, path: str, *, ns_quota: int = -1,
                  ss_quota: int = -1) -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(txn, comps, last_lock=EXCLUSIVE,
                               revalidate=True, path=path)
            node = rp.target
            if node is None:
                raise FileNotFound(path)
            q = self.store.table("quota").get((node["id"],))
            qrow = dict(q) if q else {"inode_id": node["id"], "ns_used": 0,
                                      "ss_used": 0}
            qrow["ns_quota"], qrow["ss_quota"] = ns_quota, ss_quota
            txn.write("quota", qrow)
            cost = txn.commit()
        return OpResult(None, cost)

    def append_apply(self, txn: Transaction,
                     target: Optional[Dict[str, Any]], path: str, *,
                     client: str = "client") -> int:
        if target is None or target["is_dir"]:
            raise FileNotFound(path)
        self._check_lease(txn, target, client, path, takeover=True)
        tables = (_PPIS_READ_EMPTY if target["size"] == 0
                  else _PPIS_READ_FULL)
        self._file_scan(txn, tables, target["id"], EXCLUSIVE)
        node = dict(target)
        node["under_construction"], node["client"] = True, client
        txn.write("inode", node)
        self.lease_write(txn, client, node["id"])
        return node["id"]

    def append_file(self, path: str, *, client: str = "client") -> OpResult:
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=EXCLUSIVE, path=path,
                aux=(("lease", lambda p, t: (client,), READ_COMMITTED),))
            fid = self.append_apply(txn, rp.target, path, client=client)
            cost = txn.commit()
        return OpResult(fid, cost)

    def rename_file(self, src: str, dst: str) -> OpResult:
        """mv of a FILE. Changing parent changes the composite PK (and the
        shard), hence delete+insert inside one transaction. Directory renames
        go through the subtree protocol (subtree.py)."""
        sc, dc = split_path(src), split_path(dst)
        with self._begin(self._hint_for(sc, parent=True)) as txn:
            # total-order locking over both paths (§5 "Cyclic Deadlocks")
            first, second = (sc, dc) if sc <= dc else (dc, sc)
            r1 = self._resolve(txn, first, last_lock=EXCLUSIVE,
                               lock_parent=True, revalidate=True)
            r2 = self._resolve(txn, second, last_lock=EXCLUSIVE,
                               lock_parent=True)
            srp, drp = (r1, r2) if sc <= dc else (r2, r1)
            snode = srp.target
            if snode is None or snode["is_dir"]:
                raise FileNotFound(src)
            if drp.target is not None:
                raise FileAlreadyExists(dst)
            tables = (_PPIS_READ_EMPTY if snode["size"] == 0
                      else _PPIS_READ_FULL)
            self._file_scan(txn, tables, snode["id"], EXCLUSIVE)
            txn.delete("inode", (snode["parent_id"], snode["name"]))
            moved = dict(snode)
            moved["parent_id"], moved["name"] = drp.parent["id"], dc[-1]
            moved["mtime"] = next(self.clock)
            txn.write("inode", moved)
            dp = dict(drp.parent)
            dp["mtime"] = next(self.clock)
            txn.write("inode", dp)
            if srp.parent["id"] != drp.parent["id"]:
                sp = dict(srp.parent)
                sp["mtime"] = next(self.clock)
                txn.write("inode", sp)
            if self.cache:
                self.cache.invalidate(snode["parent_id"], snode["name"])
                self.cache.put(drp.parent["id"], dc[-1], snode["id"])
            cost = txn.commit()
        return OpResult(None, cost)

    def truncate(self, path: str, new_size: int = 0) -> OpResult:
        """HDFS-style truncate: drop every block fully beyond ``new_size``,
        shrink the boundary block, update the inode size.  Registered purely
        through the op registry — no namenode/DES dispatch edits (the
        extensibility proof for the typed operation protocol)."""
        if new_size < 0:
            raise FSError(f"negative truncate size {new_size}")
        comps = split_path(path)
        with self._begin(self._hint_for(comps, parent=False)) as txn:
            rp = self._resolve(
                txn, comps, last_lock=EXCLUSIVE, revalidate=True, path=path,
                aux=(("lease", lambda p, t:
                      ((t.get("client") or "client",) if t else None),
                      READ_COMMITTED),))
            node = rp.target
            if node is None or node["is_dir"]:
                raise FileNotFound(path)
            if new_size >= node["size"]:
                # nothing to drop; still a (cheap) committed no-op like HDFS
                cost = txn.commit()
                return OpResult({"size": node["size"], "removed_blocks": 0},
                                cost)
            related = self._file_scan(txn, _PPIS_TRUNC, node["id"],
                                      EXCLUSIVE)
            blocks = sorted(related.get("block", []),
                            key=lambda b: b["index"])
            reps = related.get("replica", [])
            removed = 0
            offset = 0
            for b in blocks:
                end = offset + b["size"]
                if offset >= new_size:           # fully beyond: drop block
                    txn.delete("block", (b["block_id"],))
                    for r in reps:
                        if r["block_id"] == b["block_id"]:
                            txn.delete("replica", (r["block_id"],
                                                   r["datanode_id"]))
                            txn.write("inv", {"block_id": b["block_id"],
                                              "datanode_id":
                                              r["datanode_id"],
                                              "inode_id": node["id"]})
                    removed += 1
                elif end > new_size:             # boundary block: shrink
                    nb = dict(b)
                    nb["size"] = new_size - offset
                    txn.write("block", nb)
                offset = end
            node = dict(node)
            node["size"] = new_size
            node["mtime"] = next(self.clock)
            txn.write("inode", node)
            cost = txn.commit()
        return OpResult({"size": new_size, "removed_blocks": removed}, cost)

    def concat(self, target: str, srcs: Sequence[str]) -> OpResult:
        """HDFS-style concat: move every source file's blocks onto the
        target (re-indexed after its existing blocks) and delete the source
        inodes, all in ONE transaction.  Block/replica rows are partitioned
        by inode id (§4.2), so re-owning a block is a delete+insert exactly
        like a rename across parents.  Paths are locked in total order
        (§5 "Cyclic Deadlocks")."""
        if not srcs:
            raise FSError("concat: no source files")
        if target in srcs:
            raise FSError("concat: target cannot be a source")
        if len(set(srcs)) != len(srcs):
            raise FSError("concat: duplicate source")
        tc = split_path(target)
        with self._begin(self._hint_for(tc, parent=False)) as txn:
            resolved: Dict[str, ResolvedPath] = {}
            ordered = sorted([target, *srcs], key=split_path)
            for i, p in enumerate(ordered):
                resolved[p] = self._resolve(txn, split_path(p),
                                            last_lock=EXCLUSIVE,
                                            lock_parent=True,
                                            revalidate=(i == 0), path=p)
            trp = resolved[target]
            tnode = trp.target
            if tnode is None or tnode["is_dir"]:
                raise FileNotFound(target)
            tblocks = sorted(
                self._file_scan(txn, ("block",), tnode["id"],
                                EXCLUSIVE).get("block", []),
                key=lambda b: b["index"])
            next_index = len(tblocks)
            moved = 0
            grown = 0
            touched_parents = {trp.parent["id"]}
            for src in srcs:
                srp = resolved[src]
                snode = srp.target
                if snode is None or snode["is_dir"]:
                    raise FileNotFound(src)
                related = self._file_scan(txn, _PPIS_CREATE_FULL,
                                          snode["id"], EXCLUSIVE)
                # partition-key update: the store relocates each row to the
                # target inode's shard (internal delete+insert, §4.2).
                # EVERY file-related row is re-owned — replica-state rows
                # (urb/prb/ruc/cr/er/inv) included — so deleting the source
                # inode orphans nothing.
                for b in sorted(related.pop("block", []),
                                key=lambda x: x["index"]):
                    nb = dict(b)
                    nb["inode_id"], nb["index"] = tnode["id"], next_index
                    txn.write("block", nb)
                    next_index += 1
                    moved += 1
                for tname, rws in related.items():
                    for r in rws:
                        nr = dict(r)
                        nr["inode_id"] = tnode["id"]
                        txn.write(tname, nr)
                txn.delete("inode", (snode["parent_id"], snode["name"]))
                grown += snode["size"]
                touched_parents.add(srp.parent["id"])
                if self.cache:
                    self.cache.invalidate(snode["parent_id"], snode["name"])
            tnode = dict(tnode)
            tnode["size"] += grown
            tnode["mtime"] = next(self.clock)
            txn.write("inode", tnode)
            for p in ordered:
                prow = resolved[p].parent
                if prow["id"] in touched_parents:
                    touched_parents.discard(prow["id"])
                    pr = dict(prow)
                    pr["mtime"] = next(self.clock)
                    txn.write("inode", pr)
            cost = txn.commit()
        return OpResult({"blocks_moved": moved, "size": tnode["size"]}, cost)

    # ------------------------------------------------------------------
    # block reports (§7.8)
    # ------------------------------------------------------------------
    def process_block_report(self, datanode_id: int,
                             block_ids: Sequence[int],
                             batch: int = 1000) -> OpResult:
        """Validate a datanode's blocks against the metadata: batched PK
        reads of block rows; replicas upserted; unknown blocks invalidated."""
        agg = OpCost()
        for i in range(0, len(block_ids), batch):
            chunk = block_ids[i:i + batch]
            with Transaction(self.store,
                             partition_hint=("block", chunk[0]),
                             distribution_aware=self.dat) as txn:
                got = txn.read_batch([("block", (b,), READ_COMMITTED)
                                      for b in chunk])
                for b, row in zip(chunk, got):
                    if row is None:
                        txn.write("inv", {"block_id": b,
                                          "datanode_id": datanode_id,
                                          "inode_id": -1})
                    else:
                        txn.write("replica", make_replica(
                            b, row["inode_id"], datanode_id))
                agg.merge(txn.commit())
        return OpResult(None, agg)
