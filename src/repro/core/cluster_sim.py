"""Discrete-event simulation of HopsFS and HDFS clusters (paper §7).

One CPU container cannot measure 60-namenode wall-clock throughput, so the
cluster-scale experiments (Figs 6, 8, 9, 10, 11) run on a DES whose per-op
**database round-trip profiles are measured from the functional store**
(``profile_op``), not hand-waved: the functional layer executes the op and
its OpCost (how many PK/batch/PPIS/IS round trips, how many were local to
the transaction coordinator) parameterizes the simulated service times.

Modelled resources
  * namenode handler pool (dfs.namenode.handler.count=100, §7.1) — an op
    holds a handler for its full duration, so DB latency limits NN
    concurrency exactly as in the real system;
  * namenode CPU cores (c3.8xlarge: 32 vcores);
  * NDB datanodes — each round trip queues on one database server; local
    round trips (DAT) are cheaper than remote ones; IS/FTS fan out to all
    nodes (Fig 2a cost hierarchy);
  * for HDFS: the single global namespace RW-lock (single writer) + the
    active namenode's handler pool/CPU; failover downtime per §7.6.1.

Calibration constants approximate the paper's AWS c3.8xlarge testbed; the
benchmark suite checks *relative* claims (scaling shape, 2.6x, crossover,
zero-downtime), not absolute microseconds.
"""
from __future__ import annotations

import heapq
import random
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .fs import HopsFSOps
from .ops_registry import REGISTRY
from .store import MetadataStore, OpCost
from .workload import SpotifyWorkload, WorkloadOp

# ---------------------------------------------------------------------------
# calibration constants (seconds) — AWS c3.8xlarge-ish, virtualized network
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimParams:
    client_nn_rtt: float = 1.0e-3       # client <-> namenode RPC round trip
    nn_cpu_per_op: float = 28e-6        # namenode CPU per metadata op
    nn_handlers: int = 100              # dfs.namenode.handler.count
    nn_cores: int = 32
    db_rtt_local: float = 0.40e-3       # DAL <-> coordinator-local NDB node
    db_rtt_remote: float = 0.62e-3      # DAL <-> remote NDB node group
    # NDB datanodes run 30 worker threads (§7.1); each round trip occupies
    # one thread for the service time below (Fig 2a cost hierarchy)
    ndb_threads: int = 30
    svc_pk: float = 30e-6
    svc_batch: float = 50e-6
    svc_ppis: float = 90e-6
    svc_is_per_node: float = 120e-6     # IS occupies EVERY NDB node
    svc_fts_per_node: float = 500e-6
    ndb_txn_timeout: float = 1.2        # §7.5
    # HDFS
    hdfs_cpu_read: float = 22e-6
    hdfs_cpu_write: float = 70e-6
    hdfs_lock_write_hold: float = 55e-6  # exclusive namespace lock hold
    hdfs_lock_read_hold: float = 9e-6    # shared-path overhead
    failover_detect: float = 2.0
    failover_replay: float = 7.0         # small-metadata test: 8-10 s total


DEFAULT_PARAMS = SimParams()


# ---------------------------------------------------------------------------
# tiny DES core
# ---------------------------------------------------------------------------


class Sim:
    def __init__(self) -> None:
        self.t = 0.0
        self._q: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = 0

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._q, (self.t + dt, self._seq, fn))

    def run(self, until: float) -> None:
        while self._q and self._q[0][0] <= until:
            self.t, _, fn = heapq.heappop(self._q)
            fn()
        self.t = until


class Server:
    """k-server FIFO resource.

    ``submit(hold, done)``  — hold a server for `hold` s, then auto-release.
    ``acquire(granted)``    — grant a server to the caller (who must call
                              ``release()`` when finished); used for
                              resources held across nested waits, e.g. the
                              namenode handler held for the whole op.
    """

    def __init__(self, sim: Sim, k: int):
        self.sim, self.k = sim, k
        self.busy = 0
        self.q: deque = deque()

    # -- held-resource protocol -------------------------------------
    def acquire(self, granted: Callable[[], None]) -> None:
        if self.busy < self.k:
            self.busy += 1
            granted()
        else:
            self.q.append(("acq", granted))

    def release(self) -> None:
        if self.q:
            kind, fn = self.q.popleft()
            if kind == "acq":
                fn()
            else:
                hold, done = fn
                self._hold(hold, done)
        else:
            self.busy -= 1

    # -- auto-release protocol ---------------------------------------
    def submit(self, hold: float, done: Callable[[], None]) -> None:
        if self.busy < self.k:
            self.busy += 1
            self._hold(hold, done)
        else:
            self.q.append(("sub", (hold, done)))

    def _hold(self, hold: float, done: Callable[[], None]) -> None:
        def fin():
            done()
            self.release()
        self.sim.after(hold, fin)


class RWLock:
    """DES readers-writer lock (writer-preferring) — the HDFS global
    namespace lock (§2.1)."""

    def __init__(self, sim: Sim):
        self.sim = sim
        self.readers = 0
        self.writer = False
        self.wq: deque = deque()   # (is_write, hold, done)

    def submit(self, is_write: bool, hold: float,
               done: Callable[[], None]) -> None:
        self.wq.append((is_write, hold, done))
        self._pump()

    def _pump(self) -> None:
        while self.wq:
            is_write, hold, done = self.wq[0]
            if is_write:
                if self.writer or self.readers:
                    return
                self.wq.popleft()
                self.writer = True

                def fin_w(d=done):
                    self.writer = False
                    d()
                    self._pump()
                self.sim.after(hold, fin_w)
            else:
                if self.writer:
                    return
                self.wq.popleft()
                self.readers += 1

                def fin_r(d=done):
                    self.readers -= 1
                    d()
                    self._pump()
                self.sim.after(hold, fin_r)


# ---------------------------------------------------------------------------
# round-trip profiles measured from the functional store
# ---------------------------------------------------------------------------


@dataclass
class RTProfile:
    """Sequence-free summary of one op's DB work."""
    pk: int = 0
    batch: int = 0
    ppis: int = 0
    is_scans: int = 0
    fts: int = 0
    local: int = 0
    remote: int = 0

    @classmethod
    def from_cost(cls, c: OpCost) -> "RTProfile":
        return cls(pk=c.pk_rc + c.pk_r + c.pk_w, batch=c.batches,
                   ppis=c.ppis, is_scans=c.is_scans, fts=c.fts,
                   local=c.local_rt, remote=c.remote_rt)

    def round_trips(self) -> int:
        return self.pk + self.batch + self.ppis + self.is_scans + self.fts


def profile_ops(*, use_cache: bool = True, distribution_aware: bool = True,
                adp: bool = True, depth: int = 7
                ) -> Dict[str, RTProfile]:
    """Execute each Table-1 op once on a small functional deployment and
    capture its measured cost profile for the DES."""
    store = MetadataStore(n_datanodes=4)
    from .fs import format_fs
    format_fs(store)
    ops = HopsFSOps(store, 0, use_cache=use_cache,
                    distribution_aware=distribution_aware, adp=adp)
    d = "/" + "/".join(f"l{i}" for i in range(depth - 1))
    ops.mkdirs(d)
    f = d + "/data.bin"
    ops.create(f)
    bid = ops.add_block(f).value
    ops.complete_block(f, bid, size=1 << 27)
    # warm the cache, then measure steady-state profiles
    ops.get_block_locations(f)
    prof: Dict[str, RTProfile] = {}
    prof["read"] = RTProfile.from_cost(ops.get_block_locations(f).cost)
    prof["stat"] = RTProfile.from_cost(ops.stat(f).cost)
    prof["ls"] = RTProfile.from_cost(ops.listing(d).cost)
    prof["content_summary"] = RTProfile.from_cost(
        ops.content_summary(d).cost)
    prof["create"] = RTProfile.from_cost(ops.create(f + ".new").cost)
    prof["add_block"] = RTProfile.from_cost(ops.add_block(f + ".new").cost)
    prof["append"] = RTProfile.from_cost(ops.append_file(f).cost)
    prof["chmod_file"] = RTProfile.from_cost(ops.chmod_file(f, 0o644).cost)
    prof["chown_file"] = RTProfile.from_cost(ops.chown_file(f, "u").cost)
    prof["set_replication"] = RTProfile.from_cost(
        ops.set_replication(f, 2).cost)
    prof["rename_file"] = RTProfile.from_cost(
        ops.rename_file(f + ".new", f + ".mv").cost)
    prof["delete_file"] = RTProfile.from_cost(ops.delete_file(f + ".mv").cost)
    prof["mkdirs"] = RTProfile.from_cost(ops.mkdir(d + "/sub").cost)
    prof["set_quota"] = RTProfile.from_cost(ops.set_quota(d).cost)
    # subtree ops: profile on a modest directory; DES scales by tree size
    from .subtree import SubtreeOps
    st = SubtreeOps(ops)
    sub = d + "/tree"
    ops.mkdir(sub)
    for i in range(8):
        ops.create(f"{sub}/t{i}")
    prof["chmod_subtree"] = RTProfile.from_cost(
        st.chmod_subtree(sub, 0o700).cost)
    prof["chown_subtree"] = RTProfile.from_cost(
        st.chown_subtree(sub, "u2").cost)
    prof["delete_subtree"] = RTProfile.from_cost(st.delete_subtree(sub).cost)
    prof["rename_subtree"] = prof["chmod_subtree"]
    # block-completion profile (write-heavy mixes): measured on a fresh
    # file so none of the profiles above shift
    f3 = d + "/data3.bin"
    ops.create(f3)
    b3 = ops.add_block(f3).value
    prof["complete_block"] = RTProfile.from_cost(
        ops.complete_block(f3, b3, size=1 << 26).cost)
    prof["renew_lease"] = RTProfile.from_cost(ops.renew_lease().cost)
    return prof


# ---------------------------------------------------------------------------
# cluster models
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    completed: int
    duration: float
    latencies: List[float]
    timeline: List[Tuple[float, int]]    # (second, ops completed in it)

    @property
    def throughput(self) -> float:
        return self.completed / self.duration if self.duration else 0.0

    def latency_avg(self) -> float:
        return sum(self.latencies) / len(self.latencies) \
            if self.latencies else 0.0

    def latency_pct(self, p: float) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        return xs[min(len(xs) - 1, int(p / 100 * len(xs)))]


class HopsFSSim:
    """DES of a HopsFS deployment: M namenodes, one NDB cluster."""

    def __init__(self, *, n_namenodes: int, n_ndb: int,
                 profiles: Dict[str, RTProfile],
                 params: SimParams = DEFAULT_PARAMS, seed: int = 0,
                 timeline_bin: float = 1.0):
        self.p = params
        self.sim = Sim()
        self.rng = random.Random(seed)
        self.profiles = profiles
        self.timeline_bin = timeline_bin
        self.nn_handlers = [Server(self.sim, params.nn_handlers)
                            for _ in range(n_namenodes)]
        self.nn_cpu = [Server(self.sim, params.nn_cores)
                       for _ in range(n_namenodes)]
        self.nn_alive = [True] * n_namenodes
        self.ndb = [Server(self.sim, params.ndb_threads)
                    for _ in range(n_ndb)]
        self.n_ndb = n_ndb
        self.completed = 0
        self.latencies: List[float] = []
        self.timeline: Dict[int, int] = {}
        self.failed_ops = 0
        self.fault_events: List[Tuple[float, str, int]] = []

    # -- client behaviour ---------------------------------------------------
    def start_clients(self, n_clients: int, workload: SpotifyWorkload,
                      *, policy: str = "round_robin") -> None:
        for c in range(n_clients):
            self._client_loop(c, workload, policy,
                              jitter=self.rng.random() * 1e-3)

    def _alive_nns(self) -> List[int]:
        return [i for i, a in enumerate(self.nn_alive) if a]

    def _client_loop(self, cid: int, workload: SpotifyWorkload,
                     policy: str, jitter: float = 0.0) -> None:
        def issue():
            alive = self._alive_nns()
            if not alive:
                self.sim.after(0.05, issue)
                return
            if policy == "sticky":
                nn = alive[cid % len(alive)]
            elif policy == "random":
                nn = self.rng.choice(alive)
            else:
                nn = alive[(cid + self.completed) % len(alive)]
            op = workload.next_op()
            t0 = self.sim.t
            self._run_op(nn, op, lambda: self._done(t0, issue))
        self.sim.after(jitter, issue)

    def _done(self, t0: float, issue_next: Callable[[], None]) -> None:
        self.completed += 1
        lat = self.sim.t - t0
        self.latencies.append(lat)
        sec = int(self.sim.t / self.timeline_bin)
        self.timeline[sec] = self.timeline.get(sec, 0) + 1
        issue_next()

    # -- op execution ---------------------------------------------------------
    def _run_op(self, nn: int, op: WorkloadOp,
                done: Callable[[], None]) -> None:
        prof = self.profiles.get(op.op) or self.profiles["read"]

        def after_rpc():
            if not self.nn_alive[nn]:
                # namenode died: client times out and retries elsewhere
                self.failed_ops += 1
                alive = self._alive_nns()
                if alive:
                    nn2 = self.rng.choice(alive)
                    self.sim.after(self.p.client_nn_rtt,
                                   lambda: self._run_op(nn2, op, done))
                else:
                    self.sim.after(0.05, lambda: self._run_op(
                        nn, op, done))
                return
            self.nn_handlers[nn].acquire(lambda: self._with_handler(
                nn, prof, done))
        self.sim.after(self.p.client_nn_rtt / 2, after_rpc)

    def _build_rts(self, prof: RTProfile) -> List[Tuple[str, bool]]:
        """Expand a profile into (kind, is_local) round trips."""
        rts: List[Tuple[str, bool]] = []
        loc_total = prof.local + prof.remote
        frac_local = prof.local / loc_total if loc_total else 0.0
        for kind, cnt in (("pk", prof.pk), ("batch", prof.batch),
                          ("ppis", prof.ppis), ("is", prof.is_scans),
                          ("fts", prof.fts)):
            for _ in range(cnt):
                rts.append((kind, self.rng.random() < frac_local))
        return rts

    def _exec_rts(self, rts: List[Tuple[str, bool]],
                  finish: Callable[[], None]) -> None:
        """Run a sequence of DB round trips (each queueing on NDB server
        threads), then call ``finish``."""
        p = self.p
        self.rng.shuffle(rts)

        def next_rt(i: int) -> None:
            if i >= len(rts):
                finish()
                return
            kind, local = rts[i]
            rtt = p.db_rtt_local if local else p.db_rtt_remote
            if kind in ("is", "fts"):
                svc = (p.svc_is_per_node if kind == "is"
                       else p.svc_fts_per_node)
                remaining = [self.n_ndb]

                def one_done():
                    remaining[0] -= 1
                    if remaining[0] == 0:
                        self.sim.after(rtt, lambda: next_rt(i + 1))
                for node in self.ndb:
                    node.submit(svc, one_done)
            else:
                svc = {"pk": p.svc_pk, "batch": p.svc_batch,
                       "ppis": p.svc_ppis}[kind]
                node = self.ndb[self.rng.randrange(self.n_ndb)]
                node.submit(svc, lambda: self.sim.after(
                    rtt, lambda: next_rt(i + 1)))
        next_rt(0)

    def _with_handler(self, nn: int, prof: RTProfile,
                      done: Callable[[], None]) -> None:
        """Handler is HELD for the op's full duration (CPU + all DB round
        trips) — this is what makes DB latency throttle NN concurrency."""
        p = self.p

        def finish():
            self.nn_handlers[nn].release()
            self.sim.after(p.client_nn_rtt / 2, done)

        # CPU slice, then DB phase
        self.nn_cpu[nn].submit(
            p.nn_cpu_per_op,
            lambda: self._exec_rts(self._build_rts(prof), finish))

    # -- faults ---------------------------------------------------------------
    def kill_namenode(self, nn: int) -> None:
        self.nn_alive[nn] = False

    def restart_namenode(self, nn: int) -> None:
        self.nn_alive[nn] = True

    def _fault(self, action: str, nn: int) -> None:
        self.fault_events.append((self.sim.t, action, nn))
        if action == "killed":
            self.kill_namenode(nn)
        else:
            self.restart_namenode(nn)

    def schedule_kill(self, at: float, nn: int) -> None:
        """Mirror of a chaos-plan CRASH fault: kill ``nn`` at sim time
        ``at`` and record the event in :attr:`fault_events`."""
        self.sim.after(max(0.0, at - self.sim.t),
                       lambda: self._fault("killed", nn))

    def schedule_restart(self, at: float, nn: int) -> None:
        self.sim.after(max(0.0, at - self.sim.t),
                       lambda: self._fault("restarted", nn))

    # -- elastic membership (the DES mirror of pool.py) -----------------------
    def scale_out_namenode(self) -> int:
        """Append one namenode mid-run (the DES mirror of
        ``ElasticNamenodePool.scale_out``): fresh handler + CPU servers,
        alive immediately — clients pick it up on their next
        ``_alive_nns()`` read. Returns the new namenode's id."""
        nn = len(self.nn_handlers)
        self.nn_handlers.append(Server(self.sim, self.p.nn_handlers))
        self.nn_cpu.append(Server(self.sim, self.p.nn_cores))
        self.nn_alive.append(True)
        self._on_scale_out(nn)
        self.fault_events.append((self.sim.t, "scale_out", nn))
        return nn

    def scale_in_namenode(self) -> Optional[int]:
        """Retire the highest-id alive namenode (never below one member).
        Returns the victim's id, or None if the fleet is already minimal."""
        alive = self._alive_nns()
        if len(alive) <= 1:
            return None
        nn = alive[-1]
        self.nn_alive[nn] = False
        self._on_scale_in(nn)
        self.fault_events.append((self.sim.t, "scale_in", nn))
        return nn

    def _on_scale_out(self, nn: int) -> None:
        """Subclass hook: extend per-namenode parallel state."""

    def _on_scale_in(self, nn: int) -> None:
        """Subclass hook: react to a planned retirement."""

    def schedule_scale_out(self, at: float, n: int = 1) -> None:
        """Scale out by ``n`` namenodes at sim time ``at``."""
        def act():
            for _ in range(n):
                self.scale_out_namenode()
        self.sim.after(max(0.0, at - self.sim.t), act)

    def schedule_scale_in(self, at: float, n: int = 1) -> None:
        """Scale in by ``n`` namenodes at sim time ``at``."""
        def act():
            for _ in range(n):
                self.scale_in_namenode()
        self.sim.after(max(0.0, at - self.sim.t), act)

    # -- driver ---------------------------------------------------------------
    def run(self, seconds: float) -> SimResult:
        self.sim.run(seconds)
        tl = sorted((b * self.timeline_bin, c)
                    for b, c in self.timeline.items())
        return SimResult(self.completed, seconds, self.latencies, tl)


class BatchedHopsFSSim(HopsFSSim):
    """DES of the batched multi-namenode request pipeline (§2.2, §7.2).

    Clients enqueue into ONE shared queue; each namenode pulls batches of
    up to ``batch_size`` ops whenever it has a free handler (a batch holds
    one handler for its whole duration, so batching amortizes handler
    occupancy exactly as it amortizes round trips). Mirroring the
    functional :meth:`~repro.core.namenode.Namenode.execute_batch`, the
    PK/batch path-validation round trips of each *batchable read group*
    and each *group-mutable mutation group* inside a batch collapse into
    one batched exchange, while per-op scan round trips (PPIS/IS/FTS), the
    mutations' per-row write round trips, and every other op's full
    profile are unchanged. Batches form adaptively: an idle fleet serves
    singleton batches (no added latency); under saturation the queue depth
    grows and batching kicks in — the behaviour that produces the Fig
    7-style throughput-scaling curve replayed by
    ``benchmarks/trace_replay.py``.

    ``planned=True`` mirrors the client-side batch planner
    (:mod:`~repro.core.batch_planner`): instead of FIFO slices, pending
    ops are bucketed by (op type, hint partition) — the OpSpec's own hint
    rule — and each pulled batch drains the largest bucket, so namenodes
    see partition-aligned, type-pure batches whose validation exchanges
    collapse maximally.

    ``adaptive=True`` mirrors the planner's :class:`~repro.core.\
batch_planner.WindowController` feedback loop at DES scale: the pull cap
    is a live window resized after every completed batch from the batch's
    unplannable-op share (the DES analogue of the conflict-pin rate) and
    its executed round trips per op — growth while amortization pays,
    backoff when it regresses.
    """

    def __init__(self, *, batch_size: int = 16, planned: bool = False,
                 adaptive: bool = False, **kw):
        super().__init__(**kw)
        self.batch_size = max(1, batch_size)
        self.planned = planned
        if adaptive:
            from .batch_planner import WindowController
            self.controller = WindowController(
                self.batch_size, min_window=max(1, self.batch_size // 4),
                max_window=self.batch_size * 4)
        else:
            self.controller = None
        self.queue: deque = deque()        # (WorkloadOp, done_cb)
        self.buckets: Dict[object, deque] = {}
        self._bucket_seqs: Dict[object, deque] = {}  # enqueue seq per item
        self.pending = 0
        self._pulls = 0
        self._seq = 0
        self._front_seq = 0                # counts down: requeue priority
        self._inflight = [0] * len(self.nn_handlers)
        self.nn_ops_completed = [0] * len(self.nn_handlers)
        self.batches_executed = 0
        self.batched_ops = 0

    # -- shared-queue client behaviour ---------------------------------
    def _client_loop(self, cid: int, workload, policy: str,
                     jitter: float = 0.0) -> None:
        # `policy` is moot here: ops go to whichever NN pulls the batch
        def issue():
            op = workload.next_op()
            t0 = self.sim.t
            self._enqueue((op, lambda: self._done(t0, issue)))
            self._dispatch()
        self.sim.after(jitter, issue)

    # -- queueing ------------------------------------------------------
    def _enqueue(self, item, *, front: bool = False) -> None:
        if not self.planned:
            (self.queue.appendleft if front
             else self.queue.append)(item)
            return
        op = item[0]
        spec = REGISTRY.get(op.op)
        if spec is not None and (spec.batchable or spec.group_mutable):
            key: object = (op.op,
                           spec.sim_partition(op.path, self.N_PARTITIONS))
        else:
            key = None                     # unplannable: FIFO bucket
        dq = self.buckets.setdefault(key, deque())
        sq = self._bucket_seqs.setdefault(key, deque())
        if front:
            self._front_seq -= 1
            dq.appendleft(item)
            sq.appendleft(self._front_seq)
        else:
            self._seq += 1
            dq.append(item)
            sq.append(self._seq)
        self.pending += 1

    def _requeue(self, item) -> None:
        # a failed batch's ops keep retry priority at the queue front
        self._enqueue(item, front=True)

    def _has_work(self) -> bool:
        return bool(self.queue) or self.pending > 0

    # every Nth planned pull serves the bucket whose HEAD op has waited
    # longest instead of the largest bucket — the real BatchPlanner bounds
    # reordering to a window, so the DES mirror must not let cold
    # (op, partition) buckets starve behind continuously-refilled hot ones
    PULL_AGING = 4

    def _pull_batch(self):
        # the live pull cap: fixed batch_size, or the adaptive window
        cap = (self.controller.window if self.controller is not None
               else self.batch_size)
        if not self.planned:
            k = min(cap, len(self.queue))
            return [self.queue.popleft() for _ in range(k)]
        if not self.buckets:
            return []
        self._pulls += 1
        if self._pulls % self.PULL_AGING == 0:
            # oldest-waiting head op (requeued ops carry negative seqs,
            # so failed batches regain priority first)
            key = min(self.buckets,
                      key=lambda b: self._bucket_seqs[b][0])
        else:
            # drain the largest bucket: partition-aligned dealing
            key = max(self.buckets, key=lambda b: len(self.buckets[b]))
        dq = self.buckets[key]
        sq = self._bucket_seqs[key]
        k = min(cap, len(dq))
        out = [dq.popleft() for _ in range(k)]
        for _ in range(k):
            sq.popleft()
        if not dq:
            del self.buckets[key]
            del self._bucket_seqs[key]
        self.pending -= k
        return out

    # -- elastic membership --------------------------------------------
    def _on_scale_out(self, nn: int) -> None:
        # parallel per-namenode state must grow with the fleet, and the
        # joiner should start pulling from the shared queue immediately
        self._inflight.append(0)
        self.nn_ops_completed.append(0)
        self.sim.after(0.0, self._dispatch)

    # -- dispatch ------------------------------------------------------
    def _dispatch(self) -> None:
        progress = True
        while self._has_work() and progress:
            progress = False
            for nn in self._alive_nns():
                if not self._has_work():
                    break
                if self._inflight[nn] >= self.p.nn_handlers:
                    continue
                batch = self._pull_batch()
                if not batch:
                    break
                self._inflight[nn] += 1
                self._run_batch(nn, batch)
                progress = True

    def _run_batch(self, nn: int, batch) -> None:
        p = self.p

        def after_rpc():
            if not self.nn_alive[nn]:
                # NN died holding the batch: requeue for the survivors
                self._inflight[nn] -= 1
                self.failed_ops += len(batch)
                for item in reversed(batch):
                    self._requeue(item)
                self.sim.after(0.05, self._dispatch)
                return
            self.nn_handlers[nn].acquire(with_handler)

        def with_handler():
            rts = self._merged_rts(batch)

            def finish():
                self.nn_handlers[nn].release()
                self._inflight[nn] -= 1
                self.nn_ops_completed[nn] += len(batch)
                self.batches_executed += 1
                if len(batch) > 1:
                    self.batched_ops += len(batch)
                if self.controller is not None:
                    # feedback: unplannable ops are the DES analogue of
                    # the planner's conflict pins, executed round trips
                    # the amortization signal
                    unplanned = sum(
                        1 for op, _ in batch
                        if (s := REGISTRY.get(op.op)) is None
                        or not (s.batchable or s.group_mutable))
                    self.controller.observe(len(batch), unplanned,
                                            len(rts))
                for _, done_cb in batch:
                    self.sim.after(p.client_nn_rtt / 2, done_cb)
                self._dispatch()
            self.nn_cpu[nn].submit(
                p.nn_cpu_per_op * len(batch),
                lambda: self._exec_rts(rts, finish))
        self.sim.after(p.client_nn_rtt / 2, after_rpc)

    # partition count used to group same-type reads — mirrors the default
    # MetadataStore sharding the functional pipeline groups against
    N_PARTITIONS = 64

    def _merged_rts(self, batch) -> List[Tuple[str, bool]]:
        """Round trips for a batch, collapsed exactly as the functional
        ``Namenode.execute_batch`` does: same-type groupable ops are
        grouped by the HINT PARTITION (path-hashed via the OpSpec hint
        rule), and each multi-op group's validation round trips become ONE
        batched exchange (§5.1) — for batchable reads that absorbs the
        pk+batch validation reads; for group-mutable mutations it absorbs
        the batch-kind exchanges while the per-row write round trips (pk)
        and per-op scans survive. Singleton groups and every other op keep
        their full profiles. Zipf-popular files landing on the same
        partition are what make reactive groups collapse; planned mode
        makes the batches partition-pure so they collapse maximally."""
        groups: Dict[Tuple[str, int], List[RTProfile]] = {}
        rts: List[Tuple[str, bool]] = []
        for op, _ in batch:
            prof = self.profiles.get(op.op) or self.profiles["read"]
            spec = REGISTRY.get(op.op)
            if spec is not None and (spec.batchable
                                     or spec.group_mutable):
                # path -> partition via the OpSpec's hint derivation, the
                # same rule the functional pipeline groups against
                part = spec.sim_partition(op.path, self.N_PARTITIONS)
                groups.setdefault((op.op, part), []).append(prof)
            else:
                rts.extend(self._build_rts(prof))
        for (opname, _part), profs in groups.items():
            if len(profs) == 1:
                rts.extend(self._build_rts(profs[0]))
                continue
            spec = REGISTRY.get(opname)
            is_read = spec is not None and spec.batchable
            loc = sum(pr.local for pr in profs)
            rem = sum(pr.remote for pr in profs)
            frac_local = loc / (loc + rem) if (loc + rem) else 0.0
            # ONE batched exchange replaces the group's validation RTs
            rts.append(("batch", self.rng.random() < frac_local))
            for pr in profs:
                kinds = (("ppis", pr.ppis), ("is", pr.is_scans),
                         ("fts", pr.fts))
                if not is_read:
                    # mutations keep their per-row write round trips
                    kinds = (("pk", pr.pk),) + kinds
                for kind, cnt in kinds:
                    for _ in range(cnt):
                        rts.append((kind,
                                    self.rng.random() < frac_local))
        return rts

    def restart_namenode(self, nn: int) -> None:
        super().restart_namenode(nn)
        self._dispatch()


class HDFSSim:
    """DES of HA-HDFS: one active namenode, global RW lock, failover gap."""

    def __init__(self, *, params: SimParams = DEFAULT_PARAMS, seed: int = 0,
                 timeline_bin: float = 1.0):
        self.p = params
        self.sim = Sim()
        self.rng = random.Random(seed)
        self.handlers = Server(self.sim, params.nn_handlers)
        self.cpu = Server(self.sim, params.nn_cores)
        self.lock = RWLock(self.sim)
        self.down_until = -1.0
        self.completed = 0
        self.latencies: List[float] = []
        self.timeline: Dict[int, int] = {}
        self.timeline_bin = timeline_bin

    def start_clients(self, n_clients: int, workload: SpotifyWorkload
                      ) -> None:
        for c in range(n_clients):
            self._client_loop(workload, jitter=self.rng.random() * 1e-3)

    def _client_loop(self, workload: SpotifyWorkload,
                     jitter: float = 0.0) -> None:
        def issue():
            op = workload.next_op()
            t0 = self.sim.t
            self._run_op(op, lambda: self._done(t0, issue))
        self.sim.after(jitter, issue)

    def _done(self, t0: float, issue_next: Callable[[], None]) -> None:
        self.completed += 1
        self.latencies.append(self.sim.t - t0)
        sec = int(self.sim.t / self.timeline_bin)
        self.timeline[sec] = self.timeline.get(sec, 0) + 1
        issue_next()

    def _run_op(self, op: WorkloadOp, done: Callable[[], None]) -> None:
        p = self.p
        op_spec = REGISTRY.get(op.op)
        is_read = op_spec is not None and op_spec.read_only

        def after_rpc():
            if self.sim.t < self.down_until:
                # failover window: RPCs fail; client retries after backoff
                self.sim.after(self.down_until - self.sim.t + 0.05,
                               lambda: self._run_op(op, done))
                return
            self.handlers.acquire(with_handler)

        def with_handler():
            cpu = p.hdfs_cpu_read if is_read else p.hdfs_cpu_write
            hold = p.hdfs_lock_read_hold if is_read \
                else p.hdfs_lock_write_hold
            spec = REGISTRY.get(op.op)
            if spec is not None and spec.subtree:
                hold *= 40      # large in-heap subtree mutation

            def fin():
                self.handlers.release()
                self.sim.after(p.client_nn_rtt / 2, done)
            self.cpu.submit(cpu, lambda: self.lock.submit(
                not is_read, hold, fin))
        self.sim.after(p.client_nn_rtt / 2, after_rpc)

    def kill_active(self) -> float:
        """Failover: downtime = detection + edit-log replay (§7.6.1)."""
        gap = self.p.failover_detect + self.p.failover_replay
        self.down_until = self.sim.t + gap
        return gap

    def run(self, seconds: float) -> SimResult:
        self.sim.run(seconds)
        tl = sorted((b * self.timeline_bin, c)
                    for b, c in self.timeline.items())
        return SimResult(self.completed, seconds, self.latencies, tl)
