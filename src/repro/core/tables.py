"""Fully-normalized metadata schema (paper §4.1, Figure 3).

Every entity from the paper's ER diagram is a table:

  inode      — one row per file/directory; PK = (parent_id, name);
               partition key = parent_id  (T2: all immediate children of a
               directory live on one shard -> `ls` is a partition-pruned scan)
  block      — file blocks; partition key = inode_id (file-related metadata
               co-located on one shard -> file read is partition-pruned)
  replica    — block replica locations; partition key = inode_id
  urb        — under-replicated blocks
  prb        — pending replication blocks
  ruc        — replicas under construction
  cr         — corrupted replicas
  er         — excess replicas
  inv        — invalidated replicas (scheduled for deletion)
  lease      — client leases (writers)
  lease_path — paths under lease
  quota      — directory quota + usage
  ongoing_subtree_ops — active subtree operations (paper §6.1 phase 1)
  leader     — leader-election / namenode membership rows (paper §3, [57])
  id_seq     — id allocation blocks

Rows are plain dicts. Tables carry schema metadata: primary-key columns,
partition-key column, and secondary indexes. ``IX_`` names below are the
canonical index identifiers used by scans and by cost accounting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Schema descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TableSchema:
    name: str
    pk: Tuple[str, ...]                 # primary-key columns (composite ok)
    partition_key: str                  # column whose hash picks the shard
    indexes: Tuple[str, ...] = ()       # secondary index columns (single col)
    # approximate on-NDB bytes per row (Table 2 capacity model; includes
    # replication=1 copy; indexes/keys/padding per the paper's `sizer` tool)
    row_bytes: int = 64


ROOT_ID = 1  # inode id of "/"; always cached by every namenode (paper §5.1)


def split_path(path: str) -> list:
    """Canonical path -> component list. THE one splitter, shared by
    server-side resolution (fs), client-side invalidation (hint_cache)
    and the planner — path normalization can never drift between them."""
    return [c for c in path.split("/") if c]

INODE = TableSchema(
    name="inode",
    pk=("parent_id", "name"),
    partition_key="parent_id",
    indexes=("id", "parent_id"),  # unique id index + children-of index
    row_bytes=296,
)
BLOCK = TableSchema("block", ("block_id",), "inode_id", ("inode_id",), 128)
REPLICA = TableSchema("replica", ("block_id", "datanode_id"), "inode_id",
                      ("inode_id", "datanode_id"), 96)
URB = TableSchema("urb", ("block_id",), "inode_id", ("inode_id",), 48)
PRB = TableSchema("prb", ("block_id",), "inode_id", ("inode_id",), 48)
RUC = TableSchema("ruc", ("block_id", "datanode_id"), "inode_id", ("inode_id",), 64)
CR = TableSchema("cr", ("block_id", "datanode_id"), "inode_id", ("inode_id",), 64)
ER = TableSchema("er", ("block_id", "datanode_id"), "inode_id", ("inode_id",), 64)
INV = TableSchema("inv", ("block_id", "datanode_id"), "inode_id", ("inode_id",), 64)
LEASE = TableSchema("lease", ("holder",), "holder", (), 80)
LEASE_PATH = TableSchema("lease_path", ("inode_id",), "holder", ("holder",), 96)
QUOTA = TableSchema("quota", ("inode_id",), "inode_id", (), 72)
SUBTREE_OPS = TableSchema("ongoing_subtree_ops", ("inode_id",), "inode_id",
                          ("namenode_id",), 64)
LEADER = TableSchema("leader", ("namenode_id",), "namenode_id", (), 64)
ID_SEQ = TableSchema("id_seq", ("seq_name",), "seq_name", (), 32)

ALL_TABLES: Tuple[TableSchema, ...] = (
    INODE, BLOCK, REPLICA, URB, PRB, RUC, CR, ER, INV,
    LEASE, LEASE_PATH, QUOTA, SUBTREE_OPS, LEADER, ID_SEQ,
)

# file-inode-related tables (partitioned by inode_id => co-located; paper §4.2)
FILE_RELATED = ("block", "replica", "urb", "prb", "ruc", "cr", "er", "inv")


# ---------------------------------------------------------------------------
# Row constructors
# ---------------------------------------------------------------------------

def make_inode(inode_id: int, parent_id: int, name: str, is_dir: bool, *,
               perm: int = 0o755, owner: str = "hops", group: str = "hops",
               size: int = 0, repl: int = 3, mtime: float = 0.0,
               client: Optional[str] = None) -> Dict[str, Any]:
    return {
        "id": inode_id,
        "parent_id": parent_id,
        "name": name,
        "is_dir": is_dir,
        "perm": perm,
        "owner": owner,
        "group": group,
        "size": size,
        "repl": repl,
        "mtime": mtime,
        "atime": mtime,
        # subtree-lock flag (paper §6.1 phase 1): None, or the id of the
        # namenode that owns the application-level lock on this subtree root.
        "subtree_lock": None,
        "under_construction": client is not None,
        "client": client,
    }


def make_block(block_id: int, inode_id: int, index: int, *,
               size: int = 0, gen_stamp: int = 0) -> Dict[str, Any]:
    return {"block_id": block_id, "inode_id": inode_id, "index": index,
            "size": size, "gen_stamp": gen_stamp, "state": "COMPLETE"}


def make_replica(block_id: int, inode_id: int, datanode_id: int) -> Dict[str, Any]:
    return {"block_id": block_id, "inode_id": inode_id,
            "datanode_id": datanode_id, "state": "FINALIZED"}


def pk_of(schema: TableSchema, row: Dict[str, Any]) -> Tuple[Any, ...]:
    return tuple(row[c] for c in schema.pk)


# ---------------------------------------------------------------------------
# Capacity model (paper §7.3, Table 2)
# ---------------------------------------------------------------------------

#: HDFS in-JVM bytes for a file with two blocks, 3x replicated: 448 + L
HDFS_FILE_BYTES_BASE = 448
#: HopsFS/NDB bytes for the same file at NDB replication 2 (measured with
#: the `sizer` tool in the paper): 2420 bytes.
HOPSFS_FILE_BYTES_R2 = 2420
#: NDB cluster limits used in the paper's Table 2
NDB_MAX_DATANODES = 48
NDB_MAX_RAM_PER_NODE_GB = 512


def hdfs_capacity_files(memory_gb: float, name_len: int = 10) -> Optional[float]:
    """Files storable in an HDFS namenode heap of ``memory_gb``.

    Returns None where HDFS "Does Not Scale" (the paper caps practical JVM
    heaps at ~200 GB due to GC pauses, §2.1/§7.3).
    """
    if memory_gb > 200:
        return None
    return memory_gb * (1 << 30) / (HDFS_FILE_BYTES_BASE + name_len)


def hopsfs_capacity_files(memory_gb: float) -> float:
    """Files storable in an NDB cluster with aggregate ``memory_gb`` RAM
    (replication 2 is already folded into HOPSFS_FILE_BYTES_R2)."""
    return memory_gb * (1 << 30) / HOPSFS_FILE_BYTES_R2
