"""HopsFS transactional operations (paper §5, Figure 4).

A :class:`Transaction` implements the three-phase template:

  LOCK PHASE    — all data read up-front at the strongest lock level that the
                  op will ever need (prevents lock upgrades, §5 "Lock
                  Upgrades"), locks taken in total order (root-down DFS order
                  over paths, §5 "Cyclic Deadlocks"); batched PK reads and
                  partition-pruned index scans fill the per-transaction cache.
  EXECUTE PHASE — the FS op mutates rows *in the cache only*.
  UPDATE PHASE  — dirty rows are flushed to the store in batches, then the
                  transaction commits (locks released) or aborts (cache
                  dropped, locks released).

Every access path increments :class:`~repro.core.store.OpCost`, giving the
measured round-trip profile that `benchmarks/bench_table3_costmodel.py`
checks against the paper's Table 3 formulas.

Distribution-aware transactions (§2.2): ``begin(partition_hint=...)`` places
the coordinator on the primary datanode of the hinted partition's node group.
Each subsequent round trip is classified local/remote against that node
group — this is what Fig 12/13's DAT ablation toggles.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .store import (EXCLUSIVE, READ_COMMITTED, SHARED, LockTimeout,
                    MetadataStore, OpCost, RowNotFound, Table,
                    TransactionAborted)
from .tables import pk_of

_TOMBSTONE = object()


class Transaction:
    def __init__(self, store: MetadataStore, *,
                 partition_hint: Optional[Tuple[str, Any]] = None,
                 distribution_aware: bool = True):
        self.store = store
        self.txn_id = store.next_txn_id()
        self.cost = OpCost()
        self.cache: Dict[Tuple[str, Tuple[Any, ...]], Any] = {}
        self.dirty: Set[Tuple[str, Tuple[Any, ...]]] = set()
        # per-table insertion-ordered view of the dirty PKs: the
        # read-your-writes scan overlay walks only ITS table's pending
        # rows, in deterministic (insertion) order, instead of re-sorting
        # the whole dirty set per scan — large grouped transactions (an
        # oversized lease-ordered block-write batch) would otherwise go
        # quadratic in scans over dirty keys
        self._dirty_order: Dict[str, List[Tuple[Any, ...]]] = {}
        # (table, indexed col) -> value -> insertion-ordered dirty PKs with
        # that value: the overlay's candidate set for ppis/index_scan. The
        # full _dirty_order walk made a grouped transaction interleaving
        # indexed scans with writes (G add_blocks over G distinct files,
        # each _file_scan probing `block` by inode_id) quadratic in G —
        # every scan walked EVERY dirty row of the table just to discard
        # the non-matching ones. Mirrors Table.idx, scoped to pending rows.
        self._dirty_idx: Dict[Tuple[str, str],
                              Dict[Any, List[Tuple[Any, ...]]]] = {}
        # last indexed values per dirty key, so re-writes can unindex
        self._dirty_vals: Dict[Tuple[str, Tuple[Any, ...]],
                               Dict[str, Any]] = {}
        #: overlay candidates examined across all scans this transaction —
        #: the counter the scan-scaling guard test asserts on (10x dirty
        #: rows must mean ~10x overlay work, not ~100x)
        self.overlay_scanned = 0
        self._done = False
        # --- distribution awareness (DAT) --------------------------------
        self.coordinator_group: Optional[int] = None
        if distribution_aware and partition_hint is not None:
            tname, pkey = partition_hint
            part = store.table(tname).partition_of(pkey)
            store.check_available(part)
            self.coordinator_group = store.group_of_partition(part).gid
        elif not distribution_aware:
            # round-robin coordinator: usually the wrong node group
            self.coordinator_group = self.txn_id % store.n_groups

    # ------------------------------------------------------------------
    # locality classification
    # ------------------------------------------------------------------
    def _charge_rt(self, parts: Iterable[int]) -> None:
        """Classify one round trip as local/remote wrt the coordinator."""
        parts = list(parts)
        if self.coordinator_group is None:
            self.cost.remote_rt += 1
            return
        groups = {self.store.group_of_partition(p).gid for p in parts}
        if groups and groups <= {self.coordinator_group}:
            self.cost.local_rt += 1
        else:
            self.cost.remote_rt += 1

    def _row_op(self, n: int = 1) -> None:
        self.cost.rows_touched += n
        self.store.total_row_ops += n

    # ------------------------------------------------------------------
    # LOCK/READ phase primitives
    # ------------------------------------------------------------------
    def read(self, tname: str, pk: Tuple[Any, ...], lock: str = READ_COMMITTED,
             *, _batched: bool = False) -> Optional[Dict[str, Any]]:
        """Single-row PK read at the given lock level. One round trip
        (unless part of a batch, which charges once at the batch)."""
        t = self.store.table(tname)
        part = t.partition_of_pk(pk)
        self.store.check_available(part)
        self.store.locks.acquire(self.txn_id, tname, pk, lock)
        if not _batched:
            if lock == READ_COMMITTED:
                self.cost.pk_rc += 1
            elif lock == SHARED:
                self.cost.pk_r += 1
            else:
                self.cost.pk_w += 1
            self._charge_rt([part])
        self._row_op()
        key = (tname, pk)
        if key in self.cache:
            v = self.cache[key]
            return None if v is _TOMBSTONE else v
        row = t.get(pk, part_hint=part)
        if row is not None:
            row = dict(row)  # snapshot into txn cache
            self.cache[key] = row
        return row

    def peek(self, tname: str, pk: Tuple[Any, ...]
             ) -> Optional[Dict[str, Any]]:
        """Read a row through the transaction's own cache WITHOUT charging a
        round trip. Rows already read (lock phase) or written (execute
        phase) this transaction are served from the cache — which is what
        makes grouped write transactions see each other's in-flight updates
        (e.g. two creates in one directory accumulating the parent's quota)
        — and anything else falls through to the raw store row, matching
        the direct-store peeks the sequential write path has always done."""
        key = (tname, pk)
        if key in self.cache:
            v = self.cache[key]
            return None if v is _TOMBSTONE else v
        return self.store.table(tname).get(pk)

    def read_batch(self, reads: Sequence[Tuple[str, Tuple[Any, ...], str]]
                   ) -> List[Optional[Dict[str, Any]]]:
        """Batched PK reads: one round trip for the whole batch (§5.1).
        ``reads`` is a list of (table, pk, lock_mode)."""
        if not reads:
            return []
        out = []
        parts = []
        for tname, pk, lock in reads:
            t = self.store.table(tname)
            parts.append(t.partition_of_pk(pk))
            out.append(self.read(tname, pk, lock, _batched=True))
        self.cost.batches += 1
        self.cost.batch_rows += len(reads)
        self._charge_rt(parts)
        return out

    def batch(self) -> "_BatchCtx":
        """Context manager grouping several PK reads into ONE round trip,
        allowing later reads' keys to depend on earlier reads' values (the
        DAL builds such dependent batches; the network charge is one
        exchange). Usage::

            with txn.batch() as b:
                row = b.read("inode", pk, EXCLUSIVE)
                b.read("lease", (row["client"],), READ_COMMITTED)
        """
        return _BatchCtx(self)

    def ppis(self, tname: str, index_col: str, value: Any,
             lock: str = READ_COMMITTED, *,
             projection: Optional[Sequence[str]] = None
             ) -> List[Dict[str, Any]]:
        """Partition-pruned index scan: the index column IS the partition
        key (or co-partitioned with it), so exactly one shard is touched."""
        t = self.store.table(tname)
        part = t.partition_of(value)
        self.store.check_available(part)
        rows = t.scan_index(index_col, value)
        self.cost.ppis += 1
        self._charge_rt([part])
        return self._absorb_scan(tname, t, rows, lock, projection,
                                 match=lambda r: r.get(index_col) == value,
                                 index_key=(index_col, value))

    def index_scan(self, tname: str, index_col: str, value: Any,
                   lock: str = READ_COMMITTED) -> List[Dict[str, Any]]:
        """Index scan that cannot be pruned: hits every shard (cost IS)."""
        t = self.store.table(tname)
        rows = t.scan_index(index_col, value)
        self.cost.is_scans += 1
        self._charge_rt(range(t.n_partitions))
        return self._absorb_scan(tname, t, rows, lock, None,
                                 match=lambda r: r.get(index_col) == value,
                                 index_key=(index_col, value))

    def full_scan(self, tname: str, pred: Callable[[Dict[str, Any]], bool]
                  ) -> List[Dict[str, Any]]:
        t = self.store.table(tname)
        rows = t.scan_all(pred)
        self.cost.fts += 1
        self._charge_rt(range(t.n_partitions))
        return self._absorb_scan(tname, t, rows, READ_COMMITTED, None,
                                 match=pred)

    def scan_partition_pruned_pred(self, tname: str, pkey_value: Any,
                                   pred: Callable[[Dict[str, Any]], bool],
                                   lock: str = READ_COMMITTED
                                   ) -> List[Dict[str, Any]]:
        """PPIS variant with an arbitrary predicate evaluated on one shard
        (used by subtree quiescing, §6.1 phase 2)."""
        t = self.store.table(tname)
        part = t.partition_of(pkey_value)
        self.store.check_available(part)
        rows = t.scan_partition(part, pred)
        self.cost.ppis += 1
        self._charge_rt([part])
        return self._absorb_scan(
            tname, t, rows, lock, None,
            match=lambda r: (t.partition_of(r[t.schema.partition_key])
                             == part and pred(r)))

    def _absorb_scan(self, tname: str, t: Table, rows, lock, projection,
                     match: Optional[Callable[[Dict[str, Any]], bool]]
                     = None,
                     index_key: Optional[Tuple[str, Any]] = None):
        out = []
        seen: Set[Tuple[Any, ...]] = set()
        for row in rows:
            pk = pk_of(t.schema, row)
            seen.add(pk)
            self.store.locks.acquire(self.txn_id, tname, pk, lock)
            self._row_op()
            key = (tname, pk)
            if key in self.cache:
                v = self.cache[key]
                if v is _TOMBSTONE:
                    continue
                out.append(v)
                continue
            snap = dict(row)
            if projection is None:
                self.cache[key] = snap
            out.append({c: snap[c] for c in projection} if projection else snap)
        # Read-your-writes overlay: rows INSERTED by this transaction are
        # not in the store yet, so the store scan above cannot return them
        # — but the real engine's scans see the transaction's own pending
        # rows. Grouped write transactions rely on this: two add_blocks on
        # one file in the same group must each see the other's block row
        # exactly as committed sequential transactions would.
        if match is not None and self.dirty:
            # indexed scans walk only the dirty rows that CAN match (the
            # per-(table, col, value) candidate list); predicate scans
            # still walk the table's whole dirty set. Candidates are
            # re-checked against `match` either way, so a stale index
            # entry can only cost a wasted probe, never a wrong row.
            if index_key is not None \
                    and index_key[0] in t.schema.indexes:
                col, value = index_key
                candidates: Iterable[Tuple[Any, ...]] = \
                    self._dirty_idx.get((tname, col), {}).get(value, ())
            else:
                candidates = self._dirty_order.get(tname, ())
            for pk in candidates:
                self.overlay_scanned += 1
                if pk in seen:
                    continue
                v = self.cache[(tname, pk)]
                if v is _TOMBSTONE or not match(v):
                    continue
                self.store.locks.acquire(self.txn_id, tname, pk, lock)
                self._row_op()
                out.append({c: v[c] for c in projection}
                           if projection else v)
        return out

    # ------------------------------------------------------------------
    # EXECUTE phase: cache mutation
    # ------------------------------------------------------------------
    def _mark_dirty(self, tname: str, pk: Tuple[Any, ...]) -> None:
        key = (tname, pk)
        if key not in self.dirty:
            self.dirty.add(key)
            self._dirty_order.setdefault(tname, []).append(pk)

    def _reindex_dirty(self, tname: str, t: Table, pk: Tuple[Any, ...],
                       row: Optional[Dict[str, Any]]) -> None:
        """Keep the dirty-row secondary index in step with the txn cache
        (``row=None`` on delete): unhook the key from its previous indexed
        values, hook it under the new ones."""
        if not t.schema.indexes:
            return
        key = (tname, pk)
        old = self._dirty_vals.get(key)
        new = ({c: row.get(c) for c in t.schema.indexes}
               if row is not None else None)
        for c in t.schema.indexes:
            ov = old.get(c) if old is not None else None
            nv = new.get(c) if new is not None else None
            if old is not None and (new is None or ov != nv):
                lst = self._dirty_idx.get((tname, c), {}).get(ov)
                if lst is not None:
                    try:
                        lst.remove(pk)
                    except ValueError:
                        pass
            if new is not None and (old is None or ov != nv):
                self._dirty_idx.setdefault((tname, c), {}) \
                    .setdefault(nv, []).append(pk)
        if new is None:
            self._dirty_vals.pop(key, None)
        else:
            self._dirty_vals[key] = new

    def write(self, tname: str, row: Dict[str, Any]) -> None:
        """Insert/update a row in the txn cache (flushed at commit). The row
        lock must already be held exclusively if the row pre-existed."""
        t = self.store.table(tname)
        pk = pk_of(t.schema, row)
        self.store.locks.acquire(self.txn_id, tname, pk, EXCLUSIVE)
        self.cache[(tname, pk)] = row
        self._mark_dirty(tname, pk)
        self._reindex_dirty(tname, t, pk, row)

    def delete(self, tname: str, pk: Tuple[Any, ...]) -> None:
        self.store.locks.acquire(self.txn_id, tname, pk, EXCLUSIVE)
        self.cache[(tname, pk)] = _TOMBSTONE
        self._mark_dirty(tname, pk)
        self._reindex_dirty(tname, self.store.table(tname), pk, None)

    # ------------------------------------------------------------------
    # UPDATE phase
    # ------------------------------------------------------------------
    def commit(self, *, batch_size: int = 1024) -> OpCost:
        """Flush dirty rows in batches (each batch = 1 write round trip,
        counted as PK_w per Table 3's convention of per-row write ops when
        rows are few, or as batches when large — we count one PK_w per dirty
        row up to 8 rows, then batched), then release locks."""
        if self._done:
            raise TransactionAborted("transaction already finished")
        try:
            dirty = sorted(self.dirty)
            if dirty:
                parts_touched = []
                for tname, pk in dirty:
                    t = self.store.table(tname)
                    v = self.cache[(tname, pk)]
                    if v is _TOMBSTONE:
                        t.delete(pk)
                    else:
                        t.put(dict(v))
                    self._row_op()
                    parts_touched.append(t.partition_of_pk(pk))
                if len(dirty) <= 8:
                    self.cost.pk_w += len(dirty)
                    for p in parts_touched:
                        self._charge_rt([p])
                else:
                    nb = (len(dirty) + batch_size - 1) // batch_size
                    self.cost.batches += nb
                    self.cost.batch_rows += len(dirty)
                    for _ in range(nb):
                        self._charge_rt(parts_touched)
            return self.cost
        finally:
            self._finish()

    def abort(self) -> None:
        if not self._done:
            self._finish()

    def _finish(self) -> None:
        self._done = True
        self.store.locks.release_all(self.txn_id)

    # context manager: commit on success, abort on exception
    def __enter__(self) -> "Transaction":
        return self

    def __exit__(self, et, ev, tb) -> bool:
        if et is None:
            if not self._done:
                self.commit()
        else:
            self.abort()
        return False


class _BatchCtx:
    def __init__(self, txn: Transaction):
        self.txn = txn
        self.parts: List[int] = []
        self.rows = 0

    def read(self, tname: str, pk: Tuple[Any, ...],
             lock: str = READ_COMMITTED) -> Optional[Dict[str, Any]]:
        t = self.txn.store.table(tname)
        self.parts.append(t.partition_of_pk(pk))
        self.rows += 1
        return self.txn.read(tname, pk, lock, _batched=True)

    def __enter__(self) -> "_BatchCtx":
        return self

    def __exit__(self, et, ev, tb) -> bool:
        if et is None and self.rows:
            self.txn.cost.batches += 1
            self.txn.cost.batch_rows += self.rows
            self.txn._charge_rt(self.parts)
        return False


def run_with_retry(fn: Callable[[], Any], *, retries: int = 3,
                   backoff: float = 0.005) -> Any:
    """Namenode-side retry loop: lock timeouts and aborted transactions are
    retried (paper §7.5: failed transactions automatically retried on a
    different database node)."""
    last: Optional[Exception] = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except (LockTimeout, TransactionAborted) as e:  # pragma: no cover
            last = e
            time.sleep(backoff * (2 ** attempt))
    raise last  # type: ignore[misc]
