"""Elastic namenode pool — load-adaptive scale-out/in over a live cluster.

The paper removes the single-namenode bottleneck by making namenodes
stateless over a shared NewSQL store (§3); this module adds the next step
λFS argues for (PAPERS.md): **elastic** metadata serving, where fleet size
follows offered load instead of being fixed at construction. Because all
durable state lives in the store, membership changes are cheap — the only
thing a namenode "owns" is its warm :class:`~repro.core.hint_cache.
InodeHintCache`, and that is exactly what the pool migrates.

Control loop
------------
:class:`ElasticNamenodePool` wraps a :class:`~repro.core.namenode.
NamenodeCluster` and is ticked on the election's logical clock (each
:meth:`tick` is one heartbeat round). Every tick it samples fleet load:

* ``ops_delta`` — ops served fleet-wide since the last tick
  (``Namenode.ops_served`` deltas),
* ``queue_depth`` — the caller-reported backlog (the planned pipeline
  passes its remaining-trace depth),
* ``lock_wait_frac`` — store-level lock contention
  (``LockManager.wait_count`` / ``acquire_count`` deltas).

Per-namenode load is ``(ops_delta + queue_depth) / alive``. The policy is
deliberately boring — watermarks with hysteresis and a cooldown:

* ``hysteresis`` consecutive samples above ``high_load`` → scale OUT
  (up to ``max_namenodes``),
* ``hysteresis`` consecutive samples below ``low_load`` → scale IN
  (down to ``min_namenodes``),
* at most one scale action per ``cooldown`` ticks, so the fleet cannot
  thrash on a load spike that the previous action already absorbed.

Warm migration
--------------
Scale-out: the joiner is built by ``NamenodeCluster.add_namenode`` and
**pre-warmed before it is ever dealt a batch** — every client cache
registered via :meth:`register_client_cache` exports its newest
``prewarm_limit`` entries (:meth:`InodeHintCache.export_entries`) and the
joiner absorbs them. A cold joiner would answer its first windows with
recursive resolves; a pre-warmed one starts near the fleet's steady-state
hint hit rate (the ``elasticity`` bench section measures exactly this).

Scale-in: retirement is planned, not a crash. The victim (highest-id
alive non-leader) first exports its warm working set to every survivor,
then ``NamenodeCluster.retire`` drops it from the election *immediately*
(no staleness bound — contrast §7.6 failure detection). The leader then
reclaims any leases the victim's clients held via the existing
``recover_leases``/``scrub_leases`` housekeeping, so in-flight leases
survive membership changes without client involvement.

Every action bumps :attr:`membership_epoch` and notifies subscribers —
the ``membership_refresh`` middleware uses this to rebalance
``DFSClient`` selectors without dropping in-flight calls.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional

from .hint_cache import InodeHintCache
from .namenode import Namenode, NamenodeCluster


@dataclass
class LoadSample:
    """One tick's fleet telemetry (sampled on the election clock)."""
    t: int                  # election logical clock at sampling
    alive: int              # fleet size when sampled
    ops_delta: int          # ops served since the previous sample
    queue_depth: int        # caller-reported backlog (0 if not driven)
    lock_wait_frac: float   # store lock-wait fraction over the interval
    load: float             # (ops_delta + queue_depth) / alive


@dataclass
class ScaleEvent:
    """One membership change the pool performed."""
    t: int                  # election logical clock of the action
    action: str             # "scale_out" | "scale_in"
    nn_id: int              # joiner / victim namenode id
    reason: str             # human-readable trigger description
    migrated_entries: int = 0   # hint entries moved (pre-warm or migrate)


class ElasticNamenodePool:
    """Load-adaptive controller over a :class:`NamenodeCluster`.

    The pool never touches durable metadata — it only changes WHO serves
    (membership) and keeps hint caches warm across those changes. All
    decisions happen inside :meth:`tick`; nothing is threaded or timed,
    so replays with a pool attached stay deterministic.
    """

    def __init__(self, cluster: NamenodeCluster, *,
                 min_namenodes: int = 1,
                 max_namenodes: int = 8,
                 high_load: float = 128.0,
                 low_load: float = 16.0,
                 hysteresis: int = 2,
                 cooldown: int = 2,
                 prewarm_limit: int = 4096,
                 breakers: Any = None):
        if min_namenodes < 1:
            raise ValueError("min_namenodes must be >= 1")
        if low_load >= high_load:
            raise ValueError("low_load must be < high_load")
        self.cluster = cluster
        #: optional admission.BreakerBoard — scale-in prefers retiring a
        #: namenode whose breaker is OPEN (the fleet sheds its gray-slow
        #: member first instead of a healthy late joiner)
        self.breakers = breakers
        self.min_namenodes = min_namenodes
        self.max_namenodes = max_namenodes
        self.high_load = high_load
        self.low_load = low_load
        self.hysteresis = max(1, hysteresis)
        self.cooldown = max(0, cooldown)
        self.prewarm_limit = prewarm_limit

        #: bumped on every membership change; clients compare against it
        #: (``membership_refresh`` middleware) to rebalance lazily
        self.membership_epoch = 0
        self.samples: List[LoadSample] = []
        self.events: List[ScaleEvent] = []
        self.scale_outs = 0
        self.scale_ins = 0
        self.migrated_entries = 0

        self._subscribers: List[Callable[[ScaleEvent], None]] = []
        self._client_caches: List[InodeHintCache] = []
        self._high_streak = 0
        self._low_streak = 0
        self._last_action_t: Optional[int] = None
        self._last_ops_total = self._ops_total()
        locks = cluster.store.locks
        self._last_waits = locks.wait_count
        self._last_acquires = locks.acquire_count

    # -- wiring ---------------------------------------------------------
    def subscribe(self, fn: Callable[[ScaleEvent], None]) -> None:
        """Call ``fn(event)`` after every membership change."""
        self._subscribers.append(fn)

    def register_client_cache(self, cache: InodeHintCache) -> None:
        """Make a client-side hint cache a pre-warm donor for joiners."""
        if cache not in self._client_caches:
            self._client_caches.append(cache)

    # -- telemetry ------------------------------------------------------
    def _ops_total(self) -> int:
        return sum(nn.ops_served for nn in self.cluster.namenodes)

    def _sample(self, queue_depth: int) -> LoadSample:
        total = self._ops_total()
        ops_delta = total - self._last_ops_total
        self._last_ops_total = total
        locks = self.cluster.store.locks
        dw = locks.wait_count - self._last_waits
        da = locks.acquire_count - self._last_acquires
        self._last_waits = locks.wait_count
        self._last_acquires = locks.acquire_count
        alive = len(self.cluster.alive_namenodes())
        s = LoadSample(t=self.cluster.election.now,
                       alive=max(1, alive),
                       ops_delta=ops_delta,
                       queue_depth=queue_depth,
                       lock_wait_frac=(dw / da if da else 0.0),
                       load=(ops_delta + queue_depth) / max(1, alive))
        self.samples.append(s)
        return s

    # -- control loop ---------------------------------------------------
    def tick(self, *, queue_depth: int = 0) -> Optional[ScaleEvent]:
        """One control round: heartbeat the fleet, sample load, and act
        if the watermark/hysteresis/cooldown policy says so. Returns the
        :class:`ScaleEvent` performed this tick, if any."""
        self.cluster.tick()
        s = self._sample(queue_depth)
        if s.load > self.high_load:
            self._high_streak += 1
            self._low_streak = 0
        elif s.load < self.low_load:
            self._low_streak += 1
            self._high_streak = 0
        else:
            self._high_streak = 0
            self._low_streak = 0
        if not self._cooled(s.t):
            return None
        alive = len(self.cluster.alive_namenodes())
        if self._high_streak >= self.hysteresis \
                and alive < self.max_namenodes:
            return self.scale_out(
                f"load {s.load:.1f} > {self.high_load:.1f} for "
                f"{self._high_streak} ticks")
        if self._low_streak >= self.hysteresis \
                and alive > self.min_namenodes:
            return self.scale_in(
                f"load {s.load:.1f} < {self.low_load:.1f} for "
                f"{self._low_streak} ticks")
        return None

    def _cooled(self, now: int) -> bool:
        return (self._last_action_t is None
                or now - self._last_action_t >= self.cooldown)

    # -- actions --------------------------------------------------------
    def scale_out(self, reason: str = "manual") -> ScaleEvent:
        """Add one namenode, pre-warmed from the registered client caches
        BEFORE it can be dealt traffic (callers pick up the new member on
        their next ``alive_namenodes()`` read, which is after this
        returns)."""
        nn = self.cluster.add_namenode()
        moved = 0
        if nn.ops.cache is not None:
            for cache in self._client_caches:
                entries = cache.export_entries(self.prewarm_limit)
                nn.ops.cache.absorb(entries)
                moved += len(entries)
        return self._record("scale_out", nn.nn_id, reason, moved)

    def scale_in(self, reason: str = "manual") -> Optional[ScaleEvent]:
        """Retire one namenode: warm-migrate its hint cache to every
        survivor, drop it from the election (immediate — retirement is
        planned), and run the leader's lease housekeeping so any lease
        the victim's clients held is reclaimed, not leaked."""
        victim = self._pick_victim()
        if victim is None:
            return None
        moved = 0
        survivors = [nn for nn in self.cluster.alive_namenodes()
                     if nn.nn_id != victim.nn_id]
        if victim.ops.cache is not None:
            entries = victim.ops.cache.export_entries(self.prewarm_limit)
            for nn in survivors:
                if nn.ops.cache is not None:
                    nn.ops.cache.absorb(entries)
                    moved += len(entries)
        self.cluster.retire(victim.nn_id)
        self.cluster.recover_leases()
        self.cluster.scrub_leases()
        return self._record("scale_in", victim.nn_id, reason, moved)

    def _pick_victim(self) -> Optional[Namenode]:
        """Highest-id alive non-leader — late joiners retire first, and
        the leader never retires itself (its housekeeping must run the
        same tick to reclaim the victim's leases). With a breaker board
        attached, an OPEN-breaker namenode is preferred: shrinking the
        fleet should shed its gray-slow member, not a healthy one."""
        leader = self.cluster.election.leader()
        cands = [nn for nn in self.cluster.alive_namenodes()
                 if nn.nn_id != leader]
        if not cands:
            return None
        if self.breakers is not None:
            tripped = [nn for nn in cands
                       if self.breakers.is_open(nn.nn_id)]
            if tripped:
                return max(tripped, key=lambda nn: nn.nn_id)
        return max(cands, key=lambda nn: nn.nn_id)

    def _record(self, action: str, nn_id: int, reason: str,
                moved: int) -> ScaleEvent:
        ev = ScaleEvent(t=self.cluster.election.now, action=action,
                        nn_id=nn_id, reason=reason, migrated_entries=moved)
        self.events.append(ev)
        self.migrated_entries += moved
        if action == "scale_out":
            self.scale_outs += 1
        else:
            self.scale_ins += 1
        self.membership_epoch += 1
        self._last_action_t = ev.t
        self._high_streak = 0
        self._low_streak = 0
        for fn in self._subscribers:
            fn(ev)
        return ev
