"""Composable client-side call middleware (one implementation, three users).

The retry/failover behaviour that HopsFS clients implement — voluntary
re-try after a subtree-lock abort (§6.3) and transparent failover to
another namenode when one dies (§7.6.1) — used to be duplicated between
``Namenode._safe_exec``, ``Client.execute`` and ``RequestPipeline.run``.
It now lives here as middleware over a plain call chain:

    handler  = compose([failover(...), subtree_retry(...)], terminal)
    result   = handler(CallContext(op=..., wop=...))

A *terminal* handler performs one attempt (picking a namenode and invoking
the op through the registry) and records the namenode it used on the
context; middleware around it decide whether an exception is retryable.
``DFSClient`` accepts a custom middleware stack, so policies (more
aggressive backoff, circuit breaking, tracing) compose without touching
the namenode or the registry.

Overload protection (docs/ROBUSTNESS.md): every retrying middleware here
takes an injectable ``sleep`` (tests pass a fake clock — no wall-clock
sleeps), an optional ``jitter`` RNG that de-synchronizes backoff so
simultaneous aborters do not re-collide in lockstep (a retry herd), and
an optional shared ``budget`` (:class:`~repro.core.admission.RetryBudget`)
— a token bucket ALL retry middleware on a client draw from, so the
fleet-wide retry rate is bounded by a fraction of the call rate instead
of multiplying per-middleware attempt counters."""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

from .fs import SubtreeLockedError
from .ops_registry import REGISTRY, WorkloadOp
from .store import (LockTimeout, NetworkPartition, StoreError,
                    TransactionAborted)


@dataclass
class CallContext:
    """State threaded through one logical call (possibly many attempts)."""
    op: str
    wop: Optional[WorkloadOp] = None
    namenode: Any = None        # namenode used by the LAST attempt
    attempts: int = 0
    retries: int = 0            # subtree-abort + failover retries
    #: latest election-clock tick by which the call must complete (copied
    #: from ``wop.deadline`` by DFSClient); admission-aware namenodes shed
    #: the op once the clock passes it instead of executing stale work
    deadline: Optional[int] = None


Handler = Callable[[CallContext], Any]
Middleware = Callable[[Handler], Handler]


def compose(middleware: Sequence[Middleware], terminal: Handler) -> Handler:
    """Wrap ``terminal`` with the middleware, first entry outermost."""
    h = terminal
    for mw in reversed(list(middleware)):
        h = mw(h)
    return h


def _jittered(base: float, jitter: Optional[random.Random]) -> float:
    """Equal-jitter: half the nominal backoff deterministic, half random —
    concurrent retriers spread over [base/2, base) instead of re-colliding
    at exactly ``base`` (the classic synchronized retry herd)."""
    if jitter is None:
        return base
    return base * (0.5 + 0.5 * jitter.random())


def _spend(budget: Any, last: Exception) -> None:
    """Gate one retry on the shared token bucket: an exhausted budget
    surfaces the LAST error immediately instead of amplifying load."""
    if budget is not None and not budget.try_spend():
        raise last


def subtree_retry(retries: int = 8, backoff: float = 0.002,
                  sleep: Callable[[float], None] = time.sleep,
                  budget: Any = None) -> Middleware:
    """Ops that hit a live subtree lock voluntarily aborted (§6.3); retry
    them with linear backoff exactly as the HopsFS client does, surfacing
    :class:`SubtreeLockedError` once the attempt count — or the shared
    retry ``budget`` — is exhausted."""
    def mw(nxt: Handler) -> Handler:
        def handler(ctx: CallContext) -> Any:
            last: Optional[Exception] = None
            for attempt in range(max(1, retries)):
                try:
                    return nxt(ctx)
                except SubtreeLockedError as e:
                    last = e
                    if attempt < max(1, retries) - 1:
                        _spend(budget, e)
                    ctx.retries += 1
                    if backoff:
                        sleep(backoff * (attempt + 1))
            raise last  # type: ignore[misc]
        return handler
    return mw


def txn_retry(retries: int = 3, backoff: float = 0.005,
              sleep: Callable[[float], None] = time.sleep,
              budget: Any = None,
              jitter: Optional[random.Random] = None) -> Middleware:
    """Paper §7.5: transactions that hit the NDB inactive timeout (or were
    aborted by the engine) are automatically retried — the timed-out
    transaction aborted atomically, so re-running the op is safe and is
    exactly what the HopsFS DAL does (the client-side twin of
    ``transactions.run_with_retry``). Only genuinely concurrent execution
    can time out (a single-threaded run never waits on a row lock), so
    this middleware is inert on the deterministic pipelines; under
    concurrent workers it keeps a >1.2 s scheduler stall from surfacing a
    spurious mutation failure.

    Subtree ops are NOT retried here: they span many chunk transactions
    (§6 phase 3), so earlier chunks may already be committed when a later
    one times out — a blind re-run would return a partial count. Their
    timeout surfaces to the caller, exactly as before this middleware
    existed."""
    def mw(nxt: Handler) -> Handler:
        def handler(ctx: CallContext) -> Any:
            last: Optional[Exception] = None
            attempts = max(1, retries) + 1
            for attempt in range(attempts):
                try:
                    return nxt(ctx)
                except (LockTimeout, TransactionAborted) as e:
                    spec = REGISTRY.get(ctx.op)
                    if spec is not None and spec.subtree:
                        raise               # multi-txn op: not re-runnable
                    last = e
                    if attempt < attempts - 1:
                        _spend(budget, e)
                    ctx.retries += 1
                    if backoff and attempt < attempts - 1:
                        sleep(_jittered(backoff * (2 ** attempt), jitter))
            raise last  # type: ignore[misc]
        return handler
    return mw


def membership_refresh(pool: Any,
                       on_change: Callable[[CallContext], None]
                       ) -> Middleware:
    """Elastic-membership awareness for clients: before each attempt,
    compare the pool's ``membership_epoch`` against the epoch seen at the
    previous call through this middleware; on a change, invoke
    ``on_change(ctx)`` BEFORE the attempt proceeds. ``DFSClient`` wires
    ``on_change`` to drop its sticky namenode selection, so calls
    rebalance onto the new fleet lazily — in-flight calls are never
    interrupted, and leases survive because lease state lives in the
    store, not the namenode (the pool's scale-in already ran the leader's
    ``recover_leases``/``scrub_leases`` housekeeping)."""
    seen = [pool.membership_epoch]

    def mw(nxt: Handler) -> Handler:
        def handler(ctx: CallContext) -> Any:
            cur = pool.membership_epoch
            if cur != seen[0]:
                seen[0] = cur
                on_change(ctx)
            return nxt(ctx)
        return handler
    return mw


def failover(attempts: int = 8,
             on_failover: Optional[Callable[[CallContext], None]] = None,
             *, backoff: float = 0.0,
             sleep: Callable[[float], None] = time.sleep,
             jitter: Optional[random.Random] = None,
             budget: Any = None) -> Middleware:
    """Transparent namenode failover (§7.6.1): a :class:`StoreError` from a
    namenode that is now DEAD means the op was in flight when it died —
    retry elsewhere. A :class:`NetworkPartition` is retried even though
    the namenode is alive: to the client an unreachable namenode and a
    dead one are the same thing, and nothing executed on the other side.
    Errors from a live, reachable namenode are genuine outcomes
    (FileNotFound, quota, ...) and propagate unchanged.

    ``backoff`` (default 0 — failover itself is immediate, the dead
    namenode will not get better) enables exponential, jittered waits
    between attempts for deployments where partitions heal with time;
    the shared ``budget`` bounds how many failover retries the client
    may spend fleet-wide."""
    def mw(nxt: Handler) -> Handler:
        def handler(ctx: CallContext) -> Any:
            last: Optional[Exception] = None
            n = max(1, attempts)
            for attempt in range(n):
                try:
                    return nxt(ctx)
                except SubtreeLockedError:
                    raise               # inner middleware's business
                except StoreError as e:
                    nn = ctx.namenode
                    if isinstance(e, NetworkPartition) or (
                            nn is not None
                            and not getattr(nn, "alive", True)):
                        last = e
                        if attempt < n - 1:
                            _spend(budget, e)
                        ctx.retries += 1
                        if on_failover is not None:
                            on_failover(ctx)
                        if backoff and attempt < n - 1:
                            sleep(_jittered(backoff * (2 ** attempt),
                                            jitter))
                        continue
                    raise
            raise last  # type: ignore[misc]
        return handler
    return mw
