"""First-class operation protocol: the one table every layer reads.

Before this module existed, adding one FS operation meant editing five
parallel string tables (`Namenode._DISPATCH`, `execute_wop`'s hardcoded
defaults, `workload.READ_ONLY_OPS`, `BatchedHopsFSSim._BATCHABLE`, and the
`SpotifyWorkload` if-chain).  Now each operation is declared ONCE as an
:class:`OpSpec` in :data:`REGISTRY`:

  * handler binding  — which method on the namenode serves it
    (``ops.create``, ``subtree.delete_subtree``, ...);
  * argument schema  — extra arguments beyond the path(s), each with a
    default (a value, or a callable of the :class:`WorkloadOp`), so
    workload records can carry real arguments end-to-end instead of the
    executor hardcoding them;
  * semantic flags   — ``read_only`` (may never mutate), ``batchable``
    (the batched pipeline may group runs of it), ``subtree`` (goes through
    the §6 subtree protocol);
  * partition-hint derivation — whether the op's distribution-aware
    transaction should land on the *target* inode's partition (file ops:
    file-related rows live there) or the *parent*'s (namespace mutations).

Consumers: ``Namenode.invoke/execute_batch``, ``RequestPipeline``,
``DFSClient``, ``BatchedHopsFSSim``/``HDFSSim`` (DES), the workload
generator (via :data:`MIX_BINDINGS`, replacing the old if-chain), and the
benchmarks.  Registering a new op here — see ``docs/API.md`` — makes it
executable through every one of those layers with no dispatch edits;
``truncate`` and ``concat`` below are the proof.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from .store import READ_COMMITTED
from .tables import ROOT_ID

#: sentinel: the argument has no default and MUST be supplied by the caller
REQUIRED = object()


@dataclass
class WorkloadOp:
    """The canonical operation record: what clients submit, what traces
    are made of, and what the registry knows how to execute.  ``args``
    carries the op's real extra arguments (perm, owner, repl, sizes, ...)
    end-to-end; missing keys fall back to the :class:`OpSpec` defaults."""
    op: str
    path: str
    path2: Optional[str] = None
    on_dir: bool = False
    args: Dict[str, Any] = field(default_factory=dict)
    #: admission-control metadata (repro.core.admission): the latest
    #: election-clock tick by which this op must COMPLETE (None = no
    #: deadline — never shed), and the billing tenant the weighted
    #: fair queue accounts it to (None = the anonymous tenant).
    deadline: Optional[int] = None
    tenant: Optional[str] = None


@dataclass(frozen=True)
class ArgSpec:
    """One extra argument of an op: its name and default.  The default may
    be a plain value or a callable of the WorkloadOp (e.g. rename's
    destination defaults to ``wop.path + ".mv"``); :data:`REQUIRED` means
    the caller must supply it."""
    name: str
    default: Any = REQUIRED

    def value_for(self, wop: WorkloadOp) -> Any:
        if self.name in wop.args:
            return wop.args[self.name]
        if self.default is REQUIRED:
            raise TypeError(
                f"op {wop.op!r} requires argument {self.name!r} "
                f"(supply it in WorkloadOp.args)")
        return self.default(wop) if callable(self.default) else self.default


@dataclass
class GroupWriteCtx:
    """Validated lock-phase state handed to a :attr:`OpSpec.group_apply`
    execute phase: the (cache-fresh) parent and target rows, the path
    components, and the op's keyword arguments."""
    parent: Dict[str, Any]
    target: Optional[Dict[str, Any]]
    comps: List[str]
    path: str
    kw: Dict[str, Any]


@dataclass(frozen=True)
class OpSpec:
    """Declaration of one file-system operation."""
    name: str
    holder: str                      # attribute on Namenode: "ops"|"subtree"
    method: str                      # method name on that holder
    args: Tuple[ArgSpec, ...] = ()
    paths: int = 1                   # positional path args (0, 1 or 2)
    read_only: bool = False
    batchable: bool = False
    subtree: bool = False
    hint: str = "target"             # partition-hint derivation: see below
    # batchable ops only: the payload phase run inside the shared grouped
    # transaction, (fsops, txn, target_row) -> value.  MUST be the same
    # helper the sequential handler uses, so the two paths cannot diverge.
    batch_payload: Optional[Callable[[Any, Any, Dict[str, Any]], Any]] = None
    # the op's lock phase folds a dependent lease read into the validation
    # exchange (§5.1) — mirrored by the grouped executor
    lease_read: bool = False
    # removes or moves namespace rows (delete/rename/truncate/concat):
    # the batch planner never reorders these across other ops — a read
    # hopping over one would spuriously fail
    destructive: bool = False
    # mutations the grouped WRITE path may share a transaction across
    # (create/mkdirs/setattr-class): group_apply is the execute phase,
    # (fsops, txn, GroupWriteCtx) -> value, and MUST be built from the same
    # fs.py helpers the sequential handler uses. group_aux lists the
    # dependent lock-phase reads folded into the shared validation exchange,
    # (kw, parent_id, target_row) -> [(table, pk, lock), ...].
    group_mutable: bool = False
    group_apply: Optional[Callable[[Any, Any, GroupWriteCtx], Any]] = None
    group_aux: Optional[Callable[[Dict[str, Any], int,
                                  Optional[Dict[str, Any]]],
                                 List[Tuple[str, Tuple[Any, ...], str]]]] \
        = None
    # lease-ordered block writes (add_block/append/complete_block): ops
    # sharing a lease_order key (the file path == the per-inode lease) must
    # apply in submission order — block indices and under-construction
    # state depend on it — while ops with DIFFERENT keys may batch freely
    # across files. The batch planner keeps same-key ops in submission
    # order through its stable (partition, type) sort instead of pinning
    # them out of the groupable stream.
    lease_order: Optional[Callable[[WorkloadOp], Any]] = None
    # the handler itself stamps the client's lease inside its transaction
    # (create/append via lease_write, renew_lease by definition): the RPC
    # layer's piggybacked touch_lease would be a redundant second lock
    # round trip on the same row, so it skips these
    renews_lease: bool = False

    def __post_init__(self) -> None:
        assert self.paths in (0, 1, 2)
        assert self.hint in ("target", "parent")
        assert not (self.batchable and not self.read_only), \
            f"{self.name}: only read-only ops may be batched"
        assert not (self.batchable and self.batch_payload is None), \
            f"{self.name}: batchable ops must declare batch_payload"
        assert not (self.group_mutable and self.read_only), \
            f"{self.name}: group_mutable is for mutations (use batchable)"
        assert not (self.group_mutable and
                    (self.group_apply is None or self.paths != 1
                     or self.subtree)), \
            f"{self.name}: group_mutable needs group_apply and a single " \
            f"non-subtree path"

    @property
    def has_client_arg(self) -> bool:
        """The op is executed on behalf of a named client (its arg schema
        carries ``client``). Such ops double as lease heartbeats: the
        namenode RPC layer refreshes the executing client's lease stamp
        after any successful op (``HopsFSOps.touch_lease``, skipped when
        ``renews_lease`` says the handler already stamped it), so a
        steadily-writing holder never expires — piggybacked renewal."""
        return any(a.name == "client" for a in self.args)

    # -- execution ------------------------------------------------------
    def resolve(self, namenode: Any) -> Callable[..., Any]:
        """Bind the handler on a namenode (``ops``/``subtree`` holder)."""
        return getattr(getattr(namenode, self.holder), self.method)

    def path_args(self, wop: WorkloadOp) -> List[str]:
        """The op's positional path arguments, with rename's implicit
        destination default applied — THE one place the ``path + ".mv"``
        rule lives (the planner's conflict analysis and the client-side
        invalidation rule both resolve paths through here)."""
        paths: List[str] = []
        if self.paths >= 1:
            paths.append(wop.path)
        if self.paths == 2:
            paths.append(wop.path2 if wop.path2 is not None
                         else wop.path + ".mv")
        return paths

    def call_args(self, wop: WorkloadOp) -> Tuple[List[str], Dict[str, Any]]:
        """Positional path args + keyword args for one workload record:
        the record's own ``args`` overlaid on the spec defaults."""
        return (self.path_args(wop),
                {a.name: a.value_for(wop) for a in self.args})

    # -- partition-hint derivation --------------------------------------
    def hint_components(self, path_components: Sequence[str]
                        ) -> Sequence[str]:
        """The path chain whose last resolved inode id is the op's
        distribution-aware transaction hint: the target itself for file
        ops, the parent directory for namespace mutations (the new/removed
        row lives on the PARENT's shard — inode partitioning is by
        parent_id, §4.2)."""
        return (path_components[:-1] if self.hint == "parent"
                else path_components)

    def hint_id(self, ops: Any, path_components: Sequence[str]) -> int:
        """Hinted inode id via the namenode's hint cache (ROOT if cold)."""
        if ops.cache is None:
            return ROOT_ID
        v = ops.cache.last_resolved_id(self.hint_components(path_components))
        return v if v is not None else ROOT_ID

    def sim_partition(self, path: str, n_partitions: int) -> int:
        """Path -> partition approximation used by the DES, derived from
        the same hint rule (hash the hint path, not always the full path).
        Must stay deterministic and cheap — the DES calls it per op."""
        comps = [c for c in path.split("/") if c]
        key = "/".join(self.hint_components(comps)) or "/"
        return zlib.crc32(key.encode()) % n_partitions


class OpRegistry:
    """Ordered name -> :class:`OpSpec` mapping with derived views."""

    def __init__(self) -> None:
        self._specs: "Dict[str, OpSpec]" = {}

    def register(self, spec: OpSpec, *, replace: bool = False) -> OpSpec:
        if spec.name in self._specs and not replace:
            raise ValueError(f"op {spec.name!r} already registered")
        self._specs[spec.name] = spec
        return spec

    def unregister(self, name: str) -> None:
        self._specs.pop(name, None)

    def get(self, name: str) -> Optional[OpSpec]:
        return self._specs.get(name)

    def __getitem__(self, name: str) -> OpSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown op {name!r}; registered: {sorted(self._specs)}"
            ) from None

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self) -> Iterator[OpSpec]:
        return iter(self._specs.values())

    def names(self) -> Tuple[str, ...]:
        return tuple(self._specs)

    # -- derived tables (the old parallel string tables, now views) -----
    def read_only_ops(self) -> frozenset:
        return frozenset(s.name for s in self if s.read_only)

    def batchable_ops(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self if s.batchable)

    def group_mutable_ops(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self if s.group_mutable)

    def lease_ordered_ops(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self if s.lease_order is not None)

    def subtree_ops(self) -> frozenset:
        return frozenset(s.name for s in self if s.subtree)


REGISTRY = OpRegistry()


def register_op(name: str, holder: str, method: str, *,
                args: Sequence[Tuple[str, Any]] = (), paths: int = 1,
                read_only: bool = False, batchable: bool = False,
                subtree: bool = False, hint: str = "target",
                batch_payload: Optional[Callable[..., Any]] = None,
                lease_read: bool = False, destructive: bool = False,
                group_mutable: bool = False,
                group_apply: Optional[Callable[..., Any]] = None,
                group_aux: Optional[Callable[..., Any]] = None,
                lease_order: Optional[Callable[..., Any]] = None,
                renews_lease: bool = False,
                registry: OpRegistry = REGISTRY,
                replace: bool = False) -> OpSpec:
    """Convenience declaration helper (also the public extension point)."""
    spec = OpSpec(name=name, holder=holder, method=method,
                  args=tuple(ArgSpec(n, d) for n, d in args), paths=paths,
                  read_only=read_only, batchable=batchable, subtree=subtree,
                  hint=hint, batch_payload=batch_payload,
                  lease_read=lease_read, destructive=destructive,
                  group_mutable=group_mutable,
                  group_apply=group_apply, group_aux=group_aux,
                  lease_order=lease_order, renews_lease=renews_lease)
    return registry.register(spec, replace=replace)


# ---------------------------------------------------------------------------
# Default operation set (paper Table 1 + block protocol + subtree ops §6)
# ---------------------------------------------------------------------------

# grouped-execution payload phases: the SAME fs.py helpers the sequential
# handlers use, so batched and sequential execution cannot diverge
def _payload_read(fsops: Any, txn: Any, target: Dict[str, Any]) -> Any:
    return fsops.read_payload(txn, target)


def _payload_stat(fsops: Any, txn: Any, target: Dict[str, Any]) -> Any:
    return fsops.stat_payload(target)


def _payload_ls(fsops: Any, txn: Any, target: Dict[str, Any]) -> Any:
    return fsops.listing_payload(txn, target)


# grouped write-path execute phases: the SAME fs.py apply helpers the
# sequential handlers run after their lock phase, so grouped and sequential
# mutations cannot diverge (state equivalence is asserted by
# tests/test_batched_pipeline.py)
def _apply_create(fsops: Any, txn: Any, ctx: GroupWriteCtx) -> Any:
    return fsops.create_apply(txn, ctx.parent, ctx.target, ctx.comps[-1],
                              ctx.path, **ctx.kw)


def _apply_mkdirs(fsops: Any, txn: Any, ctx: GroupWriteCtx) -> Any:
    # ancestors were validated present by the grouped lock phase, so only
    # the leaf mkdir remains; an existing leaf is mkdirs' sequential no-op
    if ctx.target is not None:
        return None
    return fsops.mkdir_apply(txn, ctx.parent, ctx.target, ctx.comps[-1],
                             ctx.path, **ctx.kw)


def _apply_setattr(field: str) -> Callable[[Any, Any, GroupWriteCtx], Any]:
    def apply(fsops: Any, txn: Any, ctx: GroupWriteCtx) -> Any:
        value = ctx.kw[field]
        return fsops.setattr_apply(txn, ctx.target, ctx.path,
                                   lambda n: n.update({field: value}))
    return apply


def _aux_create(kw: Dict[str, Any], parent_id: int,
                target: Optional[Dict[str, Any]]
                ) -> List[Tuple[str, Tuple[Any, ...], str]]:
    return [("lease", (kw.get("client", "client"),), READ_COMMITTED),
            ("quota", (parent_id,), READ_COMMITTED)]


def _aux_setattr(kw: Dict[str, Any], parent_id: int,
                 target: Optional[Dict[str, Any]]
                 ) -> List[Tuple[str, Tuple[Any, ...], str]]:
    client = (target.get("client") or "client") if target else "client"
    return [("lease", (client,), READ_COMMITTED),
            ("quota", (parent_id,), READ_COMMITTED)]


# lease-ordered block writes: the SAME fs.py apply helpers the sequential
# add_block/append_file/complete_block handlers run after their lock phase
def _apply_add_block(fsops: Any, txn: Any, ctx: GroupWriteCtx) -> Any:
    return fsops.add_block_apply(txn, ctx.target, ctx.path, **ctx.kw)


def _apply_append(fsops: Any, txn: Any, ctx: GroupWriteCtx) -> Any:
    return fsops.append_apply(txn, ctx.target, ctx.path, **ctx.kw)


def _apply_complete_block(fsops: Any, txn: Any, ctx: GroupWriteCtx) -> Any:
    return fsops.complete_block_apply(txn, ctx.target, ctx.path, **ctx.kw)


def _aux_lease_holder(kw: Dict[str, Any], parent_id: int,
                      target: Optional[Dict[str, Any]]
                      ) -> List[Tuple[str, Tuple[Any, ...], str]]:
    """The dependent lease read of the block ops' lock phases: the file's
    current holder for add_block/complete_block, the requesting client for
    append (which is about to take the lease over)."""
    client = (target.get("client") or kw.get("client", "client")) \
        if target else kw.get("client", "client")
    return [("lease", (client,), READ_COMMITTED)]


def _aux_lease_client(kw: Dict[str, Any], parent_id: int,
                      target: Optional[Dict[str, Any]]
                      ) -> List[Tuple[str, Tuple[Any, ...], str]]:
    return [("lease", (kw.get("client", "client"),), READ_COMMITTED)]


def _lease_key_path(wop: WorkloadOp) -> Any:
    """Per-inode lease-order key: the file path (one lease per file)."""
    return wop.path


register_op("create", "ops", "create",
            args=(("repl", 3), ("client", "client"), ("overwrite", False)),
            hint="parent", group_mutable=True, group_apply=_apply_create,
            group_aux=_aux_create, renews_lease=True)
register_op("read", "ops", "get_block_locations",
            read_only=True, batchable=True, batch_payload=_payload_read,
            lease_read=True)
register_op("ls", "ops", "listing", read_only=True, batchable=True,
            batch_payload=_payload_ls)
register_op("stat", "ops", "stat", read_only=True, batchable=True,
            batch_payload=_payload_stat, lease_read=True)
register_op("mkdir", "ops", "mkdir", args=(("perm", 0o755),), hint="parent")
register_op("mkdirs", "ops", "mkdirs", args=(("perm", 0o755),),
            hint="parent", group_mutable=True, group_apply=_apply_mkdirs)
register_op("delete_file", "ops", "delete_file", hint="parent",
            destructive=True)
register_op("rename_file", "ops", "rename_file", paths=2, hint="parent",
            destructive=True)
register_op("add_block", "ops", "add_block",
            args=(("client", "client"),),
            group_mutable=True, group_apply=_apply_add_block,
            group_aux=_aux_lease_holder, lease_order=_lease_key_path)
# NOTE: no group_aux — the sequential complete_block lock phase performs
# no lease read (its _check_lease consults the charge-free txn.peek), so
# the grouped path must not charge one either: grouped and sequential
# OpCost profiles for the same op stay identical (Table 3)
register_op("complete_block", "ops", "complete_block",
            args=(("block_id", -1), ("size", REQUIRED),
                  ("client", "client")),
            group_mutable=True, group_apply=_apply_complete_block,
            lease_order=_lease_key_path)
register_op("append", "ops", "append_file", args=(("client", "client"),),
            group_mutable=True, group_apply=_apply_append,
            group_aux=_aux_lease_client, lease_order=_lease_key_path,
            renews_lease=True)
register_op("renew_lease", "ops", "renew_lease", paths=0,
            args=(("client", "client"),), renews_lease=True)
# client-initiated soft-limit lease takeover (HDFS recoverLease): the new
# writer forces recovery of an expired lease instead of waiting for the
# leader's sweep — see HopsFSOps.recover_lease
register_op("recover_lease", "ops", "recover_lease",
            args=(("client", "client"),))
register_op("chmod_file", "ops", "chmod_file", args=(("perm", 0o640),),
            group_mutable=True, group_apply=_apply_setattr("perm"),
            group_aux=_aux_setattr)
register_op("chown_file", "ops", "chown_file", args=(("owner", "wluser"),),
            group_mutable=True, group_apply=_apply_setattr("owner"),
            group_aux=_aux_setattr)
register_op("set_replication", "ops", "set_replication",
            args=(("repl", 2),),
            group_mutable=True, group_apply=_apply_setattr("repl"),
            group_aux=_aux_setattr)
register_op("content_summary", "ops", "content_summary", read_only=True)
register_op("du", "ops", "du", read_only=True)
register_op("set_quota", "ops", "set_quota",
            args=(("ns_quota", -1), ("ss_quota", -1)))
register_op("truncate", "ops", "truncate", args=(("new_size", 0),),
            destructive=True)
register_op("concat", "ops", "concat", args=(("srcs", REQUIRED),),
            destructive=True)
register_op("delete_subtree", "subtree", "delete_subtree", subtree=True,
            destructive=True)
register_op("rename_subtree", "subtree", "rename_subtree", paths=2,
            subtree=True, hint="parent", destructive=True)
register_op("chmod_subtree", "subtree", "chmod_subtree",
            args=(("perm", 0o640),), subtree=True)
register_op("chown_subtree", "subtree", "chown_subtree",
            args=(("owner", "wluser"),), subtree=True)
register_op("block_report", "ops", "process_block_report", paths=0,
            args=(("datanode_id", REQUIRED), ("block_ids", REQUIRED)))


# ---------------------------------------------------------------------------
# Workload synthesis bindings (replaces the SpotifyWorkload if-chain)
# ---------------------------------------------------------------------------
#
# A *mix name* (Table 1 / §7.2 vocabulary: "delete", "set_permissions", ...)
# maps to registered ops via a builder that samples a target and REAL
# arguments from the workload context.  The context protocol (implemented by
# SpotifyWorkload) is: ``rng`` (random.Random), ``live_file()``,
# ``live_dir()``, ``retire(path, is_dir)``, ``next_create_path()``.

#: realistic argument pools the builders sample from
_PERM_POOL = (0o644, 0o640, 0o755, 0o750, 0o700)
_OWNER_POOL = tuple(f"user{i}" for i in range(8))
_REPL_POOL = (1, 2, 3)
#: sampled sizes for completed blocks (64 MiB HDFS default ± partials)
_BLOCK_SIZE_POOL = (1 << 26, 1 << 25, 1 << 24, 1 << 20)

MixBuilder = Callable[[Any, bool], WorkloadOp]


def _mix_mkdirs(ctx: Any, on_dir: bool) -> WorkloadOp:
    d = ctx.live_dir()
    return WorkloadOp("mkdirs", f"{d}/new{ctx.rng.randrange(1 << 30):x}",
                      on_dir=True)


def _mix_create(ctx: Any, on_dir: bool) -> WorkloadOp:
    return WorkloadOp("create", ctx.next_create_path(),
                      args={"repl": ctx.rng.choice(_REPL_POOL)})


def _mix_add_block(ctx: Any, on_dir: bool) -> WorkloadOp:
    return WorkloadOp("add_block", ctx.live_file())


def _mix_rename(ctx: Any, on_dir: bool) -> WorkloadOp:
    src = ctx.live_file()
    ctx.retire(src, is_dir=False)
    return WorkloadOp("rename_file", src, src + ".mv", on_dir=on_dir)


def _mix_delete(ctx: Any, on_dir: bool) -> WorkloadOp:
    if on_dir:
        d = ctx.live_dir()
        ctx.retire(d, is_dir=True)
        return WorkloadOp("delete_subtree", d, on_dir=True)
    f = ctx.live_file()
    ctx.retire(f, is_dir=False)
    return WorkloadOp("delete_file", f)


def _mix_set_permissions(ctx: Any, on_dir: bool) -> WorkloadOp:
    p = ctx.live_dir() if on_dir else ctx.live_file()
    return WorkloadOp("chmod_subtree" if on_dir else "chmod_file", p,
                      on_dir=on_dir,
                      args={"perm": ctx.rng.choice(_PERM_POOL)})


def _mix_set_owner(ctx: Any, on_dir: bool) -> WorkloadOp:
    p = ctx.live_dir() if on_dir else ctx.live_file()
    return WorkloadOp("chown_subtree" if on_dir else "chown_file", p,
                      on_dir=on_dir,
                      args={"owner": ctx.rng.choice(_OWNER_POOL)})


def _mix_set_replication(ctx: Any, on_dir: bool) -> WorkloadOp:
    return WorkloadOp("set_replication", ctx.live_file(),
                      args={"repl": ctx.rng.choice(_REPL_POOL)})


def _mix_read(ctx: Any, on_dir: bool) -> WorkloadOp:
    return WorkloadOp("read", ctx.live_file())


def _mix_append(ctx: Any, on_dir: bool) -> WorkloadOp:
    return WorkloadOp("append", ctx.live_file())


def _mix_complete(ctx: Any, on_dir: bool) -> WorkloadOp:
    # block ids are allocated at replay time, so trace records complete
    # "the last allocated block" (block_id=-1) with a sampled size
    return WorkloadOp("complete_block", ctx.live_file(),
                      args={"block_id": -1,
                            "size": ctx.rng.choice(_BLOCK_SIZE_POOL)})


def _target_file_or_dir(op: str) -> MixBuilder:
    def build(ctx: Any, on_dir: bool) -> WorkloadOp:
        p = ctx.live_dir() if on_dir else ctx.live_file()
        return WorkloadOp(op, p, on_dir=on_dir)
    return build


#: mix-name -> builder; every produced op name must be in :data:`REGISTRY`
MIX_BINDINGS: Dict[str, MixBuilder] = {
    "mkdirs": _mix_mkdirs,
    "create": _mix_create,
    "add_block": _mix_add_block,
    "rename": _mix_rename,
    "delete": _mix_delete,
    "set_permissions": _mix_set_permissions,
    "set_owner": _mix_set_owner,
    "set_replication": _mix_set_replication,
    "append": _mix_append,
    "complete": _mix_complete,
    "read": _mix_read,
    "ls": _target_file_or_dir("ls"),
    "stat": _target_file_or_dir("stat"),
    "content_summary": _target_file_or_dir("content_summary"),
    "du": _target_file_or_dir("du"),
}


def synthesize(mix_name: str, ctx: Any, on_dir: bool) -> WorkloadOp:
    """Build one workload record for a mix entry; unknown mix names fall
    back to a read on a live file (the dominant op of every mix)."""
    builder = MIX_BINDINGS.get(mix_name, _mix_read)
    return builder(ctx, on_dir)
