"""Paper cost models: Table 3 (round trips per op) and Table 2 (capacity).

Table 3 counts database **round trips** per file-system op as a function of
path depth N, for (a) no inode-hint cache and (b) cache hits. One round trip
is a single PK op, one batch, one PPIS, one IS, or one FTS. ``f_s`` is file
size (0 = empty); we expose both variants.

These symbolic formulas are compared against the *measured* OpCost profiles
of the live implementation by ``benchmarks/bench_table3_costmodel.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from .tables import (NDB_MAX_DATANODES, NDB_MAX_RAM_PER_NODE_GB,
                     hdfs_capacity_files, hopsfs_capacity_files)


@dataclass(frozen=True)
class RTBreakdown:
    """Round trips by access path (the Table 3 vocabulary)."""
    pk_rc: int = 0
    pk_r: int = 0
    pk_w: int = 0
    batches: int = 0
    ppis: int = 0
    is_scans: int = 0

    @property
    def total(self) -> int:
        return (self.pk_rc + self.pk_r + self.pk_w + self.batches
                + self.ppis + self.is_scans)


def table3(op: str, n: int, *, cached: bool, empty_file: bool = True,
           is_dir: bool = False) -> RTBreakdown:
    """Paper Table 3 formulas (inode ops only; subtree ops are sums over
    the tree and are benchmarked structurally instead)."""
    e = empty_file
    if op == "mkdir":
        return (RTBreakdown(pk_w=2, batches=2) if cached
                else RTBreakdown(pk_rc=n - 2, pk_w=2, batches=1))
    if op == "create":  # empty-file create excl. addBlock terms
        ppis = 2 if e else 8
        return (RTBreakdown(pk_w=5, batches=4, ppis=ppis) if cached
                else RTBreakdown(pk_rc=2 * n - 3, pk_w=5, batches=2,
                                 ppis=ppis))
    if op == "addblk":
        ppis = 2 if e else 6
        return (RTBreakdown(pk_w=1, pk_r=1, batches=2, ppis=ppis) if cached
                else RTBreakdown(pk_rc=n - 1, pk_w=1, pk_r=1, batches=1,
                                 ppis=ppis))
    if op == "read":
        ppis = 1 if e else 5
        return (RTBreakdown(pk_r=1, batches=2, ppis=ppis) if cached
                else RTBreakdown(pk_rc=n - 1, pk_r=1, batches=1, ppis=ppis))
    if op == "ls":
        ppis = 1 if is_dir else 0
        return (RTBreakdown(pk_r=1, batches=1, ppis=ppis) if cached
                else RTBreakdown(pk_rc=n - 1, pk_r=1, ppis=ppis))
    if op == "stat":
        return (RTBreakdown(pk_r=1, batches=2) if cached
                else RTBreakdown(pk_rc=n - 1, pk_r=1, batches=1))
    if op == "chmod":
        extra = dict(is_scans=1) if is_dir else dict(ppis=1)
        return (RTBreakdown(pk_w=2, batches=4, **extra) if cached
                else RTBreakdown(pk_rc=2 * n - 2, pk_w=2, batches=2,
                                 **extra))
    if op == "delete":  # file delete
        ppis = 2 if e else 7
        return (RTBreakdown(pk_w=2, batches=4, ppis=ppis) if cached
                else RTBreakdown(pk_rc=2 * n - 2, pk_w=2, batches=2,
                                 ppis=ppis))
    raise KeyError(op)


# -- the worked example from §7.7 -------------------------------------------

def create_depth10_roundtrips() -> Dict[str, int]:
    """Paper: create /1/d2/.../d9/f at N=10 costs 26 RTs without the cache
    and 11 with, a saving of 15 RTs ≈ 58%."""
    miss = table3("create", 10, cached=False).total
    hit = table3("create", 10, cached=True).total
    return {"no_cache": miss, "cache": hit, "saved": miss - hit,
            "improvement_pct": round(100 * (miss - hit) / miss)}


# -- Table 2 -----------------------------------------------------------------

def table2() -> Dict[str, Dict[str, Optional[float]]]:
    rows = {}
    for label, gb in [("1 GB", 1), ("50 GB", 50), ("100 GB", 100),
                      ("200 GB", 200), ("500 GB", 500), ("1 TB", 1024),
                      ("24 TB", 24 * 1024)]:
        rows[label] = {"hdfs": hdfs_capacity_files(gb),
                       "hopsfs": hopsfs_capacity_files(gb)}
    return rows


def capacity_headline() -> Dict[str, float]:
    """HopsFS stores 24x more metadata: NDB max cluster (48 dn x 512 GB =
    24 TB => 10.8 B files) vs HDFS practical max (200 GB JVM => ~0.45 B)."""
    ndb_total_gb = NDB_MAX_DATANODES * NDB_MAX_RAM_PER_NODE_GB
    hops = hopsfs_capacity_files(ndb_total_gb)
    hdfs = hdfs_capacity_files(200)
    assert hdfs is not None
    return {"hopsfs_files": hops, "hdfs_files": hdfs,
            "ratio": hops / hdfs}
