"""Industrial workload generator (paper Table 1 + §7.4).

Reproduces the Spotify HDFS trace characteristics:

  * relative op frequencies of Table 1 (reads 68.73%, stat 17%, ls 9%, ...),
    including the per-op directory/file split where the paper gives it;
  * namespace shape: average path depth 7, ~16 files + 2 subdirs per
    directory, average name length 34;
  * heavy-tailed access popularity (Yahoo: 3% of files take 80% of
    accesses) via a Zipf-like sampler.
"""
from __future__ import annotations

import random
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .ops_registry import REGISTRY, WorkloadOp, synthesize
from .tables import ROOT_ID

# (op, weight_pct, fraction_on_directories)
TABLE1_MIX: List[Tuple[str, float, float]] = [
    ("append",          0.0,   0.0),
    ("mkdirs",          0.02,  1.0),
    ("set_replication", 0.14,  0.0),
    ("delete",          0.75,  0.035),
    ("rename",          1.3,   0.0003),
    ("ls",              9.0,   0.945),
    ("read",            68.73, 0.0),
    ("content_summary", 0.01,  0.5),
    ("set_permissions", 0.03,  0.263),
    ("set_owner",       0.32,  1.0),
    ("create",          1.2,   0.0),
    ("add_block",       1.5,   0.0),
    ("stat",            17.0,  0.233),
]

# derived from the op registry (single source of truth for op semantics);
# the name survives for importers
READ_ONLY_OPS = REGISTRY.read_only_ops()

# Spotify operational trace mix (paper §7.2): the throughput-scaling
# experiment replays the production trace rather than the steady-state
# Table 1 mix — getBlockLocations dominates (~67%), listStatus is ~12%.
# Same (op, weight_pct, fraction_on_directories) schema as TABLE1_MIX.
SPOTIFY_TRACE_MIX: List[Tuple[str, float, float]] = [
    ("read",            67.0, 0.0),    # getBlockLocations
    ("ls",              12.0, 0.95),   # listStatus
    ("stat",            10.0, 0.25),   # getFileInfo
    ("create",           3.5, 0.0),
    ("add_block",        2.0, 0.0),
    ("delete",           1.5, 0.03),
    ("rename",           1.0, 0.0),
    ("mkdirs",           1.0, 1.0),
    ("set_permissions",  0.5, 0.25),
    ("set_owner",        0.5, 1.0),
    ("set_replication",  0.5, 0.0),
    ("content_summary",  0.3, 0.5),
    ("append",           0.2, 0.0),
]

# Write-heavy block-layer mix (ingest-shaped: the paper's Spotify trace is
# write-heavy AT THE BLOCK LAYER — every created file streams several
# blocks through addBlock/complete before readers arrive). This is the
# mix that exercises the lease-ordered grouped block-write path:
# create/add_block/complete/append dominate, reads are the minority.
# Same (op, weight_pct, fraction_on_directories) schema as TABLE1_MIX;
# "complete" records carry block_id=-1 ("last allocated block") + a
# sampled size, since block ids only exist at replay time.
WRITE_HEAVY_MIX: List[Tuple[str, float, float]] = [
    ("create",          14.0, 0.0),
    ("add_block",       24.0, 0.0),
    ("complete",        12.0, 0.0),
    ("append",           8.0, 0.0),
    ("read",            22.0, 0.0),
    ("stat",             7.0, 0.25),
    ("ls",               5.0, 0.95),
    ("mkdirs",           2.5, 1.0),
    ("set_permissions",  1.5, 0.25),
    ("set_replication",  1.5, 0.0),
    ("set_owner",        0.8, 1.0),
    ("delete",           0.7, 0.03),
    ("rename",           0.5, 0.0),
    ("content_summary",  0.5, 0.5),
]


# Adjacent-traffic mix for the big-directory scenario: what the cluster
# keeps serving NEXT TO a paced delete-subtree.  Read-heavy like the
# Spotify mix but with a visible deep-aggregation share (du +
# content_summary — the ops the treeagg kernel fuses on the columnar
# backend).  Deliberately NO subtree-mutating ops ("delete"/"rename" on
# dirs): the pace hook replays these records from inside a running
# subtree op, which must never nest another one.
# Same (op, weight_pct, fraction_on_directories) schema as TABLE1_MIX.
BIG_DIR_MIX: List[Tuple[str, float, float]] = [
    ("read",            33.0, 0.0),
    ("stat",            15.0, 0.25),
    ("ls",              13.0, 0.9),
    ("create",          12.0, 0.0),
    ("du",               8.0, 0.7),
    ("content_summary",  7.0, 0.7),
    ("mkdirs",           5.0, 1.0),
    ("set_permissions",  4.0, 0.0),
    ("set_owner",        3.0, 0.0),
]


@dataclass
class NamespaceSpec:
    """Spotify-like namespace shape (§7.4)."""
    depth: int = 7
    files_per_dir: int = 16
    dirs_per_dir: int = 2
    name_len: int = 34
    seed: int = 7


class SyntheticNamespace:
    """Builds a namespace matching the spec and serves popularity-weighted
    path samples. Paths are materialized lazily per directory level."""

    def __init__(self, spec: NamespaceSpec, *, n_dirs: int = 200,
                 files_per_dir: Optional[int] = None):
        self.spec = spec
        self.rng = random.Random(spec.seed)
        self.dirs: List[str] = []
        self.files: List[str] = []
        fpd = files_per_dir if files_per_dir is not None \
            else spec.files_per_dir
        # build a tree of depth `spec.depth` with the right fanout, capped
        # at n_dirs directories
        frontier = ["/w"]
        self.dirs.append("/w")
        depth = 1
        while len(self.dirs) < n_dirs and depth < spec.depth:
            nxt = []
            for d in frontier:
                for k in range(spec.dirs_per_dir):
                    sub = f"{d}/{self._name(depth, k)}"
                    self.dirs.append(sub)
                    nxt.append(sub)
                    if len(self.dirs) >= n_dirs:
                        break
                if len(self.dirs) >= n_dirs:
                    break
            frontier = nxt or frontier
            depth += 1
        leaf_dirs = [d for d in self.dirs]
        for d in leaf_dirs:
            for i in range(fpd):
                self.files.append(f"{d}/f{i:04d}.parquet")
        # heavy-tailed popularity: rank files by a Zipf(1.1)-ish law
        self._pop_weights = [1.0 / (r + 1) ** 1.1
                             for r in range(len(self.files))]

    def _name(self, depth: int, k: int) -> str:
        base = f"d{depth}x{k}"
        pad = max(0, self.spec.name_len - len(base) - 20)
        return base + "u" * min(pad, 8)

    def sample_file(self, rng: random.Random) -> str:
        return rng.choices(self.files, weights=self._pop_weights, k=1)[0]

    def sample_dir(self, rng: random.Random) -> str:
        return rng.choice(self.dirs)


def make_big_dir_namespace(n_children: int, *, n_side_dirs: int = 12,
                           files_per_dir: int = 4, seed: int = 7,
                           big_path: str = "/bigdir"
                           ) -> Tuple[SyntheticNamespace, str, int]:
    """Namespace plan for the big-directory scenario: a small *side*
    namespace serving adjacent traffic, plus one flat directory of
    ``n_children`` files that subtree ops target (materialize it with
    ``namenode.materialize_big_dir``).  The big dir is NOT in the side
    namespace's live path sets, so sampled adjacent ops never collide
    with the subtree lock.  Returns ``(side_ns, big_path, n_children)``."""
    ns = SyntheticNamespace(NamespaceSpec(seed=seed), n_dirs=n_side_dirs,
                            files_per_dir=files_per_dir)
    return ns, big_path, n_children


class SpotifyWorkload:
    """Stream of WorkloadOps distributed per an op mix (Table 1 by default;
    pass ``mix=SPOTIFY_TRACE_MIX`` for the §7.2 trace-replay mix).

    Op synthesis is driven by the registry's ``MIX_BINDINGS`` (this class
    only implements the sampling context protocol: ``rng``, ``live_file``,
    ``live_dir``, ``retire``, ``next_create_path``), so records carry real
    arguments — sampled perms, owners, replication factors — end-to-end
    instead of the executor hardcoding defaults."""

    def __init__(self, ns: SyntheticNamespace, seed: int = 13,
                 mix: Sequence[Tuple[str, float, float]] = TABLE1_MIX):
        self.ns = ns
        self.rng = random.Random(seed)
        self.mix = list(mix)
        self._ops = [m[0] for m in self.mix]
        self._weights = [m[1] for m in self.mix]
        self._dir_frac = {m[0]: m[2] for m in self.mix}
        self._create_seq = 0
        # liveness tracking: a real trace doesn't read files it already
        # deleted/renamed, so destructive ops retire their targets from
        # the sampling pool
        self._dead: set = set()
        self._dead_dirs: set = set()

    # -- liveness-aware sampling ----------------------------------------
    def _is_dead(self, path: str) -> bool:
        """Dead iff the path itself or any ancestor directory was retired.
        Checked against sets, O(path depth) — depth is bounded (~7), while
        the dead pools grow with trace length."""
        if path in self._dead:
            return True
        prefix = ""
        for seg in path.split("/"):
            if not seg:
                continue
            prefix += "/" + seg
            if prefix in self._dead_dirs:
                return True
        return False

    # -- sampling context protocol (consumed by registry MIX_BINDINGS) --
    def live_file(self) -> str:
        for _ in range(32):
            f = self.ns.sample_file(self.rng)
            if not self._is_dead(f):
                return f
        return self.ns.sample_file(self.rng)

    def live_dir(self) -> str:
        for _ in range(32):
            d = self.ns.sample_dir(self.rng)
            if not self._is_dead(d):
                return d
        return self.ns.sample_dir(self.rng)

    def retire(self, path: str, *, is_dir: bool) -> None:
        """A destructive op consumed this target: drop it from sampling."""
        (self._dead_dirs if is_dir else self._dead).add(path)

    def next_create_path(self) -> str:
        self._create_seq += 1
        return f"{self.live_dir()}/w{self._create_seq:08d}"

    def next_op(self) -> WorkloadOp:
        mix_name = self.rng.choices(self._ops, weights=self._weights, k=1)[0]
        on_dir = self.rng.random() < self._dir_frac[mix_name]
        return synthesize(mix_name, self, on_dir)

    def make_trace(self, n_ops: int) -> List[WorkloadOp]:
        """Materialize ``n_ops`` ops up-front as a replayable trace."""
        return [self.next_op() for _ in range(n_ops)]

    def mix_histogram(self, n: int = 100_000) -> Dict[str, float]:
        counts: Dict[str, int] = {}
        for _ in range(n):
            o = self.next_op()
            counts[o.op] = counts.get(o.op, 0) + 1
        return {k: 100.0 * v / n for k, v in sorted(counts.items())}


def make_spotify_trace(ns: SyntheticNamespace, n_ops: int, *,
                       seed: int = 17,
                       mix: Sequence[Tuple[str, float, float]]
                       = SPOTIFY_TRACE_MIX) -> List[WorkloadOp]:
    """Generate a fixed Spotify-style trace (§7.2). The same trace replayed
    at every namenode count keeps throughput curves comparable — exactly the
    replay methodology of the paper's Fig 7 scaling experiment."""
    return SpotifyWorkload(ns, seed=seed, mix=mix).make_trace(n_ops)


def make_phased_trace(ns: SyntheticNamespace, phase_ops: Sequence[int], *,
                      seed: int = 13,
                      mix: Sequence[Tuple[str, float, float]]
                      = SPOTIFY_TRACE_MIX
                      ) -> Tuple[List[WorkloadOp], List[int]]:
    """One CONTINUOUS workload stream cut into phases: returns the full
    trace plus the cumulative phase boundaries ``[len(p0), len(p0)+len(p1),
    ...]``. The elasticity bench replays phases through the same pipeline
    with membership changes between them — a single stream (one generator,
    one liveness state) keeps the phases a real continuation of each other
    instead of three unrelated traces, so hint-cache warmth genuinely
    carries across scale events."""
    w = SpotifyWorkload(ns, seed=seed, mix=mix)
    trace: List[WorkloadOp] = []
    boundaries: List[int] = []
    for n in phase_ops:
        trace.extend(w.make_trace(n))
        boundaries.append(len(trace))
    return trace, boundaries


def make_zipf_tenant_trace(ns: SyntheticNamespace, n_ops: int, *,
                           n_tenants: int = 8,
                           s: float = 1.1,
                           seed: int = 23,
                           mix: Sequence[Tuple[str, float, float]]
                           = SPOTIFY_TRACE_MIX) -> List[WorkloadOp]:
    """Spotify-style trace with each op tagged by a Zipf(s)-weighted tenant
    identity (``WorkloadOp.tenant``). Tenant ``t0`` is the hot client,
    ``t{n-1}`` the coldest — at the paper-realistic skew s≈1.1, t0 issues
    roughly 1/(1)^s : 1/(2)^s : ... of the traffic. The overload bench and
    the admission-controller tests use this shape to show weighted fair
    queueing keeps the hot tenant from starving the cold ones. Tenants are
    billing identities only: lease-holding ops still run under the single
    default ``client``, so clock advancement mid-replay cannot strand a
    lease held by a tenant that never returns."""
    rng = random.Random(seed ^ 0x7E4A47)
    tenants = [f"t{k}" for k in range(max(1, n_tenants))]
    weights = [1.0 / (k + 1) ** s for k in range(len(tenants))]
    trace = make_spotify_trace(ns, n_ops, seed=seed, mix=mix)
    for wop in trace:
        wop.tenant = rng.choices(tenants, weights=weights, k=1)[0]
    return trace


def make_block_contention_trace(path: str, n_rounds: int, *,
                                clients: Sequence[str] = ("c1", "c2"),
                                block_size: int = 1 << 20
                                ) -> List[WorkloadOp]:
    """Adversarial same-file block-write contention: ``clients`` interleave
    append/add_block/complete_block on ONE file, round-robin per round.
    While the first client's lease is live, every other client's block
    write must be refused with ``LeaseConflict`` — and because the ops mix
    block-write TYPES on one path, the batch planner pins them all to
    submission order, so planned (including planned+concurrent) replay
    stays state-equal to sequential replay. The shape
    ``tests/test_closed_loop_pipeline.py`` asserts."""
    trace: List[WorkloadOp] = []
    for _ in range(n_rounds):
        for c in clients:
            trace.append(WorkloadOp("append", path, args={"client": c}))
            trace.append(WorkloadOp("add_block", path, args={"client": c}))
            trace.append(WorkloadOp("complete_block", path,
                                    args={"block_id": -1,
                                          "size": block_size,
                                          "client": c}))
    return trace


# ---------------------------------------------------------------------------
# columnar (struct-of-arrays) trace lowering — the batch planner's input
# ---------------------------------------------------------------------------


def name_hash32(name: str) -> int:
    """32-bit per-component name hash fed to the fused chain kernel."""
    return zlib.crc32(name.encode()) & 0xFFFFFFFF


@dataclass
class ColumnarTrace:
    """Struct-of-arrays lowering of a trace window (paper §2.2 batching +
    λFS-style client-side planning): one row per op, with the hint-cache
    chain resolution broken out per path component so the whole window can
    be hashed in ONE fused ``phash_chain`` kernel launch instead of per-op
    Python hashing.

    ``parent_ids[n, d]`` / ``name_hashes[n, d]`` are the composite PK
    (parent_id, hash(name)) of op n's d-th path component as the client's
    hint view resolves it (zero-padded past ``depths[n]``); ``hint_ids``
    is the op's partition-hint inode id (its target for file ops, its
    parent for namespace mutations — the same OpSpec.hint rule the
    namenodes use); ``pks``/``target_ids`` carry the exact resolution that
    ships to the executor as planner hints."""
    n: int
    max_depth: int
    type_ids: np.ndarray                       # [n] int32 registry ordinal
    depths: np.ndarray                         # [n] int32 resolved comps
    parent_ids: np.ndarray                     # [n, D] int64
    name_hashes: np.ndarray                    # [n, D] int64 (uint32 vals)
    hint_ids: np.ndarray                       # [n] int64
    resolved: List[bool] = field(default_factory=list)
    pks: List[Optional[Tuple[Tuple[int, str], ...]]] = \
        field(default_factory=list)
    target_ids: List[Optional[int]] = field(default_factory=list)


def lower_trace(wops: Sequence[WorkloadOp], resolver: Any,
                *, max_depth: int = 16) -> ColumnarTrace:
    """Lower a trace window to columnar form, resolving every op's hint
    chain in bulk against ``resolver`` (anything with a
    ``peek(parent_id, name) -> Optional[int]``, e.g. a namenode hint cache
    or the planner's merged view of all of them).

    Resolution requirements mirror the grouped executors: batchable reads
    and target-hinted mutations need the full chain including the leaf;
    parent-hinted mutations (create/mkdirs) need only the ancestors. Ops
    that fall short stay unresolved — the planner deals them in submission
    order and the namenode runs them through the exact sequential path."""
    n = len(wops)
    type_names = list(REGISTRY.names())
    type_of = {name: i for i, name in enumerate(type_names)}
    type_ids = np.zeros(n, np.int32)
    depths = np.zeros(n, np.int32)
    parent_ids = np.zeros((n, max_depth), np.int64)
    name_hashes = np.zeros((n, max_depth), np.int64)
    hint_ids = np.full(n, ROOT_ID, np.int64)
    ct = ColumnarTrace(n=n, max_depth=max_depth, type_ids=type_ids,
                       depths=depths, parent_ids=parent_ids,
                       name_hashes=name_hashes, hint_ids=hint_ids)
    for i, wop in enumerate(wops):
        spec = REGISTRY.get(wop.op)
        type_ids[i] = type_of.get(wop.op, -1)
        comps = [c for c in wop.path.split("/") if c]
        if spec is None or not comps or len(comps) > max_depth:
            ct.resolved.append(False)
            ct.pks.append(None)
            ct.target_ids.append(None)
            continue
        need_leaf = spec.batchable or (spec.group_mutable
                                       and spec.hint == "target")
        pks: List[Tuple[int, str]] = []
        parent = ROOT_ID
        target_id: Optional[int] = None
        ok = True
        for d, name in enumerate(comps):
            pks.append((parent, name))
            parent_ids[i, d] = parent
            name_hashes[i, d] = name_hash32(name)
            child = resolver.peek(parent, name)
            if child is None:
                if d < len(comps) - 1 or need_leaf:
                    ok = False
                break
            parent = child
            if d == len(comps) - 1:
                target_id = child
        depths[i] = len(pks)
        if not ok:
            ct.resolved.append(False)
            ct.pks.append(None)
            ct.target_ids.append(None)
            continue
        if spec.hint == "parent":
            hint_ids[i] = pks[-1][0]
        else:
            hint_ids[i] = target_id if target_id is not None else parent
        ct.resolved.append(True)
        ct.pks.append(tuple(pks))
        ct.target_ids.append(target_id)
    return ct


class TraceReplay:
    """Replays a pre-generated trace cyclically through the DES / pipeline
    client interface (``next_op``). Deterministic: op ``i`` issued by the
    replay is always ``trace[i % len(trace)]`` irrespective of namenode
    count, client count, or batching."""

    def __init__(self, trace: Sequence[WorkloadOp]):
        if not trace:
            raise ValueError("empty trace")
        self.trace = list(trace)
        self._i = 0
        self.issued = 0

    def __len__(self) -> int:
        return len(self.trace)

    def next_op(self) -> WorkloadOp:
        op = self.trace[self._i]
        self._i = (self._i + 1) % len(self.trace)
        self.issued += 1
        return op

    def mix_histogram(self) -> Dict[str, float]:
        counts: Dict[str, int] = {}
        for o in self.trace:
            counts[o.op] = counts.get(o.op, 0) + 1
        n = len(self.trace)
        return {k: 100.0 * v / n for k, v in sorted(counts.items())}
