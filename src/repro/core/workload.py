"""Industrial workload generator (paper Table 1 + §7.4).

Reproduces the Spotify HDFS trace characteristics:

  * relative op frequencies of Table 1 (reads 68.73%, stat 17%, ls 9%, ...),
    including the per-op directory/file split where the paper gives it;
  * namespace shape: average path depth 7, ~16 files + 2 subdirs per
    directory, average name length 34;
  * heavy-tailed access popularity (Yahoo: 3% of files take 80% of
    accesses) via a Zipf-like sampler.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# (op, weight_pct, fraction_on_directories)
TABLE1_MIX: List[Tuple[str, float, float]] = [
    ("append",          0.0,   0.0),
    ("mkdirs",          0.02,  1.0),
    ("set_replication", 0.14,  0.0),
    ("delete",          0.75,  0.035),
    ("rename",          1.3,   0.0003),
    ("ls",              9.0,   0.945),
    ("read",            68.73, 0.0),
    ("content_summary", 0.01,  0.5),
    ("set_permissions", 0.03,  0.263),
    ("set_owner",       0.32,  1.0),
    ("create",          1.2,   0.0),
    ("add_block",       1.5,   0.0),
    ("stat",            17.0,  0.233),
]

READ_ONLY_OPS = {"read", "ls", "stat", "content_summary"}


@dataclass
class NamespaceSpec:
    """Spotify-like namespace shape (§7.4)."""
    depth: int = 7
    files_per_dir: int = 16
    dirs_per_dir: int = 2
    name_len: int = 34
    seed: int = 7


class SyntheticNamespace:
    """Builds a namespace matching the spec and serves popularity-weighted
    path samples. Paths are materialized lazily per directory level."""

    def __init__(self, spec: NamespaceSpec, *, n_dirs: int = 200,
                 files_per_dir: Optional[int] = None):
        self.spec = spec
        self.rng = random.Random(spec.seed)
        self.dirs: List[str] = []
        self.files: List[str] = []
        fpd = files_per_dir if files_per_dir is not None \
            else spec.files_per_dir
        # build a tree of depth `spec.depth` with the right fanout, capped
        # at n_dirs directories
        frontier = ["/w"]
        self.dirs.append("/w")
        depth = 1
        while len(self.dirs) < n_dirs and depth < spec.depth:
            nxt = []
            for d in frontier:
                for k in range(spec.dirs_per_dir):
                    sub = f"{d}/{self._name(depth, k)}"
                    self.dirs.append(sub)
                    nxt.append(sub)
                    if len(self.dirs) >= n_dirs:
                        break
                if len(self.dirs) >= n_dirs:
                    break
            frontier = nxt or frontier
            depth += 1
        leaf_dirs = [d for d in self.dirs]
        for d in leaf_dirs:
            for i in range(fpd):
                self.files.append(f"{d}/f{i:04d}.parquet")
        # heavy-tailed popularity: rank files by a Zipf(1.1)-ish law
        self._pop_weights = [1.0 / (r + 1) ** 1.1
                             for r in range(len(self.files))]

    def _name(self, depth: int, k: int) -> str:
        base = f"d{depth}x{k}"
        pad = max(0, self.spec.name_len - len(base) - 20)
        return base + "u" * min(pad, 8)

    def sample_file(self, rng: random.Random) -> str:
        return rng.choices(self.files, weights=self._pop_weights, k=1)[0]

    def sample_dir(self, rng: random.Random) -> str:
        return rng.choice(self.dirs)


@dataclass
class WorkloadOp:
    op: str
    path: str
    path2: Optional[str] = None
    on_dir: bool = False


class SpotifyWorkload:
    """Stream of WorkloadOps distributed per Table 1."""

    def __init__(self, ns: SyntheticNamespace, seed: int = 13):
        self.ns = ns
        self.rng = random.Random(seed)
        self._ops = [m[0] for m in TABLE1_MIX]
        self._weights = [m[1] for m in TABLE1_MIX]
        self._dir_frac = {m[0]: m[2] for m in TABLE1_MIX}
        self._create_seq = 0

    def next_op(self) -> WorkloadOp:
        op = self.rng.choices(self._ops, weights=self._weights, k=1)[0]
        on_dir = self.rng.random() < self._dir_frac[op]
        if op in ("mkdirs",):
            d = self.ns.sample_dir(self.rng)
            return WorkloadOp("mkdirs", f"{d}/new{self.rng.randrange(1 << 30):x}",
                              on_dir=True)
        if op == "create":
            self._create_seq += 1
            d = self.ns.sample_dir(self.rng)
            return WorkloadOp("create", f"{d}/w{self._create_seq:08d}")
        if op == "add_block":
            return WorkloadOp("add_block", self.ns.sample_file(self.rng))
        if op == "rename":
            src = self.ns.sample_file(self.rng)
            return WorkloadOp("rename_file", src, src + ".mv", on_dir=on_dir)
        if op == "delete":
            if on_dir:
                return WorkloadOp("delete_subtree",
                                  self.ns.sample_dir(self.rng), on_dir=True)
            return WorkloadOp("delete_file", self.ns.sample_file(self.rng))
        if op == "set_permissions":
            p = (self.ns.sample_dir(self.rng) if on_dir
                 else self.ns.sample_file(self.rng))
            return WorkloadOp("chmod_subtree" if on_dir else "chmod_file",
                              p, on_dir=on_dir)
        if op == "set_owner":
            p = (self.ns.sample_dir(self.rng) if on_dir
                 else self.ns.sample_file(self.rng))
            return WorkloadOp("chown_subtree" if on_dir else "chown_file",
                              p, on_dir=on_dir)
        if op == "set_replication":
            return WorkloadOp("set_replication",
                              self.ns.sample_file(self.rng))
        if op == "ls":
            p = (self.ns.sample_dir(self.rng) if on_dir
                 else self.ns.sample_file(self.rng))
            return WorkloadOp("ls", p, on_dir=on_dir)
        if op == "stat":
            p = (self.ns.sample_dir(self.rng) if on_dir
                 else self.ns.sample_file(self.rng))
            return WorkloadOp("stat", p, on_dir=on_dir)
        if op == "content_summary":
            p = (self.ns.sample_dir(self.rng) if on_dir
                 else self.ns.sample_file(self.rng))
            return WorkloadOp("content_summary", p, on_dir=on_dir)
        if op == "append":
            return WorkloadOp("append", self.ns.sample_file(self.rng))
        # default: read
        return WorkloadOp("read", self.ns.sample_file(self.rng))

    def mix_histogram(self, n: int = 100_000) -> Dict[str, float]:
        counts: Dict[str, int] = {}
        for _ in range(n):
            o = self.next_op()
            counts[o.op] = counts.get(o.op, 0) + 1
        return {k: 100.0 * v / n for k, v in sorted(counts.items())}
