"""Deterministic chaos fault injection + recovery invariants (§7.6).

The paper's robustness claim — "failure of the leader or any other
namenode does not result in a metadata service downtime" (§7.6) — is a
statement about the WHOLE write path: grouped transactions abort cleanly,
clients fail over, the election detects the death, subtree locks and
leases held by the dead namenode are reclaimed, and the namespace
converges to exactly the state a fault-free run would have produced.

This module makes that claim testable, deterministically:

  FaultSite     — named injection points threaded through the write path
                  (grouped-txn lock phase, subtree chunk commits, batch
                  exchanges, heartbeats).
  ChaosPlan     — a schedule of faults: (site, occurrence index, victim,
                  kind).  Plans are plain frozen data, so hypothesis can
                  generate and SHRINK them; ``ChaosPlan.seeded`` derives a
                  plan from an integer seed for fixed-seed regressions.
  FaultInjector — interprets a plan against a live NamenodeCluster.  A
                  ``crash`` marks the victim dead (it stops heartbeating;
                  its in-flight transaction aborts) and raises StoreError
                  exactly where the site fired; a ``partition`` raises
                  :class:`~repro.core.store.NetworkPartition` on the next
                  ``heal_after`` client exchanges with the victim.
  RecoveryInvariants — the convergence oracle: namespace equality vs a
                  fault-free sequential replay, conserved OpCost, zero
                  orphan lease/under_construction/block rows, LockManager
                  fully released.
  replay_with_recovery — drives a trace through a pipeline under
                  injection, then runs the client-visible recovery
                  protocol (tick past the heartbeat staleness bound,
                  leader lease sweep, re-drive failed ops on survivors)
                  until the outcome set converges.

Host modules never import this one — injection points are ``chaos``
attributes (default ``None``) the injector installs, so the hot path
costs one attribute check when chaos is off.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .ops_registry import WorkloadOp
from .store import MetadataStore, NetworkPartition, OpCost, StoreError


class FaultSite(str, Enum):
    """Named injection points, in write-path order.  The string values are
    what host modules pass to :meth:`FaultInjector.fire` (they must not
    import this module)."""
    #: entry of Namenode.perform/invoke — one client RPC
    RPC = "rpc"
    #: entry of Namenode.execute_batch — one pipeline batch exchange
    #: (RequestPipeline and PlannedRequestPipeline both land here)
    BATCH_EXCHANGE = "batch_exchange"
    #: Namenode._write_group_txn, before the single lock-phase exchange
    GROUP_TXN_PRE_LOCK = "group_txn_pre_lock"
    #: Namenode._write_group_txn, locks held, before the EXECUTE phase
    GROUP_TXN_POST_LOCK = "group_txn_post_lock"
    #: SubtreeOps.delete_subtree, between phase-3 chunk commits (§6.2)
    SUBTREE_CHUNK = "subtree_chunk"
    #: LeaderElection.heartbeat — the victim's liveness proof itself
    HEARTBEAT = "heartbeat"


#: sites where a client↔namenode exchange happens (partitionable)
PARTITIONABLE = (FaultSite.RPC, FaultSite.BATCH_EXCHANGE)

CRASH = "crash"
PARTITION = "partition"
#: gray failure: the victim stays alive and heartbeating but every
#: exchange with it burns ``delay_ticks`` of the shared logical clock —
#: the "limping but not dead" server the crash/partition kinds can't
#: model. Heals after ``heal_after`` slowed exchanges.
DELAY = "delay"


@dataclass(frozen=True)
class Fault:
    """One scheduled fault: at the ``at``-th firing of ``site`` (counted
    per site, 0-based) on a namenode matching ``victim`` (None = any),
    inject ``kind``.  Partitions heal after ``heal_after`` refused
    exchanges and delays after ``heal_after`` slowed ones, so every
    plan terminates."""
    site: FaultSite
    at: int = 0
    victim: Optional[int] = None
    kind: str = CRASH
    heal_after: int = 3
    #: DELAY only: logical-clock ticks each slowed exchange burns
    delay_ticks: int = 2

    def __post_init__(self) -> None:
        assert self.kind in (CRASH, PARTITION, DELAY), self.kind
        assert self.at >= 0
        if self.kind == PARTITION:
            assert FaultSite(self.site) in PARTITIONABLE, \
                f"partition only makes sense at a client exchange, " \
                f"not {self.site}"
            assert self.heal_after >= 1, "partitions must heal"
        if self.kind == DELAY:
            # a slow heartbeat is indistinguishable from a missed one in
            # this model (the election already covers that); DELAY models
            # slow WORK, so it lives at the request-path sites
            assert FaultSite(self.site) is not FaultSite.HEARTBEAT, \
                "delay faults fire on the request path, not heartbeats"
            assert self.heal_after >= 1, "delays must heal"
            assert self.delay_ticks >= 1


@dataclass(frozen=True)
class ChaosPlan:
    """A deterministic fault schedule (plain data: shrinkable)."""
    faults: Tuple[Fault, ...] = ()

    @staticmethod
    def seeded(seed: int, *, n_namenodes: int, n_faults: int = 1,
               max_at: int = 12,
               sites: Sequence[FaultSite] = tuple(FaultSite),
               kinds: Sequence[str] = (CRASH, PARTITION)) -> "ChaosPlan":
        """Derive a plan from an integer seed — the fixed-seed regression
        twin of the hypothesis strategy (same schedule space)."""
        rng = random.Random(seed)
        faults = []
        for _ in range(n_faults):
            site = rng.choice(list(sites))
            kind = rng.choice([k for k in kinds
                               if k == CRASH
                               or (k == PARTITION and site in PARTITIONABLE)
                               or (k == DELAY
                                   and site is not FaultSite.HEARTBEAT)])
            faults.append(Fault(site=site, at=rng.randrange(max_at + 1),
                                victim=rng.choice(
                                    [None] + list(range(n_namenodes))),
                                kind=kind,
                                heal_after=rng.randrange(1, 5),
                                delay_ticks=(rng.randrange(1, 4)
                                             if kind == DELAY else 2)))
        return ChaosPlan(tuple(faults))


def fault_schedules(*, n_namenodes: int, max_at: int = 16,
                    max_faults: int = 2,
                    sites: Sequence[FaultSite] = tuple(FaultSite),
                    kinds: Sequence[str] = (CRASH, PARTITION)):
    """Hypothesis strategy over :class:`ChaosPlan` (site × trace-index ×
    victim), imported lazily so the module works without hypothesis
    installed (property tests skip; fixed-seed regressions still run)."""
    import hypothesis.strategies as st

    def mk_fault(site: FaultSite, at: int, victim: Optional[int],
                 kind: str, heal_after: int, delay_ticks: int) -> Fault:
        if kind == PARTITION and site not in PARTITIONABLE:
            kind = CRASH
        if kind == DELAY and site is FaultSite.HEARTBEAT:
            kind = CRASH
        return Fault(site=site, at=at, victim=victim, kind=kind,
                     heal_after=heal_after, delay_ticks=delay_ticks)

    fault = st.builds(
        mk_fault,
        site=st.sampled_from(list(sites)),
        at=st.integers(min_value=0, max_value=max_at),
        victim=st.one_of(st.none(),
                         st.integers(min_value=0,
                                     max_value=n_namenodes - 1)),
        kind=st.sampled_from(list(kinds)),
        heal_after=st.integers(min_value=1, max_value=4),
        delay_ticks=st.integers(min_value=1, max_value=3))
    return st.builds(lambda fs: ChaosPlan(tuple(fs)),
                     st.lists(fault, min_size=1, max_size=max_faults))


@dataclass(frozen=True)
class ChaosEvent:
    """One injector decision, for assertions and postmortems."""
    site: FaultSite
    occurrence: int
    nn_id: int
    kind: str
    action: str          # "killed" | "partitioned" | "refused" | "healed"
                         # | "slowed" | "delayed" | "delay-healed"
                         # | "skipped-last-nn"


class FaultInjector:
    """Interprets a :class:`ChaosPlan` against a live cluster.

    Deterministic: per-site occurrence counters (under one lock, so the
    concurrent pipelines count consistently), faults consumed in plan
    order, and a safety rule — a crash that would kill the LAST alive
    namenode is skipped (recorded as ``skipped-last-nn``), so injected
    runs always retain a survivor to converge on.
    """

    def __init__(self, plan: ChaosPlan, cluster: Any):
        self.plan = plan
        self.cluster = cluster
        self.counts: Dict[FaultSite, int] = {s: 0 for s in FaultSite}
        self.pending: List[Fault] = list(plan.faults)
        self.partitioned: Dict[int, int] = {}   # nn_id -> refusals left
        self.slowed: Dict[int, int] = {}        # nn_id -> slow exchanges left
        self.delay_ticks: Dict[int, int] = {}   # nn_id -> ticks per exchange
        self.events: List[ChaosEvent] = []
        self._mu = threading.Lock()
        self._installed = False

    # -- wiring --------------------------------------------------------
    def install(self) -> "FaultInjector":
        """Attach to every injection point of the cluster."""
        for nn in self.cluster.namenodes:
            nn.chaos = self
            nn.subtree.chaos = self
        self.cluster.election.chaos = self
        self._installed = True
        return self

    def uninstall(self) -> None:
        """Detach and heal outstanding partitions (recovery starts)."""
        for nn in self.cluster.namenodes:
            nn.chaos = None
            nn.subtree.chaos = None
        self.cluster.election.chaos = None
        self.partitioned.clear()
        self.slowed.clear()
        self.delay_ticks.clear()
        self._installed = False

    def __enter__(self) -> "FaultInjector":
        return self.install()

    def __exit__(self, et, ev, tb) -> bool:
        self.uninstall()
        return False

    # -- decision core -------------------------------------------------
    def _alive_ids(self) -> List[int]:
        return [nn.nn_id for nn in self.cluster.namenodes if nn.alive]

    def _kill(self, site: FaultSite, n: int, nn_id: int,
              fault: Fault) -> bool:
        alive = self._alive_ids()
        if alive == [nn_id] or nn_id not in alive:
            self.events.append(ChaosEvent(site, n, nn_id, fault.kind,
                                          "skipped-last-nn"))
            return False
        self.cluster.kill(nn_id)
        self.events.append(ChaosEvent(site, n, nn_id, fault.kind,
                                      "killed"))
        return True

    def _match(self, site: FaultSite, n: int, nn_id: int
               ) -> Optional[Fault]:
        for f in self.pending:
            if FaultSite(f.site) is site and n >= f.at \
                    and f.victim in (None, nn_id):
                return f
        return None

    def fire(self, site: str, nn_id: int) -> None:
        """One injection point fired on namenode ``nn_id``.  Raises the
        injected error (StoreError for a crash — tagged ``chaos_crash`` so
        a crashed namenode's cleanup handlers know NOT to run —
        NetworkPartition for a refused exchange) or returns normally.
        A DELAY fault raises nothing: the exchange proceeds, but first
        the shared logical clock advances ``delay_ticks`` — the victim
        is limping, so everyone else's leases, deadlines, and election
        staleness age while it works (gray failure, not clean death)."""
        fsite = FaultSite(site)
        advance = 0
        err: Optional[Exception] = None
        with self._mu:
            n = self.counts[fsite]
            self.counts[fsite] = n + 1
            # an active partition refuses this exchange first
            if fsite in PARTITIONABLE and nn_id in self.partitioned:
                left = self.partitioned[nn_id] - 1
                if left <= 0:
                    del self.partitioned[nn_id]
                    self.events.append(ChaosEvent(fsite, n, nn_id,
                                                  PARTITION, "healed"))
                else:
                    self.partitioned[nn_id] = left
                    self.events.append(ChaosEvent(fsite, n, nn_id,
                                                  PARTITION, "refused"))
                err = NetworkPartition(
                    f"client partitioned from namenode {nn_id}")
            # an active slowdown burns clock on every exchange
            if err is None and nn_id in self.slowed:
                advance = self.delay_ticks.get(nn_id, 1)
                left = self.slowed[nn_id] - 1
                if left <= 0:
                    del self.slowed[nn_id]
                    self.delay_ticks.pop(nn_id, None)
                    self.events.append(ChaosEvent(fsite, n, nn_id,
                                                  DELAY, "delay-healed"))
                else:
                    self.slowed[nn_id] = left
                    self.events.append(ChaosEvent(fsite, n, nn_id,
                                                  DELAY, "delayed"))
            if err is None:
                fault = self._match(fsite, n, nn_id)
                if fault is not None:
                    self.pending.remove(fault)
                    if fault.kind == PARTITION:
                        self.partitioned[nn_id] = fault.heal_after
                        self.events.append(ChaosEvent(fsite, n, nn_id,
                                                      PARTITION,
                                                      "partitioned"))
                        err = NetworkPartition(
                            f"client partitioned from namenode {nn_id}")
                    elif fault.kind == DELAY:
                        self.slowed[nn_id] = fault.heal_after
                        self.delay_ticks[nn_id] = fault.delay_ticks
                        advance += fault.delay_ticks
                        self.events.append(ChaosEvent(fsite, n, nn_id,
                                                      DELAY, "slowed"))
                    elif self._kill(fsite, n, nn_id, fault):
                        e = StoreError(f"chaos: namenode {nn_id} crashed "
                                       f"at {fsite.value}#{n}")
                        e.chaos_crash = True  # crashed NNs run no cleanup
                        err = e
        # clock advancement OUTSIDE the injector lock: tick() heartbeats
        # the fleet, which re-enters allow_heartbeat (and thus _mu)
        if advance:
            self._advance_clock(advance)
        if err is not None:
            raise err

    def _advance_clock(self, ticks: int) -> None:
        """Model the wall-clock time a gray-slow exchange burns: advance
        the SHARED logical clock via full heartbeat rounds, so live
        namenodes stay live (only time passes — nobody is falsely
        declared dead) while leases age and deadlines approach."""
        for _ in range(ticks):
            self.cluster.tick()

    def allow_heartbeat(self, nn_id: int) -> bool:
        """HEARTBEAT-site twin of :meth:`fire`: returning False suppresses
        the liveness proof (the victim just died), instead of raising into
        the cluster's tick loop."""
        with self._mu:
            n = self.counts[FaultSite.HEARTBEAT]
            self.counts[FaultSite.HEARTBEAT] = n + 1
            fault = self._match(FaultSite.HEARTBEAT, n, nn_id)
            if fault is None:
                return True
            self.pending.remove(fault)
            return not self._kill(FaultSite.HEARTBEAT, n, nn_id, fault)

    def heal_all(self) -> None:
        with self._mu:
            self.partitioned.clear()
            self.slowed.clear()
            self.delay_ticks.clear()

    @property
    def injected(self) -> List[ChaosEvent]:
        return [e for e in self.events
                if e.action in ("killed", "partitioned", "slowed")]


# ---------------------------------------------------------------------------
# recovery invariants
# ---------------------------------------------------------------------------


class RecoveryInvariants:
    """The convergence oracle a chaos run must satisfy AFTER recovery.

    Each check returns a list of violation strings (empty = holds), so a
    failing property test shows every broken invariant at once;
    :meth:`assert_all` raises with the full report.
    """

    def __init__(self, store: MetadataStore, cluster: Any = None):
        self.store = store
        self.cluster = cluster

    # -- namespace equality vs the fault-free oracle -------------------
    def namespace_violations(self, oracle_snapshot: Dict[str, tuple]
                             ) -> List[str]:
        from .namenode import namespace_snapshot
        got = namespace_snapshot(self.store)
        out = []
        for path in sorted(set(oracle_snapshot) | set(got)):
            a, b = oracle_snapshot.get(path), got.get(path)
            if a != b:
                out.append(f"namespace diverged at {path}: "
                           f"oracle={a!r} got={b!r}")
        return out

    # -- OpCost conservation -------------------------------------------
    def cost_violations(self, outcome_cost: OpCost,
                        per_nn_delta: Dict[int, OpCost],
                        housekeeping: Optional[OpCost] = None
                        ) -> List[str]:
        """Merging every namenode's committed-cost delta must equal the
        merge of every successful outcome's cost plus the housekeeping
        (lease sweeps) the recovery protocol ran — faults must never
        mint or leak accounted round trips."""
        total = OpCost()
        for c in per_nn_delta.values():
            total.merge(c)
        expect = outcome_cost.copy()
        if housekeeping is not None:
            expect.merge(housekeeping)
        if total.as_dict() != expect.as_dict():
            return [f"OpCost not conserved: per-NN {total.as_dict()} != "
                    f"outcomes+housekeeping {expect.as_dict()}"]
        return []

    # -- orphan rows ----------------------------------------------------
    def orphan_violations(self) -> List[str]:
        out: List[str] = []
        inode_t = self.store.table("inode")
        ids = {r["id"] for r in inode_t.scan_all(lambda r: True)}
        holders = {r["holder"]
                   for r in self.store.table("lease").scan_all(
                       lambda r: True)}
        for lp in self.store.table("lease_path").scan_all(lambda r: True):
            if lp["inode_id"] not in ids:
                out.append(f"orphan lease_path row for deleted inode "
                           f"{lp['inode_id']}")
            if lp["holder"] not in holders:
                out.append(f"orphan lease_path row: holder "
                           f"{lp['holder']!r} has no lease")
        for r in inode_t.scan_all(
                lambda r: not r["is_dir"] and r.get("under_construction")):
            if r.get("client") is None:
                out.append(f"inode {r['id']} under construction with no "
                           f"writer")
            elif r["client"] not in holders:
                out.append(f"orphan under_construction: inode {r['id']} "
                           f"writer {r['client']!r} has no lease")
        for b in self.store.table("block").scan_all(lambda r: True):
            if b["inode_id"] not in ids:
                out.append(f"orphan block {b['block_id']} of deleted "
                           f"inode {b['inode_id']}")
        for r in inode_t.scan_all(
                lambda r: r.get("subtree_lock") is not None):
            out.append(f"stale subtree lock on inode {r['id']} "
                       f"(owner NN {r['subtree_lock']})")
        for r in self.store.table("ongoing_subtree_ops").scan_all(
                lambda r: True):
            out.append(f"stale ongoing_subtree_ops row for inode "
                       f"{r['inode_id']}")
        return out

    # -- lock release ---------------------------------------------------
    def lock_violations(self) -> List[str]:
        held = {txn: keys for txn, keys
                in self.store.locks._held.items() if keys}
        if held:
            return [f"LockManager not fully released: txn {txn} holds "
                    f"{len(keys)} locks" for txn, keys in held.items()]
        return []

    def assert_all(self, oracle_snapshot: Optional[Dict[str, tuple]] = None,
                   *, outcome_cost: Optional[OpCost] = None,
                   per_nn_delta: Optional[Dict[int, OpCost]] = None,
                   housekeeping: Optional[OpCost] = None) -> None:
        out = self.orphan_violations() + self.lock_violations()
        if oracle_snapshot is not None:
            out += self.namespace_violations(oracle_snapshot)
        if outcome_cost is not None and per_nn_delta is not None:
            out += self.cost_violations(outcome_cost, per_nn_delta,
                                        housekeeping)
        assert not out, "recovery invariants violated:\n  " + \
            "\n  ".join(out)


# ---------------------------------------------------------------------------
# chaos replay driver
# ---------------------------------------------------------------------------

#: outcome error names the recovery protocol re-drives: transient
#: transport/abort failures, NOT genuine FS outcomes (FileNotFound, ...)
RETRYABLE_ERRORS = frozenset({
    "StoreError", "NetworkPartition", "LockTimeout", "TransactionAborted",
    "SubtreeLockedError",
    # admission sheds (repro.core.admission): the op itself is valid —
    # only its timing budget or a pressure policy refused it, so the
    # recovery protocol re-drives it once the fault/pressure cleared
    # (required for namespace equality when MUTATIONS are shed)
    "DeadlineExpired", "OverloadShed"})


@dataclass
class ChaosReport:
    """What a :func:`replay_with_recovery` run did and cost."""
    outcomes: List[Any]
    ok: int
    failed: int
    recovery_rounds: int
    retried_ops: int
    events: List[ChaosEvent] = field(default_factory=list)
    outcome_cost: OpCost = field(default_factory=OpCost)
    housekeeping_cost: OpCost = field(default_factory=OpCost)
    per_nn_delta: Dict[int, OpCost] = field(default_factory=dict)


def _agg_costs(cluster: Any) -> OpCost:
    total = OpCost()
    for nn in cluster.namenodes:
        total.merge(nn.agg_cost)
    return total


def replay_with_recovery(cluster: Any, wops: Sequence[WorkloadOp], *,
                         injector: Optional[FaultInjector] = None,
                         batch_size: int = 8, planned: bool = False,
                         max_rounds: int = 5) -> ChaosReport:
    """Drive ``wops`` through a pipeline under fault injection, then run
    the §7.6 recovery protocol until outcomes converge:

      1. tick the election past the heartbeat staleness bound, so dead
         namenodes' subtree locks become reclaimable (§6.2) and the
         leader role moves;
      2. run the leader's housekeeping (lease-recovery sweep + orphaned
         lease-path scrub);
      3. re-drive every transiently-failed op, in submission order, on
         the survivors (the client's failover retry, §7.6.1).

    The injector is detached before recovery — faults strike during the
    replay; recovery itself runs fault-free (crashed namenodes STAY
    crashed; recovery must succeed without them)."""
    from .batch_planner import PlannedRequestPipeline
    from .namenode import RequestPipeline
    wops = list(wops)
    cost0 = {nn.nn_id: nn.agg_cost.copy() for nn in cluster.namenodes}
    if injector is not None:
        injector.install()
    try:
        if planned:
            stats = PlannedRequestPipeline(
                cluster, batch_size=batch_size).run(wops)
        else:
            stats = RequestPipeline(cluster, batch_size=batch_size).run(wops)
    finally:
        if injector is not None:
            injector.uninstall()
    outcomes: List[Any] = list(stats.outcomes)
    housekeeping = OpCost()
    rounds = retried = 0
    while rounds < max_rounds:
        todo = [i for i, oc in enumerate(outcomes)
                if not oc.ok and oc.error in RETRYABLE_ERRORS]
        if not todo or not cluster.alive_namenodes():
            break
        rounds += 1
        retried += len(todo)
        # let the election see the deaths (bounded staleness, §7.6);
        # housekeeping cost (lease sweeps — possibly auto, on tick) is
        # measured around the whole non-pipeline recovery step
        before = _agg_costs(cluster)
        for _ in range(cluster.election.max_missed + 1):
            cluster.tick()
        cluster.recover_leases()
        housekeeping.merge(_agg_costs(cluster).diff(before))
        rstats = RequestPipeline(cluster, batch_size=batch_size).run(
            [wops[i] for i in todo])
        for i, oc in zip(todo, rstats.outcomes):
            outcomes[i] = oc
    # final housekeeping: scrub lease_path rows orphaned by deletes (the
    # model's deferred HDFS LeaseManager on-delete cleanup) so the
    # post-recovery store satisfies the zero-orphan invariant
    if cluster.alive_namenodes():
        before = _agg_costs(cluster)
        ldr = cluster.leader()
        if ldr is None or not ldr.alive:
            # a zero-retry run never entered the recovery loop: let the
            # election converge on a live leader before housekeeping
            for _ in range(cluster.election.max_missed + 1):
                cluster.tick()
        cluster.scrub_leases()
        housekeeping.merge(_agg_costs(cluster).diff(before))
    outcome_cost = OpCost()
    ok = failed = 0
    for oc in outcomes:
        if oc.ok:
            ok += 1
            outcome_cost.merge(oc.result.cost)
        else:
            failed += 1
    per_nn = {nn.nn_id: nn.agg_cost.diff(cost0.get(nn.nn_id, OpCost()))
              for nn in cluster.namenodes}
    return ChaosReport(outcomes=outcomes, ok=ok, failed=failed,
                       recovery_rounds=rounds, retried_ops=retried,
                       events=list(injector.events) if injector else [],
                       outcome_cost=outcome_cost,
                       housekeeping_cost=housekeeping,
                       per_nn_delta=per_nn)
