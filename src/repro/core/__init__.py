"""HopsFS core: scale-out hierarchical metadata over a partitioned,
transactional, in-memory store (the paper's contribution).

Public surface:
  MetadataStore        — NDB-equivalent partitioned store w/ node groups
  Transaction          — 3-phase txn template (lock/execute/update) + OpCost
  REGISTRY / OpSpec / register_op — the typed operation protocol (one
                         declaration per op: handler, arg schema, flags)
  DFSClient            — HDFS-style typed facade with composable middleware
  HopsFSOps            — inode operations (Fig 4 template, Table 3 costs)
  SubtreeOps           — subtree operations protocol (§6)
  NamenodeCluster / Client — stateless namenodes + selection policies
  RequestPipeline      — batched multi-namenode request pipeline (§7.2)
  BatchPlanner / PlannedRequestPipeline — client-side columnar batch
                         planner: partition-aligned, type-sorted dealing
  LeaderElection       — DB-as-shared-memory leader election (§3)
  HDFSNamenode / HDFSHACluster — the HDFS baseline (§2.1)
  profile_ops / HopsFSSim / HDFSSim — measured-cost DES (§7)
  FaultInjector / ChaosPlan / RecoveryInvariants — deterministic chaos
                         fault injection + failover convergence oracle
                         (§7.6, docs/CHAOS.md)
  AdmissionController / BreakerBoard / RetryBudget — overload-hardened
                         request path: deadlines, weighted fair queueing,
                         retry budgets, circuit breakers
                         (docs/ROBUSTNESS.md)
"""
from .admission import (AdmissionController, BREAKER_FAILURES, BreakerBoard,
                        CircuitBreaker, DeadlineExpired, OverloadShed,
                        RetryBudget, TenantLoad, circuit_breaker,
                        stamp_deadlines)
from .batch_planner import (BatchPlanner, HintResolver, MultiCacheResolver,
                            PlanReport, PlannedBatch,
                            PlannedRequestPipeline, WindowController)
from .chaos import (CRASH, ChaosEvent, ChaosPlan, ChaosReport, DELAY, Fault,
                    FaultInjector, FaultSite, PARTITION, RecoveryInvariants,
                    fault_schedules, replay_with_recovery)
from .dfs_client import (BlockLocation, ConcatSummary, ContentSummary,
                         DFSClient, DeleteSummary, FileStatus,
                         TruncateSummary)
from .fs import (FSError, FileAlreadyExists, FileNotFound, HopsFSOps,
                 LeaseConflict, OpResult, SubtreeLockedError, format_fs,
                 split_path)
from .hdfs_baseline import HDFSHACluster, HDFSNamenode
from .hint_cache import EPOCH_TAG, InodeHintCache, split_epoch_entries
from .leader import LeaderElection
from .middleware import (CallContext, compose, failover,
                         membership_refresh, subtree_retry, txn_retry)
from .namenode import (BATCHABLE_READ_OPS, Client, GROUP_MUTABLE_OPS,
                       Namenode, NamenodeCluster, OpOutcome, PipelineStats,
                       PlanHint, RequestPipeline, materialize_big_dir,
                       materialize_namespace, namespace_snapshot)
from .ops_registry import (ArgSpec, OpSpec, OpRegistry, REGISTRY, REQUIRED,
                           WorkloadOp, register_op)
from .pool import ElasticNamenodePool, LoadSample, ScaleEvent
from .store import (EXCLUSIVE, READ_COMMITTED, SHARED, LockTimeout,
                    MetadataStore, NetworkPartition, NodeGroupDown, OpCost,
                    StoreError)
from .subtree import SubtreeOps, TreeNode
from .tables import ROOT_ID, hdfs_capacity_files, hopsfs_capacity_files
from .transactions import Transaction, run_with_retry

__all__ = [
    "MetadataStore", "Transaction", "OpCost", "HopsFSOps", "SubtreeOps",
    "TreeNode", "NamenodeCluster", "Namenode", "Client", "LeaderElection",
    "RequestPipeline", "PipelineStats", "OpOutcome", "BATCHABLE_READ_OPS",
    "GROUP_MUTABLE_OPS", "PlanHint", "BatchPlanner", "HintResolver",
    "MultiCacheResolver", "PlannedBatch", "PlannedRequestPipeline",
    "PlanReport", "WindowController",
    "materialize_big_dir", "materialize_namespace", "namespace_snapshot",
    "REGISTRY", "OpRegistry", "OpSpec", "ArgSpec", "REQUIRED",
    "register_op", "WorkloadOp",
    "DFSClient", "FileStatus", "BlockLocation", "ContentSummary",
    "DeleteSummary", "TruncateSummary", "ConcatSummary",
    "CallContext", "compose", "failover", "membership_refresh",
    "subtree_retry", "txn_retry",
    "ElasticNamenodePool", "LoadSample", "ScaleEvent",
    "EPOCH_TAG", "split_epoch_entries",
    "HDFSNamenode", "HDFSHACluster", "InodeHintCache", "format_fs",
    "split_path", "run_with_retry", "FSError", "FileNotFound",
    "FileAlreadyExists", "LeaseConflict", "SubtreeLockedError",
    "StoreError", "LockTimeout",
    "NodeGroupDown", "NetworkPartition", "ROOT_ID", "READ_COMMITTED",
    "SHARED", "EXCLUSIVE",
    "FaultSite", "Fault", "ChaosPlan", "ChaosEvent", "ChaosReport",
    "FaultInjector", "RecoveryInvariants", "fault_schedules",
    "replay_with_recovery", "CRASH", "PARTITION", "DELAY",
    "AdmissionController", "BreakerBoard", "CircuitBreaker", "RetryBudget",
    "TenantLoad", "DeadlineExpired", "OverloadShed", "BREAKER_FAILURES",
    "circuit_breaker", "stamp_deadlines",
    "hdfs_capacity_files", "hopsfs_capacity_files",
]
