"""Columnar struct-of-arrays metadata engine (HopsFS §4.2 partitioned
tables, re-laid-out for batch validation).

The dict-backed :class:`~repro.core.store.Table` stores one Python dict
per row, sharded over partition dicts.  This module keeps the exact same
``MetadataStore``/``Table`` interface but lays hot tables (inode, block,
lease) out column-major: every column is one flat array/list indexed by a
row *slot*, integer id columns and the per-row partition assignment are
mirrored into flat numpy arrays, and the inode table's composite PK
``(parent_id, name)`` is additionally maintained in an open-addressing
:class:`HashIndex` whose backing arrays feed the fused Pallas kernels:

* ``repro.kernels.pkval`` — grouped-batch PK validation: ONE launch
  checks a whole planner window's client-resolved ``(parent_id, name)``
  chains against the store's hash index, demoting stale hints to the
  sequential path before they waste a batched round trip;
* ``repro.kernels.hintchain`` — vectorized hint-chain resolution: ONE
  launch walks every op's cached parent chain against snapshots of the
  client + namenode hint caches, replacing the per-probe Python loop in
  ``lower_trace``.

Both kernels are ADVISORY: their output only picks which ops ride the
batched fast path vs the exact sequential path, and every shipped hint is
still validated against real rows inside the server transaction.  The
dict store therefore remains the always-on oracle — the differential
harness (``tests/test_columnar_store.py``) asserts ``dump_state``
byte-equality between the two backends, kernels on or off.

Sentinel encoding shared by the host index and both kernels::

    parent slot  -1  EMPTY      ends a linear-probe chain
    parent slot  -2  TOMBSTONE  probe continues through it
    value        -3  AMBIG      crc32-collided bucket: cannot be trusted,
                                the host must re-resolve exactly
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Set, Tuple)

import numpy as np

from .namenode import _KernelProbe, _with_phash_kernel
from .store import MetadataStore
from .tables import ROOT_ID, TableSchema, pk_of
from .ops_registry import REGISTRY
from .workload import ColumnarTrace, WorkloadOp, lower_trace, name_hash32

# sentinels — MUST match repro.kernels.pkval.kernel (asserted by the
# kernel regression tests so the two can never drift silently)
EMPTY = -1
TOMB = -2
AMBIG = -3
#: linear-probe bound shared with the kernels: the host index GROWS
#: rather than ever placing an entry more than MAX_PROBE slots from home,
#: so a kernel miss after MAX_PROBE steps is a real miss.
MAX_PROBE = 8

_GOLDEN = 0x9E3779B1
_GOLDEN2 = 0x85EBCA6B

#: below this many probes the scalar Python walk beats an interpret-mode
#: kernel launch (same rationale as ``namenode.PHASH_MIN_BATCH``; lower
#: because these kernels replace *per-probe* Python work, not one hash)
PKVAL_MIN_BATCH = 128
HINTCHAIN_MIN_BATCH = 128
#: treeagg gates on the inode table's SLOT count (the kernel sweeps every
#: slot per launch), so tiny namespaces stay on the Python path entirely
TREEAGG_MIN_BATCH = 128

# per-family availability gates: a pkval failure must not latch the
# hintchain (or phash) fallback, and vice versa
_pkval_probe = _KernelProbe()
_hintchain_probe = _KernelProbe()
_treeagg_probe = _KernelProbe()

_MISSING = object()          # column sentinel: row has no such key


# ---------------------------------------------------------------------------
# open-addressing (parent_id, name_hash32) -> inode id index
# ---------------------------------------------------------------------------


class HashIndex:
    """Flat open-addressing hash table over composite PKs, kernel-ready.

    Keys are ``(parent_id, crc32(name))``; values are inode ids.  The
    three backing arrays (``par`` int32, ``nam`` uint32, ``val`` int32)
    are exactly what ``pkval``/``hintchain`` consume — :meth:`arrays`
    hands them over with zero copying.  The bucket mix is the kernels'
    ``_bucket_hash`` bit-for-bit; capacity is always a power of two and
    the index grows whenever an insert cannot land within ``MAX_PROBE``
    slots of home (or load passes 1/2), so device probes and host probes
    always agree.

    A bucket whose 32-bit key collides across DIFFERENT names under the
    same parent is poisoned with the value ``AMBIG`` — the kernels pass
    it through and the caller re-resolves those probes exactly.
    """

    def __init__(self, cap: int = 64):
        if cap & (cap - 1):
            raise ValueError("capacity must be a power of two")
        self.cap = cap
        self.par = np.full(cap, EMPTY, np.int32)
        self.nam = np.zeros(cap, np.uint32)
        self.val = np.full(cap, EMPTY, np.int32)
        self.used = 0            # live + tombstones (probe-chain occupancy)
        self.live = 0

    @staticmethod
    def _mix(par: int, nam: int) -> int:
        """Host mirror of the kernels' uint32 bucket mix."""
        h = ((par * _GOLDEN) & 0xFFFFFFFF) ^ ((nam * _GOLDEN2) & 0xFFFFFFFF)
        return (h ^ (h >> 16)) & 0xFFFFFFFF

    def _find(self, par: int, nam: int
              ) -> Tuple[Optional[int], Optional[int]]:
        """(slot holding the key or None, first insertable slot or None),
        scanning at most MAX_PROBE slots from home — the device bound."""
        home = self._mix(par & 0xFFFFFFFF, nam) & (self.cap - 1)
        ins: Optional[int] = None
        for step in range(MAX_PROBE):
            j = (home + step) & (self.cap - 1)
            p = int(self.par[j])
            if p == EMPTY:
                return None, (j if ins is None else ins)
            if p == TOMB:
                if ins is None:
                    ins = j
                continue
            if p == par and int(self.nam[j]) == nam:
                return j, ins
        return None, ins

    def set(self, par: int, nam: int, value: int) -> None:
        j, ins = self._find(par, nam)
        if j is not None:
            self.val[j] = value
            return
        if ins is None or 2 * (self.used + 1) > self.cap:
            self._grow()
            self.set(par, nam, value)
            return
        if int(self.par[ins]) == EMPTY:
            self.used += 1
        self.par[ins] = par
        self.nam[ins] = nam
        self.val[ins] = value
        self.live += 1

    def remove(self, par: int, nam: int) -> bool:
        j, _ = self._find(par, nam)
        if j is None:
            return False
        self.par[j] = TOMB
        self.nam[j] = 0
        self.val[j] = EMPTY
        self.live -= 1
        return True

    def get(self, par: int, nam: int) -> int:
        """Resolved id, EMPTY on miss — may return AMBIG for a poisoned
        bucket, exactly like the kernels."""
        j, _ = self._find(par, nam)
        return int(self.val[j]) if j is not None else EMPTY

    def _grow(self) -> None:
        entries = [(int(p), int(m), int(v))
                   for p, m, v in zip(self.par, self.nam, self.val)
                   if int(p) >= 0]
        cap = self.cap
        while True:
            cap *= 2
            par = np.full(cap, EMPTY, np.int32)
            nam = np.zeros(cap, np.uint32)
            val = np.full(cap, EMPTY, np.int32)
            ok = True
            for p, m, v in entries:
                home = self._mix(p & 0xFFFFFFFF, m) & (cap - 1)
                for step in range(MAX_PROBE):
                    j = (home + step) & (cap - 1)
                    if int(par[j]) == EMPTY:
                        par[j] = p
                        nam[j] = m
                        val[j] = v
                        break
                else:
                    ok = False       # chain still too long — double again
                    break
            if ok:
                self.cap = cap
                self.par, self.nam, self.val = par, nam, val
                self.used = self.live = len(entries)
                return

    def arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The kernel-facing (parent, name_hash, value) triple — views,
        not copies; snapshot semantics come from the jit boundary."""
        return self.par, self.nam, self.val

    @classmethod
    def from_entries(cls, entries: Iterable[Tuple[int, str, int]]
                     ) -> "HashIndex":
        """Build from ``(parent_id, name, inode_id)`` triples (hint-cache
        ``export_entries`` order = oldest first, so later duplicates win
        exactly like the cache's own overwrite), poisoning crc32-collided
        buckets with AMBIG."""
        idx = cls()
        seen: Dict[Tuple[int, int], str] = {}
        ambig: Set[Tuple[int, int]] = set()
        for par, name, iid in entries:
            h = name_hash32(name)
            key = (par, h)
            if key in ambig:
                continue
            prev = seen.get(key)
            if prev is None or prev == name:
                seen[key] = name
                idx.set(par, h, iid)
            else:
                ambig.add(key)
                idx.set(par, h, AMBIG)
        return idx


# ---------------------------------------------------------------------------
# columnar table
# ---------------------------------------------------------------------------

#: integer columns mirrored into flat numpy arrays per table (ids and
#: parent pointers — what scans, joins and kernels actually consume)
HOT_INT_COLS: Dict[str, Tuple[str, ...]] = {
    "inode": ("id", "parent_id", "size", "is_dir"),
    "block": ("block_id", "inode_id"),
    "lease": (),
}


class ColumnarTable:
    """Struct-of-arrays drop-in for :class:`repro.core.store.Table`.

    Rows live in per-column arrays indexed by an integer *slot*:
    ``_cols[col][slot]`` holds the exact Python value (``_MISSING`` where
    a row lacks the key, so heterogeneous rows round-trip byte-exact),
    ``part_slots[slot]`` the row's partition, and the ``HOT_INT_COLS``
    are mirrored into flat ``int64`` arrays.  ``_slots`` maps PK ->
    slot in insertion order, which makes every scan reproduce the dict
    store's iteration order (per-partition insertion order; partition-key
    relocation moves the row to the end of its new shard, exactly like
    the dict store's pop+reinsert).

    The inode table additionally maintains :attr:`hindex`, the
    open-addressing ``(parent_id, crc32(name)) -> id`` index the pkval
    kernel probes; crc-collided buckets are tracked per key and poisoned
    with ``AMBIG``.

    Interface parity with ``Table`` (schema/n_partitions/parts/idx/
    n_rows/_pk_loc/partition_of/partition_of_pk/get/put/delete/
    scan_index/scan_partition/scan_all) is what lets the transaction
    engine, namenodes and ``dump_state`` run unchanged on either backend.
    """

    def __init__(self, schema: TableSchema, n_partitions: int):
        self.schema = schema
        self.n_partitions = n_partitions
        self.idx: Dict[str, Dict[Any, Set[Tuple[Any, ...]]]] = {
            c: {} for c in schema.indexes}
        self.n_rows = 0
        self._pk_loc: Optional[Dict[Tuple[Any, ...], int]] = (
            None if schema.partition_key in schema.pk else {})
        self._cap = 16
        self._top = 0
        self._free: List[int] = []
        self._slots: Dict[Tuple[Any, ...], int] = {}
        self._cols: Dict[str, List[Any]] = {}
        self.part_slots = np.full(self._cap, -1, np.int64)
        self._hot: Dict[str, np.ndarray] = {
            c: np.full(self._cap, -1, np.int64)
            for c in HOT_INT_COLS.get(schema.name, ())}
        if schema.name == "inode":
            self.hindex: Optional[HashIndex] = HashIndex()
            self._namehash = np.zeros(self._cap, np.uint32)
            # (parent, crc32(name)) -> {pk: id}: crc collision tracker
            # that keeps hindex's AMBIG poisoning exact under churn
            self._hkey: Dict[Tuple[int, int], Dict[Tuple[Any, ...], int]] = {}
        else:
            self.hindex = None

    # -- slot management -----------------------------------------------
    def _alloc(self) -> int:
        if self._free:
            return self._free.pop()
        if self._top == self._cap:
            new_cap = self._cap * 2
            grown = np.full(new_cap, -1, np.int64)
            grown[:self._cap] = self.part_slots
            self.part_slots = grown
            for c, arr in self._hot.items():
                g = np.full(new_cap, -1, np.int64)
                g[:self._cap] = arr
                self._hot[c] = g
            if self.hindex is not None:
                g = np.zeros(new_cap, np.uint32)
                g[:self._cap] = self._namehash
                self._namehash = g
            for col in self._cols.values():
                col.extend([_MISSING] * self._cap)
            self._cap = new_cap
        slot = self._top
        self._top += 1
        return slot

    def _store_row(self, slot: int, row: Dict[str, Any]) -> None:
        for col in self._cols.values():
            col[slot] = _MISSING
        for k, v in row.items():
            col = self._cols.get(k)
            if col is None:
                col = [_MISSING] * self._cap
                self._cols[k] = col
            col[slot] = v
        for c, arr in self._hot.items():
            v = row.get(c)
            arr[slot] = int(v) if isinstance(v, (int, np.integer)) else -1
        if self.hindex is not None:
            self._namehash[slot] = name_hash32(row["name"])

    def _materialize(self, slot: int) -> Dict[str, Any]:
        return {k: col[slot] for k, col in self._cols.items()
                if col[slot] is not _MISSING}

    def _clear_slot(self, slot: int) -> None:
        for col in self._cols.values():
            col[slot] = _MISSING
        for arr in self._hot.values():
            arr[slot] = -1

    # -- inode PK hash-index maintenance --------------------------------
    def _hash_sync(self, key: Tuple[int, int]) -> None:
        assert self.hindex is not None
        d = self._hkey.get(key)
        if not d:
            self._hkey.pop(key, None)
            self.hindex.remove(key[0], key[1])
        elif len(d) == 1:
            self.hindex.set(key[0], key[1], next(iter(d.values())))
        else:
            self.hindex.set(key[0], key[1], AMBIG)

    def _hash_add(self, pk: Tuple[Any, ...], row: Dict[str, Any]) -> None:
        key = (int(row["parent_id"]), name_hash32(row["name"]))
        self._hkey.setdefault(key, {})[pk] = int(row["id"])
        self._hash_sync(key)

    def _hash_remove(self, pk: Tuple[Any, ...], row: Dict[str, Any]) -> None:
        key = (int(row["parent_id"]), name_hash32(row["name"]))
        d = self._hkey.get(key)
        if d is not None:
            d.pop(pk, None)
            self._hash_sync(key)

    # -- placement (identical to Table) ---------------------------------
    def partition_of(self, partition_key_value: Any) -> int:
        from .store import _hash_key
        return _hash_key(partition_key_value) % self.n_partitions

    def partition_of_pk(self, pk: Tuple[Any, ...]) -> int:
        s = self.schema
        if s.partition_key in s.pk:
            return self.partition_of(pk[s.pk.index(s.partition_key)])
        p = self._pk_loc.get(pk)  # type: ignore[union-attr]
        return p if p is not None else self.partition_of(pk)

    # -- row ops ---------------------------------------------------------
    def get(self, pk: Tuple[Any, ...], part_hint: Optional[int] = None
            ) -> Optional[Dict[str, Any]]:
        slot = self._slots.get(pk)
        if slot is None:
            return None
        if part_hint is not None and int(self.part_slots[slot]) != part_hint:
            return None          # wrong-shard probe misses, like the dict store
        return self._materialize(slot)

    def put(self, row: Dict[str, Any]) -> None:
        pk = pk_of(self.schema, row)
        p = self.partition_of(row[self.schema.partition_key])
        slot = self._slots.get(pk)
        if slot is None:
            slot = self._alloc()
            self._slots[pk] = slot
            self.n_rows += 1
        else:
            old = self._materialize(slot)
            self._unindex(old, pk)
            if self.hindex is not None:
                self._hash_remove(pk, old)
            if int(self.part_slots[slot]) != p:
                # partition-key UPDATE = NDB-internal delete+insert; the
                # dict store reinserts at the end of the new shard, so
                # move the slot to the end of insertion order too
                self._slots.pop(pk)
                self._slots[pk] = slot
        self.part_slots[slot] = p
        self._store_row(slot, row)
        if self._pk_loc is not None:
            self._pk_loc[pk] = p
        self._index(row, pk)
        if self.hindex is not None:
            self._hash_add(pk, row)

    def delete(self, pk: Tuple[Any, ...]) -> bool:
        slot = self._slots.pop(pk, None)
        if self._pk_loc is not None:
            self._pk_loc.pop(pk, None)
        if slot is None:
            return False
        row = self._materialize(slot)
        self._unindex(row, pk)
        if self.hindex is not None:
            self._hash_remove(pk, row)
        self._clear_slot(slot)
        self.part_slots[slot] = -1
        self._free.append(slot)
        self.n_rows -= 1
        return True

    def _index(self, row: Dict[str, Any], pk: Tuple[Any, ...]) -> None:
        for c, ix in self.idx.items():
            ix.setdefault(row[c], set()).add(pk)

    def _unindex(self, row: Dict[str, Any], pk: Tuple[Any, ...]) -> None:
        for c, ix in self.idx.items():
            s = ix.get(row[c])
            if s is not None:
                s.discard(pk)
                if not s:
                    del ix[row[c]]

    # -- scans -----------------------------------------------------------
    def scan_index(self, col: str, value: Any) -> List[Dict[str, Any]]:
        pks = self.idx.get(col, {}).get(value, ())
        out = []
        for pk in pks:
            r = self.get(pk)
            if r is not None:
                out.append(r)
        return out

    def scan_partition(self, part: int, pred: Callable[[Dict[str, Any]], bool]
                       ) -> List[Dict[str, Any]]:
        out = []
        for pk, slot in self._slots.items():
            if int(self.part_slots[slot]) == part:
                r = self._materialize(slot)
                if pred(r):
                    out.append(r)
        return out

    def scan_all(self, pred: Callable[[Dict[str, Any]], bool]
                 ) -> List[Dict[str, Any]]:
        # partition-major like the dict store: bucket one insertion-order
        # pass, then flatten in partition order
        buckets: List[List[Dict[str, Any]]] = [
            [] for _ in range(self.n_partitions)]
        for pk, slot in self._slots.items():
            r = self._materialize(slot)
            if pred(r):
                buckets[int(self.part_slots[slot])].append(r)
        out: List[Dict[str, Any]] = []
        for b in buckets:
            out.extend(b)
        return out

    # -- dict-store-compatible views --------------------------------------
    @property
    def parts(self) -> List[Dict[Tuple[Any, ...], Dict[str, Any]]]:
        """Materialized per-partition row dicts — the read-only iteration
        view ``dump_state``/``namespace_snapshot`` consume."""
        out: List[Dict[Tuple[Any, ...], Dict[str, Any]]] = [
            {} for _ in range(self.n_partitions)]
        for pk, slot in self._slots.items():
            out[int(self.part_slots[slot])][pk] = self._materialize(slot)
        return out

    def hot_column(self, col: str) -> np.ndarray:
        """The live int64 mirror of a hot id column (slots, -1 = empty)."""
        return self._hot[col][:self._top]


class ColumnarMetadataStore(MetadataStore):
    """`MetadataStore` with the hot tables swapped to :class:`ColumnarTable`.

    Constructed exactly like the dict store (same partitioning, node
    groups, locks, hint-epoch piggyback) — only the storage layout of
    inode/block/lease changes, which is what the differential harness
    relies on: any behavioural drift IS a bug, not a feature."""

    COLUMNAR_TABLES = ("inode", "block", "lease")

    def __init__(self, *args: Any, **kwargs: Any):
        super().__init__(*args, **kwargs)
        for name in self.COLUMNAR_TABLES:
            t = self.tables.get(name)
            if t is not None:
                self.tables[name] = ColumnarTable(t.schema,
                                                  self.n_partitions)


# ---------------------------------------------------------------------------
# fused hint-chain window lowering (hintchain kernel launch site)
# ---------------------------------------------------------------------------


def _lower_one(ct: ColumnarTrace, i: int, wop: WorkloadOp, spec: Any,
               comps: List[str], resolver: Any) -> None:
    """Per-op body of ``workload.lower_trace``, verbatim — the exact path
    the fused reconstruction falls back to for AMBIG buckets."""
    need_leaf = spec.batchable or (spec.group_mutable
                                   and spec.hint == "target")
    pks: List[Tuple[int, str]] = []
    parent = ROOT_ID
    target_id: Optional[int] = None
    ok = True
    for d, name in enumerate(comps):
        pks.append((parent, name))
        ct.parent_ids[i, d] = parent
        ct.name_hashes[i, d] = name_hash32(name)
        child = resolver.peek(parent, name)
        if child is None:
            if d < len(comps) - 1 or need_leaf:
                ok = False
            break
        parent = child
        if d == len(comps) - 1:
            target_id = child
    ct.depths[i] = len(pks)
    if not ok:
        ct.resolved.append(False)
        ct.pks.append(None)
        ct.target_ids.append(None)
        return
    if spec.hint == "parent":
        ct.hint_ids[i] = pks[-1][0]
    else:
        ct.hint_ids[i] = target_id if target_id is not None else parent
    ct.resolved.append(True)
    ct.pks.append(tuple(pks))
    ct.target_ids.append(target_id)


def _snapshot_resolver(cache: Any, fallback: Any
                       ) -> Optional[Tuple[HashIndex, HashIndex]]:
    """Hash-index snapshots of (client cache, merged namenode caches);
    None when a view cannot be represented (unknown resolver shape)."""
    if not hasattr(cache, "export_entries"):
        return None
    cidx = HashIndex.from_entries(cache.export_entries())
    if fallback is None:
        fidx = HashIndex()
    elif hasattr(fallback, "caches"):
        # MultiCacheResolver precedence: first cache that knows a key wins
        merged: Dict[Tuple[int, str], int] = {}
        for c in fallback.caches:
            if not hasattr(c, "export_entries"):
                return None
            for par, name, iid in c.export_entries():
                merged.setdefault((par, name), iid)
        fidx = HashIndex.from_entries(
            (par, name, iid) for (par, name), iid in merged.items())
    elif hasattr(fallback, "export_entries"):
        fidx = HashIndex.from_entries(fallback.export_entries())
    else:
        return None
    return cidx, fidx


def lower_trace_fused(wops: Sequence[WorkloadOp], resolver: Any, *,
                      max_depth: int = 16,
                      min_batch: Optional[int] = None,
                      interpret: bool = True) -> Tuple[ColumnarTrace, bool]:
    """``workload.lower_trace`` with the per-probe Python loop replaced by
    ONE ``hintchain`` kernel launch over the whole window.

    Returns ``(trace, used_kernel)``.  Bit-equivalent to the Python walk:
    the resolver's hit/fallback/miss telemetry is replayed from the
    kernel's per-depth source codes, and any op that touches a
    crc-collided (AMBIG) bucket is re-resolved through the exact per-probe
    path.  Windows below ``min_batch`` total probes, resolvers that are
    not a ``HintResolver`` shape, or an unavailable kernel stack all fall
    back — the pure walk for the first two, the numpy oracle under the
    ``_KernelProbe`` gate for the last."""
    if min_batch is None:
        min_batch = HINTCHAIN_MIN_BATCH      # runtime lookup: patchable
    cache = getattr(resolver, "cache", None)
    fallback = getattr(resolver, "fallback", None)
    if cache is None or not all(hasattr(resolver, a) for a in
                                ("hits", "fallback_hits", "misses")):
        return lower_trace(wops, resolver, max_depth=max_depth), False
    n = len(wops)
    comps_of: List[Optional[List[str]]] = []
    specs: List[Any] = []
    total = 0
    for wop in wops:
        spec = REGISTRY.get(wop.op)
        comps = [c for c in wop.path.split("/") if c]
        specs.append(spec)
        if spec is None or not comps or len(comps) > max_depth:
            comps_of.append(None)
        else:
            comps_of.append(comps)
            total += len(comps)
    if total < max(2, min_batch):
        return lower_trace(wops, resolver, max_depth=max_depth), False
    snap = _snapshot_resolver(cache, fallback)
    if snap is None:
        return lower_trace(wops, resolver, max_depth=max_depth), False
    cidx, fidx = snap
    nam = np.zeros((n, max_depth), np.uint32)
    dep = np.zeros(n, np.int32)
    for i, comps in enumerate(comps_of):
        if comps:
            dep[i] = len(comps)
            nam[i, :len(comps)] = [name_hash32(c) for c in comps]

    def kern() -> Tuple[np.ndarray, np.ndarray]:
        from ..kernels.hintchain.ops import hintchain_resolve
        return hintchain_resolve(cidx.arrays(), fidx.arrays(), nam, dep,
                                 root_id=ROOT_ID, interpret=interpret)

    def fallb() -> Tuple[np.ndarray, np.ndarray]:
        from ..kernels.hintchain.ref import hintchain_ref
        cp, cn, cv = cidx.arrays()
        fp, fn, fv = fidx.arrays()
        return hintchain_ref(cp, cn, cv, fp, fn, fv, nam, dep,
                             root_id=ROOT_ID)

    try:
        (childs, srcs), used = _with_phash_kernel(
            kern, fallb, n_keys=total, min_batch=min_batch,
            probe=_hintchain_probe)
    except Exception:
        # even the numpy oracle failed (kernel package unimportable):
        # the pure walk is always available
        return lower_trace(wops, resolver, max_depth=max_depth), False

    type_names = list(REGISTRY.names())
    type_of = {name: i for i, name in enumerate(type_names)}
    type_ids = np.zeros(n, np.int32)
    depths = np.zeros(n, np.int32)
    parent_ids = np.zeros((n, max_depth), np.int64)
    name_hashes = np.zeros((n, max_depth), np.int64)
    hint_ids = np.full(n, ROOT_ID, np.int64)
    ct = ColumnarTrace(n=n, max_depth=max_depth, type_ids=type_ids,
                       depths=depths, parent_ids=parent_ids,
                       name_hashes=name_hashes, hint_ids=hint_ids)
    for i, wop in enumerate(wops):
        spec = specs[i]
        type_ids[i] = type_of.get(wop.op, -1)
        comps = comps_of[i]
        if comps is None:
            ct.resolved.append(False)
            ct.pks.append(None)
            ct.target_ids.append(None)
            continue
        need_leaf = spec.batchable or (spec.group_mutable
                                       and spec.hint == "target")
        pks: List[Tuple[int, str]] = []
        parent = ROOT_ID
        target_id: Optional[int] = None
        ok = True
        redo = False
        for d, name in enumerate(comps):
            pks.append((parent, name))
            parent_ids[i, d] = parent
            name_hashes[i, d] = name_hash32(name)
            child = int(childs[i, d])
            if child <= 0 and child != EMPTY:
                redo = True     # AMBIG bucket (or out-of-protocol code):
                break           # re-resolve this op exactly
            if child == EMPTY:
                resolver.misses += 1
                if d < len(comps) - 1 or need_leaf:
                    ok = False
                break
            if int(srcs[i, d]) == 0:
                resolver.hits += 1
            else:
                resolver.fallback_hits += 1
            parent = child
            if d == len(comps) - 1:
                target_id = child
        if redo:
            parent_ids[i, :] = 0
            name_hashes[i, :] = 0
            _lower_one(ct, i, wop, spec, comps, resolver)
            continue
        depths[i] = len(pks)
        if not ok:
            ct.resolved.append(False)
            ct.pks.append(None)
            ct.target_ids.append(None)
            continue
        if spec.hint == "parent":
            hint_ids[i] = pks[-1][0]
        else:
            hint_ids[i] = target_id if target_id is not None else parent
        ct.resolved.append(True)
        ct.pks.append(tuple(pks))
        ct.target_ids.append(target_id)
    return ct, used


# ---------------------------------------------------------------------------
# grouped-batch PK validation (pkval kernel launch site)
# ---------------------------------------------------------------------------


def _chain_probes(chains: Sequence[Tuple[Sequence[Tuple[int, str]],
                                         Optional[int]]]
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, List[int]]:
    """Flatten resolved ``(pks, target_id)`` chains into parallel probe
    arrays: each link's composite PK plus the inode id the client believes
    it resolves to (the next link's parent; the target for the leaf)."""
    parents: List[int] = []
    nams: List[int] = []
    expect: List[int] = []
    owner: List[int] = []
    for k, (pks, target_id) in enumerate(chains):
        if not pks:
            continue
        for d, (par, name) in enumerate(pks):
            if d < len(pks) - 1:
                want = pks[d + 1][0]
            elif target_id is not None:
                want = target_id
            else:
                continue        # parent-hinted leaf: nothing was resolved
            parents.append(par)
            nams.append(name_hash32(name))
            expect.append(want)
            owner.append(k)
    return (np.asarray(parents, np.int64), np.asarray(nams, np.int64),
            np.asarray(expect, np.int64), owner)


def _validate_chains(hindex: HashIndex,
                     chains: Sequence[Tuple[Sequence[Tuple[int, str]],
                                            Optional[int]]],
                     *, min_batch: int, interpret: bool
                     ) -> Tuple[Set[int], int, bool]:
    """(chain indices whose client resolution disagrees with the store,
    probe count, used_kernel). AMBIG buckets are inconclusive — the chain
    is KEPT and the server-side in-transaction validation decides."""
    parents, nams, expect, owner = _chain_probes(chains)
    # below the gate, skip validation ENTIRELY (not "validate on the
    # numpy oracle"): small windows then behave bit-identically to the
    # dict backend, and whether validation runs never depends on kernel
    # availability — kernel and oracle demote identically above the gate
    if len(parents) < max(2, min_batch):
        return set(), 0, False

    def kern() -> np.ndarray:
        from ..kernels.pkval.ops import pkval_lookup
        tp, tn, tv = hindex.arrays()
        return pkval_lookup(tp, tn, tv, parents, nams, interpret=interpret)

    def fallb() -> np.ndarray:
        from ..kernels.pkval.ref import pkval_ref
        tp, tn, tv = hindex.arrays()
        return pkval_ref(tp, tn, tv, parents.astype(np.int32),
                         nams.astype(np.uint32))

    out, used = _with_phash_kernel(kern, fallb, n_keys=len(parents),
                                   min_batch=min_batch, probe=_pkval_probe)
    demoted: Set[int] = set()
    for i, k in enumerate(owner):
        got = int(out[i])
        if got == AMBIG:
            continue
        if got != int(expect[i]):
            demoted.add(k)
    return demoted, len(parents), used


def validate_window_pks(store: MetadataStore, ct: ColumnarTrace, *,
                        min_batch: Optional[int] = None,
                        interpret: bool = True
                        ) -> Optional[Tuple[Set[int], int, bool]]:
    """Grouped-batch PK validation of a planner window (§5.1 batched
    reads, validated BEFORE they ship): every client-resolved chain in
    ``ct`` is probed against the columnar inode hash index in one fused
    launch.  Returns ``(demoted op indices, probes, used_kernel)``, or
    None when the store has no columnar inode table (the dict oracle) —
    validation is purely advisory, so the dict backend simply skips it.

    A demoted op is NOT failed: the planner clears its resolution so it
    rides the exact sequential path, which is also why a stale-but-
    revalidated-server-side hint and a demotion produce byte-identical
    final state."""
    if min_batch is None:
        min_batch = PKVAL_MIN_BATCH          # runtime lookup: patchable
    try:
        t = store.table("inode")
    except Exception:
        return None
    hindex = getattr(t, "hindex", None)
    if hindex is None:
        return None
    chains: List[Tuple[Sequence[Tuple[int, str]], Optional[int]]] = []
    owners: List[int] = []
    for k in range(ct.n):
        if k < len(ct.resolved) and ct.resolved[k] and ct.pks[k]:
            chains.append((ct.pks[k], ct.target_ids[k]))
            owners.append(k)
    if not chains:
        return set(), 0, False
    demoted_local, probes, used = _validate_chains(
        hindex, chains, min_batch=min_batch, interpret=interpret)
    return {owners[j] for j in demoted_local}, probes, used


def prevalidate_chains(store: MetadataStore,
                       chains: Sequence[Tuple[Sequence[Tuple[int, str]],
                                              Optional[int]]],
                       *, min_batch: Optional[int] = None,
                       interpret: bool = True
                       ) -> Optional[Tuple[List[bool], int, bool]]:
    """Namenode-side flavour of :func:`validate_window_pks` for the
    grouped read path: ``chains`` are the hint chains a read run is about
    to trust.  Returns ``(ok flags, probes, used_kernel)`` or None when
    the store is not columnar."""
    if min_batch is None:
        min_batch = PKVAL_MIN_BATCH          # runtime lookup: patchable
    try:
        t = store.table("inode")
    except Exception:
        return None
    hindex = getattr(t, "hindex", None)
    if hindex is None:
        return None
    demoted, probes, used = _validate_chains(
        hindex, chains, min_batch=min_batch, interpret=interpret)
    return [k not in demoted for k in range(len(chains))], probes, used


# ---------------------------------------------------------------------------
# fused subtree wave expansion (treeagg kernel launch site)
# ---------------------------------------------------------------------------


class WaveExpansion:
    """One BFS wave resolved in a single fused treeagg launch.

    ``wave`` is the sorted unique member ids the per-member arrays are
    aligned to; ``counts``/``dirs``/``sizes`` are int64 segment sums over
    each member's direct children; ``child_ids``/``child_dir_ids`` the
    children themselves (``child_dir_ids`` is the next frontier); ``used``
    whether the Pallas kernel ran (False = numpy-oracle fallback, i.e. a
    demotion above the gate)."""

    __slots__ = ("wave", "counts", "dirs", "sizes", "child_ids",
                 "child_dir_ids", "used")

    def __init__(self, wave, counts, dirs, sizes, child_ids,
                 child_dir_ids, used):
        self.wave = wave
        self.counts = counts
        self.dirs = dirs
        self.sizes = sizes
        self.child_ids = child_ids
        self.child_dir_ids = child_dir_ids
        self.used = used

    @property
    def n_children(self) -> int:
        return int(self.counts.sum())


def expand_wave(store: MetadataStore, wave_ids: Iterable[int], *,
                min_batch: Optional[int] = None,
                interpret: bool = True) -> Optional["WaveExpansion"]:
    """Resolve one subtree BFS wave — every member's direct children plus
    the ``du``/``content_summary`` segment sums — in ONE fused launch over
    the columnar inode table's hot columns.

    Returns None on the dict backend or below the slot-count gate (small
    tables then behave identically to the dict store, and whether the
    fused path runs never depends on kernel availability — kernel and
    numpy oracle produce bit-identical expansions above the gate).

    Sizes are summed as int32 inside the launch and widened to int64 here;
    the modeled file sizes stay far below the 2^31 partial-sum bound."""
    if min_batch is None:
        min_batch = TREEAGG_MIN_BATCH        # runtime lookup: patchable
    try:
        t = store.table("inode")
    except Exception:
        return None
    if not isinstance(t, ColumnarTable) or "size" not in t._hot:
        return None
    par = t.hot_column("parent_id")
    n_slots = int(par.shape[0])
    if n_slots < max(2, min_batch):
        return None
    wave = np.unique(np.fromiter(wave_ids, dtype=np.int64))
    if wave.size == 0:
        return None
    ids = t.hot_column("id")
    isdir = np.maximum(t.hot_column("is_dir"), 0)   # cleared slots: -1 -> 0
    size = np.maximum(t.hot_column("size"), 0)

    def kern():
        from ..kernels.treeagg.ops import treeagg_expand
        return treeagg_expand(wave, par, isdir, size, interpret=interpret)

    def fallb():
        from ..kernels.treeagg.ref import treeagg_ref
        return treeagg_ref(wave.astype(np.int32), par.astype(np.int32),
                           isdir.astype(np.int32), size.astype(np.int32))

    (seg, counts, dirs, sizes), used = _with_phash_kernel(
        kern, fallb, n_keys=n_slots, min_batch=min_batch,
        probe=_treeagg_probe)
    hit = seg >= 0
    child_ids = ids[hit]
    child_dir_ids = child_ids[isdir[hit] == 1]
    return WaveExpansion(wave, counts.astype(np.int64),
                         dirs.astype(np.int64), sizes.astype(np.int64),
                         child_ids, child_dir_ids, used)
