"""Stateless namenodes + client namenode-selection policies (paper §3).

A :class:`Namenode` is stateless apart from its inode hint cache: all
authoritative state lives in the :class:`~repro.core.store.MetadataStore`.
Any number of namenodes serve the same store concurrently; clients pick one
per-op via *random*, *round-robin* or *sticky* policies and transparently
fail over to another namenode when one dies (§7.6.1 — this is why HopsFS has
no failover downtime).
"""
from __future__ import annotations

import random
from typing import Any, Callable, Dict, List, Optional, Sequence

from .fs import FSError, HopsFSOps, OpResult, SubtreeLockedError
from .leader import LeaderElection
from .store import MetadataStore, StoreError
from .subtree import SubtreeOps


class Namenode:
    def __init__(self, store: MetadataStore, nn_id: int,
                 election: LeaderElection, **ops_kw):
        self.nn_id = nn_id
        self.election = election
        self.ops = HopsFSOps(store, nn_id,
                             is_nn_alive=election.is_alive, **ops_kw)
        self.subtree = SubtreeOps(self.ops)
        self.alive = True
        self.ops_served = 0

    def is_leader(self) -> bool:
        return self.election.leader() == self.nn_id

    # unified dispatch used by the workload driver / DES / benchmarks
    def execute(self, op: str, *args, **kw) -> OpResult:
        if not self.alive:
            raise StoreError(f"namenode {self.nn_id} is down")
        fn: Callable[..., OpResult] = {
            "create": self.ops.create,
            "read": self.ops.get_block_locations,
            "ls": self.ops.listing,
            "stat": self.ops.stat,
            "mkdir": self.ops.mkdir,
            "mkdirs": self.ops.mkdirs,
            "delete_file": self.ops.delete_file,
            "rename_file": self.ops.rename_file,
            "add_block": self.ops.add_block,
            "complete_block": self.ops.complete_block,
            "append": self.ops.append_file,
            "chmod_file": self.ops.chmod_file,
            "chown_file": self.ops.chown_file,
            "set_replication": self.ops.set_replication,
            "content_summary": self.ops.content_summary,
            "set_quota": self.ops.set_quota,
            "delete_subtree": self.subtree.delete_subtree,
            "rename_subtree": self.subtree.rename_subtree,
            "chmod_subtree": self.subtree.chmod_subtree,
            "chown_subtree": self.subtree.chown_subtree,
            "block_report": self.ops.process_block_report,
        }[op]
        res = fn(*args, **kw)
        self.ops_served += 1
        return res


class NamenodeCluster:
    """A fleet of stateless namenodes over one store, plus the election."""

    def __init__(self, store: MetadataStore, n_namenodes: int, **ops_kw):
        self.store = store
        self.election = LeaderElection(store)
        self.namenodes = [Namenode(store, i, self.election, **ops_kw)
                          for i in range(n_namenodes)]
        for nn in self.namenodes:
            self.election.heartbeat(nn.nn_id)

    def tick(self) -> None:
        """One heartbeat round: alive namenodes prove liveness."""
        self.election.tick()
        for nn in self.namenodes:
            if nn.alive:
                self.election.heartbeat(nn.nn_id)

    def kill(self, nn_id: int) -> None:
        self.namenodes[nn_id].alive = False

    def restart(self, nn_id: int) -> None:
        self.namenodes[nn_id].alive = True
        self.election.heartbeat(nn_id)

    def alive_namenodes(self) -> List[Namenode]:
        return [nn for nn in self.namenodes if nn.alive]

    def leader(self) -> Optional[Namenode]:
        lid = self.election.leader()
        return self.namenodes[lid] if lid is not None else None


class Client:
    """HopsFS client with namenode selection policies (§3) and transparent
    retry on namenode failure (§7.6.1) or subtree-lock conflicts (§6.3)."""

    def __init__(self, cluster: NamenodeCluster, policy: str = "sticky",
                 seed: int = 0):
        assert policy in ("random", "round_robin", "sticky")
        self.cluster = cluster
        self.policy = policy
        self.rng = random.Random(seed)
        self._rr = self.rng.randrange(1 << 16)
        self._sticky: Optional[int] = None
        self.retries = 0

    def _pick(self) -> Namenode:
        alive = self.cluster.alive_namenodes()
        if not alive:
            raise StoreError("no alive namenodes")
        if self.policy == "random":
            return self.rng.choice(alive)
        if self.policy == "round_robin":
            nn = alive[self._rr % len(alive)]
            self._rr += 1
            return nn
        # sticky: stay with one namenode (better hint-cache locality §5.1.1)
        if self._sticky is None or not self.cluster.namenodes[
                self._sticky].alive:
            self._sticky = self.rng.choice(alive).nn_id
        return self.cluster.namenodes[self._sticky]

    def execute(self, op: str, *args, **kw) -> OpResult:
        last: Optional[Exception] = None
        for _ in range(8):
            nn = self._pick()
            try:
                return nn.execute(op, *args, **kw)
            except SubtreeLockedError as e:      # voluntary abort: retry
                last = e
                self.retries += 1
            except StoreError as e:
                if not nn.alive:                  # failover: pick another NN
                    self.retries += 1
                    self._sticky = None
                    last = e
                    continue
                raise
        raise last  # type: ignore[misc]
