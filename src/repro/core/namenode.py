"""Stateless namenodes + client policies + the batched request pipeline.

A :class:`Namenode` is stateless apart from its inode hint cache: all
authoritative state lives in the :class:`~repro.core.store.MetadataStore`.
Any number of namenodes serve the same store concurrently; clients pick one
per-op via *random*, *round-robin* or *sticky* policies and transparently
fail over to another namenode when one dies (§7.6.1 — this is why HopsFS has
no failover downtime).

Batched request pipeline (paper §2.2/§7.2): the throughput headline comes
from many namenodes issuing *batched, distribution-aware* transactions.
:class:`RequestPipeline` feeds N namenodes from one shared client queue in
fixed-size batches; :meth:`Namenode.execute_batch` groups consecutive
same-type read ops whose paths fully hit the hint cache, hashes every
hinted inode id to its partition in one vectorized ``phash`` kernel call
(§4.2), and validates each same-partition group's paths with ONE batched
PK exchange instead of 2-3 round trips per op. Mutating ops and cache
misses fall back to the sequential path, preserving exact sequential
semantics (asserted by tests/test_batched_pipeline.py).
"""
from __future__ import annotations

import random
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from .fs import (FSError, HopsFSOps, OpResult, SubtreeLockedError,
                 split_path)
from .leader import LeaderElection
from .middleware import (CallContext, compose, failover, subtree_retry,
                         txn_retry)
from .ops_registry import GroupWriteCtx, REGISTRY, WorkloadOp
from .store import (EXCLUSIVE, MetadataStore, OpCost, READ_COMMITTED,
                    SHARED, StoreError, _hash_key)
from .subtree import SubtreeOps
from .tables import ROOT_ID
from .transactions import Transaction

# read-only op types the batched executor may group (no mutation => any
# ordering within a run of them is equivalent to sequential execution).
# Derived from the op registry — the registry's `batchable` flag is the
# single source of truth; this name survives for importers as an
# import-time snapshot (live code paths consult REGISTRY directly, so ops
# registered later batch too).
BATCHABLE_READ_OPS = REGISTRY.batchable_ops()

#: mutation op names the grouped WRITE path may share a transaction across
#: (same import-time-snapshot convention as BATCHABLE_READ_OPS)
GROUP_MUTABLE_OPS = REGISTRY.group_mutable_ops()

# Below this many keys the scalar hash beats an interpret-mode Pallas call
# (kernel dispatch overhead dominates); on accelerator-backed deployments
# the vectorized path wins for the bulk workloads (block reports, import
# manifests) that hash thousands of keys at once.
PHASH_MIN_BATCH = 512


class _KernelProbe:
    """Availability gate for the vectorized phash path.

    A kernel failure disables the vectorized path only TEMPORARILY: after
    ``reprobe_every`` eligible calls the kernel is probed again, so a
    transient failure (jit cache eviction, accelerator hiccup, OOM) can
    never latch the scalar fallback for the life of the process — which is
    exactly what the module-global bool this replaces used to do."""

    def __init__(self, reprobe_every: int = 64):
        self.reprobe_every = reprobe_every
        self.failures = 0                  # consecutive probe failures
        self._calls_since_failure = 0

    def usable(self) -> bool:
        if self.failures == 0:
            return True
        self._calls_since_failure += 1
        if self._calls_since_failure >= self.reprobe_every:
            self._calls_since_failure = 0  # bounded re-probe
            return True
        return False

    def succeeded(self) -> None:
        self.failures = 0
        self._calls_since_failure = 0

    def failed(self) -> None:
        self.failures += 1
        self._calls_since_failure = 0


_phash_probe = _KernelProbe()


def _with_phash_kernel(kernel_fn: Any, fallback_fn: Any, *, n_keys: int,
                       min_batch: int = PHASH_MIN_BATCH,
                       probe: Optional[_KernelProbe] = None
                       ) -> Tuple[Any, bool]:
    """Run a phash kernel under the shared availability probe: size-gated
    (below ``min_batch`` the scalar/numpy path wins on dispatch overhead),
    per-call fallback, bounded re-probe. The SINGLE implementation of the
    fallback policy for namenode-side grouping and the client-side batch
    planner — returns (result, used_kernel). Other kernel families (pkval,
    hintchain) pass their own ``probe`` so one family's failure never
    latches another's fallback."""
    gate = probe if probe is not None else _phash_probe
    if n_keys >= max(2, min_batch) and gate.usable():
        try:
            out = kernel_fn()
        except Exception:
            gate.failed()
        else:
            gate.succeeded()
            return out, True
    return fallback_fn(), False


def _partitions_for(ids: Sequence[int], n_partitions: int, *,
                    min_batch: int = PHASH_MIN_BATCH) -> List[int]:
    """Batch path->partition hashing: the phash Pallas kernel for large
    batches, the scalar store hash below ``min_batch`` (or while the kernel
    stack is unavailable — per-call fallback with bounded re-probe). Both
    implement the identical mix, so placement always agrees with
    ``MetadataStore`` partitioning."""
    def kern() -> List[int]:
        from ..kernels.phash.ops import phash_partitions
        return [int(p) for p in phash_partitions(ids, n_partitions)]

    out, _ = _with_phash_kernel(
        kern, lambda: [_hash_key(i) % n_partitions for i in ids],
        n_keys=len(ids), min_batch=min_batch)
    return out


@dataclass(frozen=True)
class PlanHint:
    """Client-side path resolution shipped with a planned batch (λFS-style
    client-side routing): the composite-PK chain of the op's path, the
    target inode id when the leaf resolved client-side, and the
    partition-hint inode id the planner grouped on. The executor treats
    these exactly like its own hint-cache output — validated against real
    rows inside the transaction, never trusted."""
    pks: Tuple[Tuple[int, str], ...]
    target_id: Optional[int]
    hint_id: int


@dataclass
class OpOutcome:
    """Per-op outcome from the batched pipeline: either a result or the
    name of the FS error that sequential execution would have raised."""
    result: Optional[OpResult]
    error: Optional[str] = None
    batched: bool = False

    @property
    def ok(self) -> bool:
        return self.result is not None


class Namenode:
    def __init__(self, store: MetadataStore, nn_id: int,
                 election: LeaderElection, **ops_kw):
        self.nn_id = nn_id
        self.store = store
        self.election = election
        # client leases are renewed/expired against the SAME logical clock
        # the election uses, so client death is detected exactly like
        # namenode death (bounded heartbeat staleness)
        ops_kw.setdefault("lease_now", lambda: election.now)
        self.ops = HopsFSOps(store, nn_id,
                             is_nn_alive=election.is_alive, **ops_kw)
        self.subtree = SubtreeOps(self.ops)
        self.alive = True
        #: chaos injection hook (chaos.FaultInjector.install); None = off.
        #: Sites fired here: "rpc" (perform/invoke), "batch_exchange"
        #: (execute_batch), "group_txn_pre_lock"/"group_txn_post_lock"
        #: (_write_group_txn) — see docs/CHAOS.md
        self.chaos: Optional[Any] = None
        #: admission-control hook (admission.AdmissionController.install);
        #: None = admit everything. Consulted AFTER the chaos site fires
        #: (a gray-slow exchange ages the clock first, THEN stale work is
        #: shed) — see docs/ROBUSTNESS.md
        self.admission: Optional[Any] = None
        self._in_batch = False   # suppress the rpc site for internal invokes
        self.ops_served = 0
        self.agg_cost = OpCost()     # committed-txn cost served by this NN
        self.batches_executed = 0
        self.batched_ops = 0
        self.batched_write_ops = 0   # mutations served by grouped txns
        # fused PK-validation telemetry (columnar backend only): grouped
        # read runs prevalidate their hint chains in one pkval launch
        self.pkval_launches = 0
        self.pkval_probes = 0
        self.pkval_demotions = 0
        # fused subtree/aggregation telemetry lives on ops + subtree;
        # see the treeagg_launches/treeagg_demotions properties below
        # prebuilt default retry chain — the batch hot path must not
        # recompose middleware per op. txn_retry sits inside: a lock
        # timeout under concurrent workers aborted atomically (§7.5), so
        # the op re-runs instead of surfacing a spurious failure
        self._safe_handler = compose([subtree_retry(), txn_retry()],
                                     lambda ctx: self.invoke(ctx.wop))

    @property
    def treeagg_launches(self) -> int:
        """Fused treeagg launches across this NN's two launch sites: the
        du/content aggregation (ops) and phase-2 wave advisory (subtree)."""
        return self.ops.treeagg_launches + self.subtree.treeagg_launches

    @property
    def treeagg_demotions(self) -> int:
        return self.ops.treeagg_demotions + self.subtree.treeagg_demotions

    def is_leader(self) -> bool:
        return self.election.leader() == self.nn_id

    def recover_leases(self) -> int:
        """Leader housekeeping (§3: "the leader runs ... lease recovery"):
        reclaim every lease whose holder stopped renewing for longer than
        the lease limit — clears under-construction state so another
        client's append/add_block can proceed. Only the leader runs this,
        mirroring §6.2's dead-namenode subtree-lock reclaim for clients.
        Returns the number of leases reclaimed."""
        if not self.alive or not self.is_leader():
            return 0
        reclaimed = 0
        for holder in self.ops.expired_lease_holders():
            try:
                res = self.ops.lease_recover(holder)
            except StoreError:
                # lock contention with the holder's own in-flight write
                # (it is evidently alive): skip — the next sweep re-scans
                continue
            self.agg_cost.merge(res.cost)
            if res.value is not None:    # None = renewed since the scan
                reclaimed += 1
        return reclaimed

    def scrub_leases(self) -> int:
        """Leader housekeeping twin of :meth:`recover_leases`: drop
        lease_path rows orphaned by file deletion (the model defers the
        HDFS LeaseManager's on-delete path removal to this sweep).
        Returns the number of rows scrubbed."""
        if not self.alive or not self.is_leader():
            return 0
        res = self.ops.scrub_leases()
        self.agg_cost.merge(res.cost)
        return res.value

    # -- response piggybacking (the closed-loop hint path) -------------
    def _piggyback_hints(self, paths: Sequence[str]
                         ) -> Tuple[Tuple[int, str, int], ...]:
        """The ``(parent_id, name) -> inode_id`` resolutions this
        namenode's hint cache holds for the op's path(s) AFTER execution
        — shipped back on every response (``OpResult.hints``) so client
        caches warm from responses instead of reading namenode caches.
        Pure in-memory peeks: charge-free, and post-execution state means
        a create's new inode rides its own response while a delete's
        victim (invalidated by the handler) never does."""
        cache = self.ops.cache
        if cache is None:
            return ()
        out: List[Tuple[int, str, int]] = []
        for p in paths:
            parent = ROOT_ID
            for name in split_path(p):
                child = cache.peek(parent, name)
                if child is None:
                    break
                out.append((parent, name, child))
                parent = child
        return tuple(out)

    def _finish_op(self, spec: Any, paths: Sequence[str],
                   kw: Dict[str, Any], res: OpResult) -> OpResult:
        """Post-execution RPC work shared by every entry point: account
        the op, piggyback the hint set onto the response, and refresh the
        executing client's lease stamp (piggybacked renewal — any op by a
        live holder is a heartbeat, ``HopsFSOps.touch_lease``)."""
        self.ops_served += 1
        self.agg_cost.merge(res.cost)
        if spec is not None and spec.destructive:
            # cross-client invalidation push: log the destroyed/moved
            # paths under a fresh store-wide hint epoch, so OTHER
            # clients' caches learn of them from their own next response
            # (concat's srcs are paths too, but arrive as a kwarg)
            self.store.record_hint_invalidation(
                list(paths) + [str(s) for s in kw.get("srcs", ()) or ()])
        res.hints = self._piggyback_hints(paths) + self.store.hint_piggyback()
        # goodput stamp: the election-clock tick this op finished at —
        # compared against WorkloadOp.deadline by the admission layer
        res.completed_at = self.election.now
        if spec is not None and spec.has_client_arg \
                and not spec.renews_lease and "client" in kw:
            # skipped for renews_lease ops: their handler already stamped
            # the lease inside its own transaction (lease_write)
            self.ops.touch_lease(kw["client"])
        return res

    # -- registry-dispatched execution ---------------------------------
    def perform(self, op: str, *args, **kw) -> OpResult:
        """Execute one op by registry name with explicit arguments — the
        canonical positional entry point (DFSClient and Client use it)."""
        if not self.alive:
            raise StoreError(f"namenode {self.nn_id} is down")
        if self.chaos is not None and not self._in_batch:
            self.chaos.fire("rpc", self.nn_id)
        spec = REGISTRY[op]
        res = spec.resolve(self)(*args, **kw)
        return self._finish_op(spec, [a for a in args[:spec.paths]
                                      if isinstance(a, str)], kw, res)

    def invoke(self, wop: WorkloadOp) -> OpResult:
        """Execute one :class:`WorkloadOp` record: the record's own
        ``args`` overlaid on the :class:`~.ops_registry.OpSpec` defaults,
        so workload-supplied arguments (perm, owner, repl, ...) flow
        end-to-end instead of being hardcoded here."""
        if not self.alive:
            raise StoreError(f"namenode {self.nn_id} is down")
        if self.chaos is not None and not self._in_batch:
            self.chaos.fire("rpc", self.nn_id)
        if self.admission is not None:
            # sequential-path admission: shed work already past its
            # deadline. Inside a batch this is a RE-check (the batch was
            # admitted as a whole, but a mid-batch group txn may have
            # burned clock) — record=False avoids double accounting
            self.admission.check_op(wop, record=not self._in_batch)
        spec = REGISTRY[wop.op]
        paths, kw = spec.call_args(wop)
        res = spec.resolve(self)(*paths, **kw)
        return self._finish_op(spec, paths, kw, res)

    # -- deprecated string-dispatch shims ------------------------------
    def execute(self, op: str, *args, **kw) -> OpResult:
        """Deprecated: use :meth:`perform` (or the ``DFSClient`` facade)."""
        warnings.warn("Namenode.execute(op, ...) is deprecated; use "
                      "Namenode.perform or the DFSClient facade",
                      DeprecationWarning, stacklevel=2)
        return self.perform(op, *args, **kw)

    def execute_wop(self, wop: WorkloadOp) -> OpResult:
        """Deprecated: use :meth:`invoke`."""
        warnings.warn("Namenode.execute_wop(wop) is deprecated; use "
                      "Namenode.invoke", DeprecationWarning, stacklevel=2)
        return self.invoke(wop)

    # ------------------------------------------------------------------
    # batched execution (pipeline hot path)
    # ------------------------------------------------------------------
    def _safe_exec(self, wop: WorkloadOp, *, retries: int = 8,
                   backoff: float = 0.002) -> OpOutcome:
        """Execute one op, mapping FS errors to outcomes. Ops that hit a
        live subtree lock voluntarily aborted (§6.3) — retried with backoff
        by the shared ``subtree_retry`` middleware, exactly as the HopsFS
        client does, instead of failing."""
        if (retries, backoff) == (8, 0.002):
            handler = self._safe_handler      # hot path: prebuilt chain
        else:
            handler = compose(
                [subtree_retry(retries=retries, backoff=backoff),
                 txn_retry()],
                lambda ctx: self.invoke(ctx.wop))
        try:
            return OpOutcome(handler(CallContext(op=wop.op, wop=wop,
                                                 namenode=self)))
        except StoreError as e:      # includes surfaced SubtreeLockedError
            return OpOutcome(None, type(e).__name__)

    def execute_batch(self, wops: Sequence[WorkloadOp],
                      hints: Optional[Sequence[Optional[PlanHint]]] = None
                      ) -> List[OpOutcome]:
        """Execute a pulled batch. Maximal runs of consecutive same-type
        groupable ops are executed through the grouped paths — batchable
        reads via one shared transaction per partition group, group-mutable
        mutations via one shared run transaction with total-order locking
        and submission-order execute phases — and everything else runs
        through the exact sequential path, in order. Either way the store
        ends in the same state as strictly sequential execution of the
        batch. ``hints`` optionally carries the planner's client-side path
        resolutions (one entry per op, None where unplanned)."""
        if not self.alive:
            raise StoreError(f"namenode {self.nn_id} is down")
        if self.chaos is not None:
            self.chaos.fire("batch_exchange", self.nn_id)
        # ops inside the batch share THIS exchange: the per-op rpc site
        # must not fire again for internal invokes
        self._in_batch = True
        try:
            if self.admission is None:
                return self._execute_batch_inner(wops, hints)
            # batch admission AFTER the exchange's chaos site: a gray-slow
            # exchange ages the clock first, so work that expired while
            # this namenode limped is shed here instead of executed
            decisions = self.admission.admit_batch(wops)
            results: List[Optional[OpOutcome]] = [
                None if d is None else OpOutcome(None, d, batched=True)
                for d in decisions]
            keep = [i for i, d in enumerate(decisions) if d is None]
            if keep:
                sub = [wops[i] for i in keep]
                subh = ([hints[i] for i in keep]
                        if hints is not None else None)
                for i, oc in zip(keep, self._execute_batch_inner(sub, subh)):
                    results[i] = oc
            return results  # type: ignore[return-value]
        finally:
            self._in_batch = False

    def _execute_batch_inner(self, wops: Sequence[WorkloadOp],
                             hints: Optional[Sequence[Optional[PlanHint]]]
                             ) -> List[OpOutcome]:
        results: List[Optional[OpOutcome]] = [None] * len(wops)
        i = 0
        while i < len(wops):
            op = wops[i].op
            j = i + 1
            spec = REGISTRY.get(op)
            groupable = spec is not None and (
                spec.batchable
                or (spec.group_mutable and spec.group_apply is not None))
            if groupable:                             # live registry check
                while j < len(wops) and wops[j].op == op:
                    j += 1
                if j - i > 1:
                    if spec.batchable:
                        self._execute_read_run(op, wops, i, j, results,
                                               hints)
                    else:
                        self._execute_write_run(op, wops, i, j, results,
                                                hints)
                else:
                    results[i] = self._safe_exec(wops[i])
            else:
                results[i] = self._safe_exec(wops[i])
            i = j
        self.batches_executed += 1
        # response piggybacking for the GROUPED outcomes (the sequential
        # path attaches hints in invoke): ship back the hint-cache state
        # the grouped transactions repaired, and refresh the executing
        # clients' lease stamps (any op by a live holder is a heartbeat —
        # once per DISTINCT client, not per op: all stamps in one batch
        # share the same logical tick, so N touches of one hot client
        # would just be N redundant lock round trips)
        clients: Set[str] = set()
        for wop, oc in zip(wops, results):
            if oc is None or not oc.ok or not oc.batched:
                continue
            spec = REGISTRY.get(wop.op)
            if spec is None:
                continue
            paths, kw = spec.call_args(wop)
            oc.result.hints = self._piggyback_hints(paths) \
                + self.store.hint_piggyback()
            if spec.has_client_arg and not spec.renews_lease \
                    and "client" in kw:
                clients.add(kw["client"])
        for client in sorted(clients):
            self.ops.touch_lease(client)
        return results  # type: ignore[return-value]

    def _execute_read_run(self, op: str, wops: Sequence[WorkloadOp],
                          lo: int, hi: int,
                          results: List[Optional[OpOutcome]],
                          hints: Optional[Sequence[Optional[PlanHint]]]
                          = None) -> None:
        """A run of same-type read ops: ops whose full path chain hits the
        hint cache (or arrived with a planner hint) are grouped by target
        partition (vectorized phash over the hinted inode ids) and executed
        one shared transaction per partition group; cache misses fall back
        to the sequential path."""
        cache = self.ops.cache
        hits: List[Tuple[int, List[str], List[Tuple[int, str]], int]] = []
        for idx in range(lo, hi):
            comps = split_path(wops[idx].path)
            resolved = (cache.resolve_pks_and_id(comps)
                        if (cache is not None and comps) else None)
            if resolved is None and hints is not None and comps:
                h = hints[idx]
                if h is not None and h.target_id is not None:
                    resolved = (list(h.pks), h.target_id)
            if resolved is None:
                results[idx] = self._safe_exec(wops[idx])
            else:
                pks, tid = resolved
                hits.append((idx, comps, pks, tid))
        hits = self._prevalidate_hits(wops, hits, results)
        if not hits:
            return
        parts = _partitions_for([h[3] for h in hits],
                                self.ops.store.n_partitions)
        groups: Dict[int, List[Tuple[int, List[str],
                                     List[Tuple[int, str]], int]]] = {}
        for h, p in zip(hits, parts):
            groups.setdefault(p, []).append(h)
        for _, group in sorted(groups.items()):
            self._read_group_txn(op, wops, group, results)

    def _prevalidate_hits(self, wops: Sequence[WorkloadOp],
                          hits: List[Tuple[int, List[str],
                                           List[Tuple[int, str]], int]],
                          results: List[Optional[OpOutcome]]
                          ) -> List[Tuple[int, List[str],
                                          List[Tuple[int, str]], int]]:
        """Grouped-batch PK validation of a read run's hint chains: ONE
        fused pkval launch against the columnar store's hash index, stale
        chains demoted to the exact sequential path BEFORE they waste a
        grouped round trip. A no-op on the dict backend (no hash index)
        and below the kernel's batch gate — purely advisory either way,
        since in-transaction validation still guards every grouped read."""
        if not hits:
            return hits
        from .columnar import prevalidate_chains
        out = prevalidate_chains(
            self.ops.store, [(h[2], h[3]) for h in hits])
        if out is None:
            return hits
        ok_flags, probes, used = out
        if probes:
            self.pkval_probes += probes
            if used:
                self.pkval_launches += 1
        kept = []
        for h, ok in zip(hits, ok_flags):
            if ok:
                kept.append(h)
            else:
                self.pkval_demotions += 1
                results[h[0]] = self._safe_exec(wops[h[0]])
        return kept

    def _commit_group(self, txn: Transaction, order: Sequence[int],
                      values: Dict[int, Any], op_costs: Dict[int, OpCost],
                      errors: Dict[int, str], accounted: OpCost,
                      results: List[Optional[OpOutcome]], *,
                      writes: bool = False) -> None:
        """Commit a grouped transaction and attribute its cost per op —
        the single source of the conserved-accounting invariant for BOTH
        the grouped read and grouped write paths: each op keeps its own
        ``OpCost.diff`` share; the shared validation batch, commit flush,
        and any reads done for ops that errored or fell back are charged
        to the FIRST successful op, so Σ outcome costs == the cost
        aggregated per namenode. (Like the sequential path, the cost of a
        transaction that served no op at all is dropped.)"""
        total = txn.commit()
        unattributed = total.diff(accounted)
        served = OpCost()
        first_done = True
        for idx in order:
            if idx in values:
                cost = op_costs[idx]
                if first_done:
                    cost.merge(unattributed)
                    first_done = False
                results[idx] = OpOutcome(
                    OpResult(values[idx], cost,
                             completed_at=self.election.now),
                    batched=True)
                served.merge(cost)
                self.ops_served += 1
                self.batched_ops += 1
                if writes:
                    self.batched_write_ops += 1
            elif idx in errors:
                results[idx] = OpOutcome(None, errors[idx], batched=True)
        self.agg_cost.merge(served)

    def _read_group_txn(self, op: str, wops: Sequence[WorkloadOp],
                        group: Sequence[Tuple[int, List[str],
                                              List[Tuple[int, str]], int]],
                        results: List[Optional[OpOutcome]]) -> None:
        """One shared distribution-aware transaction for a same-partition
        group: ONE batched exchange validates every op's ancestor chain,
        lock-reads every target, and folds in the dependent lease reads;
        per-op file scans then run inside the same transaction. Stale hints
        are invalidated and the op re-runs sequentially (§5.1.1)."""
        fsops = self.ops
        spec = REGISTRY[op]
        fallback: List[int] = []
        try:
            txn = Transaction(fsops.store,
                              partition_hint=("inode", group[0][3]),
                              distribution_aware=fsops.dat)
        except StoreError:
            for idx, *_ in group:
                results[idx] = self._safe_exec(wops[idx])
            return
        try:
            per_op: Dict[int, Tuple[bool, List[Dict[str, Any]],
                                    Optional[Dict[str, Any]], int]] = {}
            with txn.batch() as b:
                for idx, comps, pks, _tid in group:
                    got: List[Dict[str, Any]] = []
                    ok = True
                    parent = ROOT_ID
                    for pk in pks[:-1]:
                        r = b.read("inode", pk, READ_COMMITTED)
                        if r is None or pk[0] != parent:
                            ok = False
                            break
                        got.append(r)
                        parent = r["id"]
                    target = None
                    if ok:
                        target = b.read("inode", (parent, comps[-1]), SHARED)
                        if target is not None and spec.lease_read:
                            # dependent lease read, same exchange (§5.1)
                            b.read("lease",
                                   (target.get("client") or "client",),
                                   READ_COMMITTED)
                    per_op[idx] = (ok, got, target, parent)
            op_costs: Dict[int, OpCost] = {}
            values: Dict[int, Any] = {}
            errors: Dict[int, str] = {}
            accounted = OpCost()
            for idx, comps, pks, _tid in group:
                ok, ancestors, target, parent_id = per_op[idx]
                if not ok or target is None:
                    # stale hints (rename/delete moved a row): repair + redo
                    if cachev := fsops.cache:
                        for pk in pks:
                            cachev.invalidate(*pk)
                    fallback.append(idx)
                    continue
                before = txn.cost.copy()
                try:
                    values[idx] = spec.batch_payload(fsops, txn, target)
                    for row in ancestors:
                        fsops._check_subtree_lock(row, txn)
                    fsops._check_subtree_lock(target, txn)
                    if fsops.cache:
                        # repair under the VALIDATED ids — a recreated
                        # ancestor keeps its composite PK but gets a new
                        # inode id, and the hinted ids may be stale
                        for pk, row in zip(pks, ancestors):
                            fsops.cache.put(pk[0], pk[1], row["id"])
                        fsops.cache.put(parent_id, comps[-1], target["id"])
                    op_costs[idx] = txn.cost.diff(before)
                    accounted.merge(op_costs[idx])
                except SubtreeLockedError:
                    # voluntary abort (§6.3): re-run sequentially w/ retry
                    values.pop(idx, None)
                    fallback.append(idx)
                except StoreError as e:
                    errors[idx] = type(e).__name__
                    values.pop(idx, None)
            self._commit_group(txn, [idx for idx, *_ in group], values,
                               op_costs, errors, accounted, results)
        except StoreError:
            txn.abort()
            fallback = [idx for idx, *_ in group]
        for idx in fallback:
            results[idx] = self._safe_exec(wops[idx])

    # ------------------------------------------------------------------
    # grouped WRITE path (§5 three-phase template shared across a run)
    # ------------------------------------------------------------------
    def _execute_write_run(self, op: str, wops: Sequence[WorkloadOp],
                           lo: int, hi: int,
                           results: List[Optional[OpOutcome]],
                           hints: Optional[Sequence[Optional[PlanHint]]]
                           = None) -> None:
        """A run of same-type group-mutable mutations: ops whose ancestor
        chain resolves (hint cache, else planner hints) share ONE
        transaction whose coordinator lands on the partition most ops in
        the run hash to (vectorized phash — for planner-aligned batches the
        whole run shares that partition, so the DAT hint is exact).
        Execute phases apply in submission order, so grouped execution
        stays observably identical to sequential execution; everything
        unresolvable falls back to the sequential path, in order.

        Lease-ordered block writes (add_block/append/complete_block) ride
        this same path: submission-order execute phases serialize each
        file's block mutations behind its lease (block indices and
        under-construction state stay exactly sequential) while distinct
        files — distinct lease keys — batch freely in one transaction."""
        cache = self.ops.cache
        spec = REGISTRY[op]
        segment: List[Tuple[int, List[str], List[Tuple[int, str]], int,
                            Dict[str, Any]]] = []

        def flush_segment() -> None:
            if not segment:
                return
            items = list(segment)
            segment.clear()
            parts = _partitions_for([it[3] for it in items],
                                    self.ops.store.n_partitions)
            counts: Dict[int, int] = {}
            for p in parts:
                counts[p] = counts.get(p, 0) + 1
            coord = max(counts, key=lambda p: (counts[p], -p))
            hint_key = items[parts.index(coord)][3]
            fallback: List[int] = []
            self._write_group_txn(spec, wops, items, hint_key, results,
                                  fallback)
            for i in sorted(set(fallback)):
                if results[i] is None:
                    results[i] = self._safe_exec(wops[i])

        # the run is split into maximal SEGMENTS of consecutive resolvable
        # ops: a cache-miss op executes sequentially AT ITS SUBMISSION
        # POSITION (after the segment before it, before everything after),
        # so resolvability differences can never reorder mutations
        for idx in range(lo, hi):
            wop = wops[idx]
            comps = split_path(wop.path)
            resolved: Optional[Tuple[List[Tuple[int, str]], int]] = None
            if comps and cache is not None:
                if spec.hint == "parent":
                    pks = cache.resolve_pks(comps)
                    if pks is not None:
                        resolved = (pks, pks[-1][0])
                else:
                    resolved = cache.resolve_pks_and_id(comps)
            if resolved is None and hints is not None and comps:
                h = hints[idx]
                if h is not None:
                    resolved = (list(h.pks), h.hint_id)
            if resolved is None:
                flush_segment()
                results[idx] = self._safe_exec(wop)
            else:
                _, kw = spec.call_args(wop)
                segment.append((idx, comps, resolved[0], resolved[1], kw))
        flush_segment()

    def _write_group_txn(self, spec: Any, wops: Sequence[WorkloadOp],
                         items: Sequence[Tuple[int, List[str],
                                               List[Tuple[int, str]], int,
                                               Dict[str, Any]]],
                         hint_key: int,
                         results: List[Optional[OpOutcome]],
                         fallback: List[int]) -> None:
        """One shared distribution-aware transaction for a run of
        mutations, following the Fig 4 template across the whole group:

        LOCK    — ONE batched exchange: every op's ancestor chain at
                  read-committed, then every op's exclusive (parent,
                  target) locks in GLOBAL root-down path order (§5 "Cyclic
                  Deadlocks" — two namenodes grouping overlapping paths
                  acquire in the same order), then the dependent aux reads
                  (lease/quota) of the ops' lock phases. Lease rows are
                  only X-locked at write time, AFTER the holder's file
                  inode lock — so lease-lock order is derived from the
                  global inode-lock order and cannot deadlock either.
        EXECUTE — per-op ``group_apply`` (the same fs.py apply helpers the
                  sequential handlers run) in SUBMISSION order, on
                  cache-fresh rows, so ops in one group observe each
                  other exactly as sequential execution interleaves them.
        UPDATE  — one commit flushes every op's dirty rows; per-op cost
                  attributed via ``OpCost.diff`` snapshots, the shared
                  validation/commit cost to the first successful op.

        Stale hints are invalidated and the op re-runs sequentially
        (§5.1.1); a transaction-level failure aborts (discarding every
        in-cache effect) and the whole group re-runs sequentially."""
        fsops = self.ops
        lock_parent = spec.hint == "parent"
        root_pk = (0, "")
        try:
            txn = Transaction(fsops.store,
                              partition_hint=("inode", hint_key),
                              distribution_aware=fsops.dat)
        except StoreError:
            fallback.extend(idx for idx, *_ in items)
            return
        try:
            if self.chaos is not None:     # crash before any lock is taken
                self.chaos.fire("group_txn_pre_lock", self.nn_id)
            chains: Dict[int, Tuple[bool, List[Dict[str, Any]], int]] = {}
            rows: Dict[Tuple[int, str],
                       Tuple[Tuple[int, str],
                             Optional[Dict[str, Any]]]] = {}
            with txn.batch() as b:
                for idx, comps, pks, _hint, kw in items:
                    ok = True
                    got: List[Dict[str, Any]] = []
                    parent = ROOT_ID
                    for pk in pks[:-1]:
                        r = b.read("inode", pk, READ_COMMITTED)
                        if r is None or pk[0] != parent:
                            ok = False
                            break
                        got.append(r)
                        parent = r["id"]
                    chains[idx] = (ok, got, parent)
                # exclusive locks for every op, globally sorted root-down
                lock_list: List[Tuple[Tuple[str, ...], Tuple[int, str],
                                      int, str]] = []
                for idx, comps, pks, _hint, kw in items:
                    ok, _got, parent_id = chains[idx]
                    if not ok:
                        continue
                    if lock_parent:
                        ppk = pks[-2] if len(pks) >= 2 else root_pk
                        lock_list.append((tuple(comps[:-1]), ppk, idx,
                                          "parent"))
                    lock_list.append((tuple(comps),
                                      (parent_id, comps[-1]), idx,
                                      "target"))
                for path_key, pk, idx, kind in sorted(
                        lock_list, key=lambda e: e[0]):
                    rows[(idx, kind)] = (pk, b.read("inode", pk, EXCLUSIVE))
                if spec.group_aux is not None:
                    for idx, comps, pks, _hint, kw in items:
                        ok, _got, parent_id = chains[idx]
                        if not ok:
                            continue
                        target = rows[(idx, "target")][1]
                        for tname, pk, lk in spec.group_aux(kw, parent_id,
                                                            target):
                            b.read(tname, pk, lk)
            if self.chaos is not None:     # crash HOLDING the group's locks
                self.chaos.fire("group_txn_post_lock", self.nn_id)
            # ---- validation + subtree checks + cache repair ------------
            valid: List[Tuple[int, List[str], Dict[str, Any],
                              Tuple[int, str], Tuple[int, str]]] = []
            for idx, comps, pks, _hint, kw in items:
                ok, got, parent_id = chains[idx]
                parent_pk = (pks[-2] if len(pks) >= 2 else root_pk)
                if ok and lock_parent and rows[(idx, "parent")][1] is None:
                    ok = False
                if not ok:
                    if cachev := fsops.cache:
                        for pk in pks:
                            cachev.invalidate(*pk)
                    fallback.append(idx)
                    continue
                target_pk, target = rows[(idx, "target")]
                try:
                    for row in got:
                        fsops._check_subtree_lock(row, txn)
                    if lock_parent:
                        fsops._check_subtree_lock(rows[(idx, "parent")][1],
                                                  txn)
                    if target is not None:
                        fsops._check_subtree_lock(target, txn)
                except SubtreeLockedError:
                    fallback.append(idx)        # voluntary abort (§6.3)
                    continue
                if fsops.cache:
                    # repair under the VALIDATED ids (cf. the read path)
                    for pk, row in zip(pks, got):
                        fsops.cache.put(pk[0], pk[1], row["id"])
                    if target is not None:
                        fsops.cache.put(parent_id, comps[-1], target["id"])
                valid.append((idx, comps, kw, parent_pk, target_pk))
            # ---- EXECUTE phase, strictly in submission order -----------
            op_costs: Dict[int, OpCost] = {}
            values: Dict[int, Any] = {}
            errors: Dict[int, str] = {}
            accounted = OpCost()
            for idx, comps, kw, parent_pk, target_pk in sorted(valid):
                parent_row = txn.peek("inode", parent_pk)
                target_row = txn.peek("inode", target_pk)
                before = txn.cost.copy()
                before_dirty = len(txn.dirty)
                try:
                    ctx = GroupWriteCtx(parent=parent_row,
                                        target=target_row,
                                        comps=list(comps),
                                        path=wops[idx].path, kw=kw)
                    values[idx] = spec.group_apply(fsops, txn, ctx)
                    op_costs[idx] = txn.cost.diff(before)
                    accounted.merge(op_costs[idx])
                except SubtreeLockedError:
                    # apply helpers check before writing, so a clean raise
                    # leaves no trace; anything that DID write must not be
                    # half-committed — abort the whole group instead
                    # (sequential execution aborts that op's transaction)
                    if len(txn.dirty) != before_dirty:
                        raise
                    fallback.append(idx)
                except StoreError as e:
                    if len(txn.dirty) != before_dirty:
                        raise
                    errors[idx] = type(e).__name__
            self._commit_group(txn, [idx for idx, *_ in items], values,
                               op_costs, errors, accounted, results,
                               writes=True)
        except StoreError:
            # transaction-level failure: discard every in-cache effect and
            # re-run the whole group sequentially
            txn.abort()
            fallback.extend(idx for idx, *_ in items)
            for idx, *_ in items:
                results[idx] = None


class NamenodeCluster:
    """A fleet of stateless namenodes over one store, plus the election.

    ``auto_lease_recovery=True`` makes every heartbeat round also run the
    leader's lease-recovery housekeeping (production behaviour); the
    default keeps recovery explicit (:meth:`recover_leases`) so
    state-equivalence tests control exactly when store state changes."""

    def __init__(self, store: MetadataStore, n_namenodes: int, *,
                 auto_lease_recovery: bool = False, **ops_kw):
        self.store = store
        self.election = LeaderElection(store)
        self.auto_lease_recovery = auto_lease_recovery
        # kept for elastic membership: add_namenode builds late joiners
        # with the same ops configuration the founders got (copied per
        # namenode — Namenode.__init__ setdefaults into the dict)
        self._ops_kw = dict(ops_kw)
        self.namenodes = [Namenode(store, i, self.election, **ops_kw)
                          for i in range(n_namenodes)]
        for nn in self.namenodes:
            self.election.heartbeat(nn.nn_id)

    def tick(self) -> None:
        """One heartbeat round: alive namenodes prove liveness."""
        self.election.tick()
        for nn in self.namenodes:
            if nn.alive:
                self.election.heartbeat(nn.nn_id)
        if self.auto_lease_recovery:
            self.recover_leases()

    def recover_leases(self) -> int:
        """Run the leader's lease-recovery housekeeping once."""
        ldr = self.leader()
        return ldr.recover_leases() if ldr is not None else 0

    def scrub_leases(self) -> int:
        """Run the leader's orphaned-lease-path scrub once."""
        ldr = self.leader()
        return ldr.scrub_leases() if ldr is not None else 0

    def kill(self, nn_id: int) -> None:
        self.namenodes[nn_id].alive = False

    def restart(self, nn_id: int) -> None:
        self.namenodes[nn_id].alive = True
        self.election.heartbeat(nn_id)

    # -- elastic membership (the ElasticNamenodePool's substrate) -------
    def add_namenode(self, **ops_kw) -> Namenode:
        """Scale-out: append a fresh stateless namenode (ids are list
        indices, so new members always take ``len(namenodes)``), register
        it with the election, and — if a chaos injector is attached to the
        fleet — extend the injector to it (faults must be able to strike
        late joiners too). The caller (the pool) pre-warms its hint cache
        BEFORE the next batch is dealt, so it never serves cold."""
        kw = dict(self._ops_kw)
        kw.update(ops_kw)
        nn = Namenode(self.store, len(self.namenodes), self.election, **kw)
        donor = next((m for m in self.namenodes if m.chaos is not None),
                     None)
        if donor is not None:
            nn.chaos = donor.chaos
            nn.subtree.chaos = donor.subtree.chaos
        self.namenodes.append(nn)
        self.election.heartbeat(nn.nn_id)
        return nn

    def retire(self, nn_id: int) -> None:
        """Scale-in: stop serving AND leave the election immediately
        (``LeaderElection.remove`` deletes the heartbeat row, so the
        leader role moves this tick instead of after the staleness bound —
        a retirement is planned, unlike a crash). The slot stays in
        ``namenodes`` (ids are indices); ``alive_namenodes`` excludes it."""
        self.namenodes[nn_id].alive = False
        self.election.remove(nn_id)

    def alive_namenodes(self) -> List[Namenode]:
        return [nn for nn in self.namenodes if nn.alive]

    def leader(self) -> Optional[Namenode]:
        lid = self.election.leader()
        return self.namenodes[lid] if lid is not None else None


class Client:
    """HopsFS client with namenode selection policies (§3) and transparent
    retry on namenode failure (§7.6.1) or subtree-lock conflicts (§6.3) —
    both implemented by the shared :mod:`~repro.core.middleware` stack the
    ``DFSClient`` facade uses."""

    def __init__(self, cluster: NamenodeCluster, policy: str = "sticky",
                 seed: int = 0, board: Any = None):
        assert policy in ("random", "round_robin", "sticky")
        self.cluster = cluster
        self.policy = policy
        self.rng = random.Random(seed)
        self._rr = self.rng.randrange(1 << 16)
        self._sticky: Optional[int] = None
        self.retries = 0
        #: optional admission.BreakerBoard — selection avoids namenodes
        #: whose circuit breaker is open (unless every breaker is open,
        #: in which case routing proceeds and the breakers re-probe)
        self.board = board

        def _on_failover(ctx: CallContext) -> None:
            self._sticky = None

        self._middleware = [failover(on_failover=_on_failover),
                            subtree_retry(backoff=0.0)]

    def _pick(self) -> Namenode:
        alive = self.cluster.alive_namenodes()
        if not alive:
            raise StoreError("no alive namenodes")
        if self.board is not None:
            # breaker-aware: don't route at a tripped namenode; if the
            # whole fleet tripped, fall through (half-open probes heal)
            routable = [nn for nn in alive
                        if self.board.routable(nn.nn_id)]
            alive = routable or alive
        if self.policy == "random":
            return self.rng.choice(alive)
        if self.policy == "round_robin":
            nn = alive[self._rr % len(alive)]
            self._rr += 1
            return nn
        # sticky: stay with one namenode (better hint-cache locality §5.1.1)
        if self._sticky is not None and not any(
                nn.nn_id == self._sticky for nn in alive):
            self._sticky = None          # dead OR breaker-open: re-pick
        if self._sticky is None:
            self._sticky = self.rng.choice(alive).nn_id
        return self.cluster.namenodes[self._sticky]

    def execute(self, op: str, *args, **kw) -> OpResult:
        def terminal(ctx: CallContext) -> OpResult:
            nn = self._pick()
            ctx.namenode = nn
            ctx.attempts += 1
            return nn.perform(op, *args, **kw)

        ctx = CallContext(op=op)
        try:
            return compose(self._middleware, terminal)(ctx)
        finally:
            self.retries += ctx.retries


# ---------------------------------------------------------------------------
# batched multi-namenode request pipeline
# ---------------------------------------------------------------------------


@dataclass
class PipelineStats:
    """Result of one :class:`RequestPipeline` run. ``per_nn_cost`` is each
    namenode's committed-transaction cost during this run; the pipeline
    conserves accounting: merging ``per_nn_cost`` over namenodes equals
    ``total_cost`` equals the merge of every successful outcome's cost."""
    outcomes: List[OpOutcome]
    per_nn_cost: Dict[int, OpCost]
    per_nn_ops: Dict[int, int]
    total_cost: OpCost
    ok: int
    failed: int
    wall_s: float
    batch_size: int
    n_batches: int
    batched_read_ops: int = 0     # read-only ops served by grouped txns
    batched_write_ops: int = 0    # mutations served by grouped txns

    @property
    def throughput(self) -> float:
        return self.ok / self.wall_s if self.wall_s else 0.0

    @property
    def batched_fraction(self) -> float:
        if not self.outcomes:
            return 0.0
        return sum(1 for o in self.outcomes if o.batched) / len(self.outcomes)

    @property
    def batched_read_fraction(self) -> float:
        """Share of ops served by a grouped READ transaction."""
        return self.batched_read_ops / len(self.outcomes) \
            if self.outcomes else 0.0

    @property
    def batched_write_fraction(self) -> float:
        """Share of ops served by a grouped WRITE transaction — zero before
        the grouped write path existed, so batched_fraction strictly above
        batched_read_fraction is the write path engaging."""
        return self.batched_write_ops / len(self.outcomes) \
            if self.outcomes else 0.0

    @property
    def local_rt_fraction(self) -> float:
        """Share of DB round trips answered by the transaction
        coordinator's own node group (DAT effectiveness, §7.7)."""
        loc = self.total_cost.local_rt
        tot = loc + self.total_cost.remote_rt
        return loc / tot if tot else 0.0


class RequestPipeline:
    """Shared client queue feeding a fleet of namenodes in fixed batches.

    ``concurrent=False`` drains the queue round-robin on the calling thread
    — fully deterministic (ops execute in submission order regardless of
    namenode count or batch size), which is what the state-equivalence
    tests rely on. ``concurrent=True`` runs one worker thread per alive
    namenode against the same queue, exercising real row-lock contention
    on the shared store.

    ``hint_routing=True`` (the elastic-fleet mode) replaces blind
    round-robin dealing with hint-aware routing: a batch goes to the
    namenode whose inode hint cache already resolves its first op's path
    (side-effect-free peeks), falling back to round-robin when nobody is
    warm. On a static fleet the partition hash already gives stable
    affinity, so this stays off by default — it matters when membership
    changes mid-run and the warm cache IS the routing signal."""

    def __init__(self, cluster: NamenodeCluster, *, batch_size: int = 16,
                 concurrent: bool = False, hint_routing: bool = False):
        self.cluster = cluster
        self.batch_size = max(1, batch_size)
        self.concurrent = concurrent
        self.hint_routing = hint_routing

    @staticmethod
    def _warm_namenode(path: str, alive: Sequence[Namenode]
                       ) -> Optional[Namenode]:
        """First alive namenode whose hint cache resolves ``path``'s full
        component chain — pure peeks, so routing probes never skew any
        namenode's own cache statistics."""
        comps = split_path(path)
        if not comps:
            return None
        for nn in alive:
            cache = nn.ops.cache
            if cache is None:
                continue
            parent: Optional[int] = ROOT_ID
            for name in comps:
                parent = cache.peek(parent, name)
                if parent is None:
                    break
            if parent is not None:
                return nn
        return None

    def run(self, wops: Sequence[WorkloadOp]) -> PipelineStats:
        wops = list(wops)
        outcomes: List[Optional[OpOutcome]] = [None] * len(wops)
        q: deque = deque(range(len(wops)))
        qlock = threading.Lock()
        n_batches = [0]
        alive = self.cluster.alive_namenodes()
        if not alive:
            raise StoreError("no alive namenodes")
        cost0 = {nn.nn_id: nn.agg_cost.copy()
                 for nn in self.cluster.namenodes}
        served0 = {nn.nn_id: nn.ops_served for nn in self.cluster.namenodes}

        def pull() -> List[int]:
            with qlock:
                k = min(self.batch_size, len(q))
                return [q.popleft() for _ in range(k)]

        def requeue(idxs: List[int]) -> None:
            with qlock:
                q.extendleft(reversed(idxs))

        def run_one(nn: Namenode, idxs: List[int]) -> bool:
            """One batch on one namenode; False if the NN died mid-run (the
            batch is requeued for the survivors — §7.6.1 failover)."""
            try:
                res = nn.execute_batch([wops[i] for i in idxs])
            except StoreError:
                requeue(idxs)
                return False
            retry: List[int] = []
            for i, oc in zip(idxs, res):
                if not oc.ok and oc.error == "StoreError" and not nn.alive:
                    # op was in flight when this NN died: fail over (§7.6.1)
                    retry.append(i)
                else:
                    outcomes[i] = oc
            if retry:
                requeue(retry)
            with qlock:
                n_batches[0] += 1
            return not retry

        def drain(nn: Namenode) -> None:
            while True:
                idxs = pull()
                if not idxs:
                    return
                if not run_one(nn, idxs):
                    return

        t0 = time.perf_counter()
        if self.concurrent:
            # re-drain with the survivors if a dying namenode requeued its
            # batch after the other workers already saw an empty queue
            while True:
                live = self.cluster.alive_namenodes()
                with qlock:
                    pending = bool(q)
                if not pending or not live:
                    break
                workers = [threading.Thread(target=drain, args=(nn,))
                           for nn in live]
                for w in workers:
                    w.start()
                for w in workers:
                    w.join()
        else:
            rr = 0
            while q:
                alive = self.cluster.alive_namenodes()
                if not alive:
                    break
                idxs = pull()
                nn = alive[rr % len(alive)]
                rr += 1
                if self.hint_routing and idxs and len(alive) > 1:
                    warm = self._warm_namenode(wops[idxs[0]].path, alive)
                    if warm is not None:
                        nn = warm
                run_one(nn, idxs)
        wall = time.perf_counter() - t0
        # ops left without an outcome (every namenode died mid-run) fail
        # the way a client with no namenodes to fail over to would
        for i, oc in enumerate(outcomes):
            if oc is None:
                outcomes[i] = OpOutcome(None, "StoreError")
        return self._finalize_stats(wops, outcomes, cost0, served0, wall,
                                    n_batches[0])

    def _finalize_stats(self, wops: Sequence[WorkloadOp],
                        outcomes: Sequence[Optional[OpOutcome]],
                        cost0: Dict[int, OpCost], served0: Dict[int, int],
                        wall: float, n_batches: int) -> PipelineStats:
        """Conserved-accounting roll-up shared by the reactive and planned
        pipelines: per-namenode cost deltas, total cost over successful
        outcomes, and the batched read/write op split."""
        # namenodes absent from the snapshots joined mid-run (elastic
        # scale-out): their whole lifetime cost belongs to this run
        per_nn_cost = {nn.nn_id: nn.agg_cost.diff(cost0.get(nn.nn_id,
                                                            OpCost()))
                       for nn in self.cluster.namenodes}
        per_nn_ops = {nn.nn_id: nn.ops_served - served0.get(nn.nn_id, 0)
                      for nn in self.cluster.namenodes}
        total = OpCost()
        ok = failed = 0
        for oc in outcomes:
            if oc.ok:
                ok += 1
                total.merge(oc.result.cost)  # type: ignore[union-attr]
            else:
                failed += 1
        b_reads = b_writes = 0
        for wop, oc in zip(wops, outcomes):
            # only SERVED ops count toward the read/write batched split,
            # matching the per-namenode batched_ops/batched_write_ops
            # counters (a grouped op that errored is not "served by" the
            # grouped transaction)
            if oc is not None and oc.batched and oc.ok:
                s = REGISTRY.get(wop.op)
                if s is not None and s.read_only:
                    b_reads += 1
                else:
                    b_writes += 1
        return PipelineStats(outcomes=list(outcomes),  # type: ignore
                             per_nn_cost=per_nn_cost, per_nn_ops=per_nn_ops,
                             total_cost=total, ok=ok, failed=failed,
                             wall_s=wall, batch_size=self.batch_size,
                             n_batches=n_batches,
                             batched_read_ops=b_reads,
                             batched_write_ops=b_writes)


def namespace_snapshot(store: MetadataStore) -> Dict[str, Tuple]:
    """Logical namespace view: full path -> (is_dir, size, perm, owner,
    repl, n_blocks). Physical identifiers (inode/block ids, per-namenode
    mtime clocks) are deliberately absent, so two runs that dispatched ops
    to different namenodes — and therefore drew from different id-allocator
    blocks — can still be compared for namespace equivalence."""
    rows: Dict[int, Dict[str, Any]] = {}
    for part in store.table("inode").parts:
        for row in part.values():
            rows[row["id"]] = row
    blocks_per_inode: Dict[int, int] = {}
    for part in store.table("block").parts:
        for row in part.values():
            blocks_per_inode[row["inode_id"]] = \
                blocks_per_inode.get(row["inode_id"], 0) + 1

    paths: Dict[int, str] = {ROOT_ID: ""}

    def path_of(iid: int) -> Optional[str]:
        # iterative ancestor walk: deep namespaces (depth >> 1000) would
        # blow Python's recursion limit with the naive recursive form
        chain: List[Tuple[int, Dict[str, Any]]] = []
        seen: Set[int] = set()
        cur = iid
        while cur not in paths:
            row = rows.get(cur)
            if row is None or cur in seen:    # orphan or corrupt cycle
                return None
            seen.add(cur)
            chain.append((cur, row))
            cur = row["parent_id"]
        p = paths[cur]
        for cid, row in reversed(chain):
            p = p + "/" + row["name"]
            paths[cid] = p
        return p

    snap: Dict[str, Tuple] = {}
    for iid, row in rows.items():
        if iid == ROOT_ID:
            continue
        p = path_of(iid)
        if p is None:
            continue
        snap[p] = (row["is_dir"], row["size"], row["perm"], row["owner"],
                   row["repl"], blocks_per_inode.get(iid, 0))
    return snap


def materialize_namespace(nn: Namenode, ns) -> int:
    """Ensure a :class:`~repro.core.workload.SyntheticNamespace`'s dirs and
    files exist in the live store so trace replay targets resolve.
    Idempotent; returns the number of namespace paths ensured present."""
    for d in ns.dirs:
        try:
            nn.ops.mkdirs(d)
        except FSError:
            pass
    for f in ns.files:
        try:
            nn.ops.create(f)
        except FSError:
            pass
    return len(ns.dirs) + len(ns.files)


def materialize_big_dir(nn: Namenode, path: str, n_children: int, *,
                        file_prefix: str = "f") -> int:
    """Bulk-load a flat directory of ``n_children`` file inodes (the
    million-entry-directory scenario's fixture).

    Test/bench scaffolding, not a modeled op: the directory itself is
    created through the normal op path, but children are direct table
    puts — no transactions, no mtime ticks — so loading the same plan
    into two stores leaves them byte-identical.  Ids still come from the
    namenode's allocator, keeping ``id_seq`` consistent for follow-on
    ops.  Returns the directory's inode id."""
    from .tables import make_inode
    nn.ops.mkdirs(path)
    t = nn.store.table("inode")
    parent = ROOT_ID
    for name in split_path(path):
        parent = t.get((parent, name))["id"]
    for i in range(n_children):
        iid = nn.ops.inode_ids.next_id()
        t.put(make_inode(iid, parent, f"{file_prefix}{i:06d}", False))
    return parent
