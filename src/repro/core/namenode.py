"""Stateless namenodes + client policies + the batched request pipeline.

A :class:`Namenode` is stateless apart from its inode hint cache: all
authoritative state lives in the :class:`~repro.core.store.MetadataStore`.
Any number of namenodes serve the same store concurrently; clients pick one
per-op via *random*, *round-robin* or *sticky* policies and transparently
fail over to another namenode when one dies (§7.6.1 — this is why HopsFS has
no failover downtime).

Batched request pipeline (paper §2.2/§7.2): the throughput headline comes
from many namenodes issuing *batched, distribution-aware* transactions.
:class:`RequestPipeline` feeds N namenodes from one shared client queue in
fixed-size batches; :meth:`Namenode.execute_batch` groups consecutive
same-type read ops whose paths fully hit the hint cache, hashes every
hinted inode id to its partition in one vectorized ``phash`` kernel call
(§4.2), and validates each same-partition group's paths with ONE batched
PK exchange instead of 2-3 round trips per op. Mutating ops and cache
misses fall back to the sequential path, preserving exact sequential
semantics (asserted by tests/test_batched_pipeline.py).
"""
from __future__ import annotations

import random
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .fs import (FSError, HopsFSOps, OpResult, SubtreeLockedError,
                 split_path)
from .leader import LeaderElection
from .middleware import CallContext, compose, failover, subtree_retry
from .ops_registry import REGISTRY, WorkloadOp
from .store import (MetadataStore, OpCost, READ_COMMITTED, SHARED,
                    StoreError, _hash_key)
from .subtree import SubtreeOps
from .tables import ROOT_ID
from .transactions import Transaction

# read-only op types the batched executor may group (no mutation => any
# ordering within a run of them is equivalent to sequential execution).
# Derived from the op registry — the registry's `batchable` flag is the
# single source of truth; this name survives for importers as an
# import-time snapshot (live code paths consult REGISTRY directly, so ops
# registered later batch too).
BATCHABLE_READ_OPS = REGISTRY.batchable_ops()

_phash_usable = True

# Below this many keys the scalar hash beats an interpret-mode Pallas call
# (kernel dispatch overhead dominates); on accelerator-backed deployments
# the vectorized path wins for the bulk workloads (block reports, import
# manifests) that hash thousands of keys at once.
PHASH_MIN_BATCH = 512


def _partitions_for(ids: Sequence[int], n_partitions: int, *,
                    min_batch: int = PHASH_MIN_BATCH) -> List[int]:
    """Batch path->partition hashing: the phash Pallas kernel for large
    batches, the scalar store hash below ``min_batch`` (or if the kernel
    stack is unavailable). Both implement the identical mix, so placement
    always agrees with ``MetadataStore`` partitioning."""
    global _phash_usable
    if _phash_usable and len(ids) >= max(2, min_batch):
        try:
            from ..kernels.phash.ops import phash_partitions
            return [int(p) for p in phash_partitions(ids, n_partitions)]
        except Exception:
            _phash_usable = False
    return [_hash_key(i) % n_partitions for i in ids]


@dataclass
class OpOutcome:
    """Per-op outcome from the batched pipeline: either a result or the
    name of the FS error that sequential execution would have raised."""
    result: Optional[OpResult]
    error: Optional[str] = None
    batched: bool = False

    @property
    def ok(self) -> bool:
        return self.result is not None


class Namenode:
    def __init__(self, store: MetadataStore, nn_id: int,
                 election: LeaderElection, **ops_kw):
        self.nn_id = nn_id
        self.election = election
        self.ops = HopsFSOps(store, nn_id,
                             is_nn_alive=election.is_alive, **ops_kw)
        self.subtree = SubtreeOps(self.ops)
        self.alive = True
        self.ops_served = 0
        self.agg_cost = OpCost()     # committed-txn cost served by this NN
        self.batches_executed = 0
        self.batched_ops = 0
        # prebuilt default retry chain — the batch hot path must not
        # recompose middleware per op
        self._safe_handler = compose([subtree_retry()],
                                     lambda ctx: self.invoke(ctx.wop))

    def is_leader(self) -> bool:
        return self.election.leader() == self.nn_id

    # -- registry-dispatched execution ---------------------------------
    def perform(self, op: str, *args, **kw) -> OpResult:
        """Execute one op by registry name with explicit arguments — the
        canonical positional entry point (DFSClient and Client use it)."""
        if not self.alive:
            raise StoreError(f"namenode {self.nn_id} is down")
        res = REGISTRY[op].resolve(self)(*args, **kw)
        self.ops_served += 1
        self.agg_cost.merge(res.cost)
        return res

    def invoke(self, wop: WorkloadOp) -> OpResult:
        """Execute one :class:`WorkloadOp` record: the record's own
        ``args`` overlaid on the :class:`~.ops_registry.OpSpec` defaults,
        so workload-supplied arguments (perm, owner, repl, ...) flow
        end-to-end instead of being hardcoded here."""
        if not self.alive:
            raise StoreError(f"namenode {self.nn_id} is down")
        spec = REGISTRY[wop.op]
        paths, kw = spec.call_args(wop)
        res = spec.resolve(self)(*paths, **kw)
        self.ops_served += 1
        self.agg_cost.merge(res.cost)
        return res

    # -- deprecated string-dispatch shims ------------------------------
    def execute(self, op: str, *args, **kw) -> OpResult:
        """Deprecated: use :meth:`perform` (or the ``DFSClient`` facade)."""
        warnings.warn("Namenode.execute(op, ...) is deprecated; use "
                      "Namenode.perform or the DFSClient facade",
                      DeprecationWarning, stacklevel=2)
        return self.perform(op, *args, **kw)

    def execute_wop(self, wop: WorkloadOp) -> OpResult:
        """Deprecated: use :meth:`invoke`."""
        warnings.warn("Namenode.execute_wop(wop) is deprecated; use "
                      "Namenode.invoke", DeprecationWarning, stacklevel=2)
        return self.invoke(wop)

    # ------------------------------------------------------------------
    # batched execution (pipeline hot path)
    # ------------------------------------------------------------------
    def _safe_exec(self, wop: WorkloadOp, *, retries: int = 8,
                   backoff: float = 0.002) -> OpOutcome:
        """Execute one op, mapping FS errors to outcomes. Ops that hit a
        live subtree lock voluntarily aborted (§6.3) — retried with backoff
        by the shared ``subtree_retry`` middleware, exactly as the HopsFS
        client does, instead of failing."""
        if (retries, backoff) == (8, 0.002):
            handler = self._safe_handler      # hot path: prebuilt chain
        else:
            handler = compose(
                [subtree_retry(retries=retries, backoff=backoff)],
                lambda ctx: self.invoke(ctx.wop))
        try:
            return OpOutcome(handler(CallContext(op=wop.op, wop=wop,
                                                 namenode=self)))
        except StoreError as e:      # includes surfaced SubtreeLockedError
            return OpOutcome(None, type(e).__name__)

    def execute_batch(self, wops: Sequence[WorkloadOp]) -> List[OpOutcome]:
        """Execute a pulled batch. Maximal runs of consecutive same-type
        batchable read ops are executed through the grouped path (batched
        PK validation per partition group); everything else runs through
        the exact sequential path, in order. Because only read-only ops are
        reordered *within* a run, the store ends in the same state as
        strictly sequential execution of the batch."""
        if not self.alive:
            raise StoreError(f"namenode {self.nn_id} is down")
        results: List[Optional[OpOutcome]] = [None] * len(wops)
        i = 0
        while i < len(wops):
            op = wops[i].op
            j = i + 1
            spec = REGISTRY.get(op)
            if spec is not None and spec.batchable:   # live registry check
                while j < len(wops) and wops[j].op == op:
                    j += 1
                if j - i > 1:
                    self._execute_read_run(op, wops, i, j, results)
                else:
                    results[i] = self._safe_exec(wops[i])
            else:
                results[i] = self._safe_exec(wops[i])
            i = j
        self.batches_executed += 1
        return results  # type: ignore[return-value]

    def _execute_read_run(self, op: str, wops: Sequence[WorkloadOp],
                          lo: int, hi: int,
                          results: List[Optional[OpOutcome]]) -> None:
        """A run of same-type read ops: ops whose full path chain hits the
        hint cache are grouped by target partition (vectorized phash over
        the hinted inode ids) and executed one shared transaction per
        partition group; cache misses fall back to the sequential path."""
        cache = self.ops.cache
        hits: List[Tuple[int, List[str], List[Tuple[int, str]], int]] = []
        for idx in range(lo, hi):
            comps = split_path(wops[idx].path)
            resolved = (cache.resolve_pks_and_id(comps)
                        if (cache is not None and comps) else None)
            if resolved is None:
                results[idx] = self._safe_exec(wops[idx])
            else:
                pks, tid = resolved
                hits.append((idx, comps, pks, tid))
        if not hits:
            return
        parts = _partitions_for([h[3] for h in hits],
                                self.ops.store.n_partitions)
        groups: Dict[int, List[Tuple[int, List[str],
                                     List[Tuple[int, str]], int]]] = {}
        for h, p in zip(hits, parts):
            groups.setdefault(p, []).append(h)
        for _, group in sorted(groups.items()):
            self._read_group_txn(op, wops, group, results)

    def _read_group_txn(self, op: str, wops: Sequence[WorkloadOp],
                        group: Sequence[Tuple[int, List[str],
                                              List[Tuple[int, str]], int]],
                        results: List[Optional[OpOutcome]]) -> None:
        """One shared distribution-aware transaction for a same-partition
        group: ONE batched exchange validates every op's ancestor chain,
        lock-reads every target, and folds in the dependent lease reads;
        per-op file scans then run inside the same transaction. Stale hints
        are invalidated and the op re-runs sequentially (§5.1.1)."""
        fsops = self.ops
        spec = REGISTRY[op]
        fallback: List[int] = []
        try:
            txn = Transaction(fsops.store,
                              partition_hint=("inode", group[0][3]),
                              distribution_aware=fsops.dat)
        except StoreError:
            for idx, *_ in group:
                results[idx] = self._safe_exec(wops[idx])
            return
        try:
            per_op: Dict[int, Tuple[bool, List[Dict[str, Any]],
                                    Optional[Dict[str, Any]], int]] = {}
            with txn.batch() as b:
                for idx, comps, pks, _tid in group:
                    got: List[Dict[str, Any]] = []
                    ok = True
                    parent = ROOT_ID
                    for pk in pks[:-1]:
                        r = b.read("inode", pk, READ_COMMITTED)
                        if r is None or pk[0] != parent:
                            ok = False
                            break
                        got.append(r)
                        parent = r["id"]
                    target = None
                    if ok:
                        target = b.read("inode", (parent, comps[-1]), SHARED)
                        if target is not None and spec.lease_read:
                            # dependent lease read, same exchange (§5.1)
                            b.read("lease",
                                   (target.get("client") or "client",),
                                   READ_COMMITTED)
                    per_op[idx] = (ok, got, target, parent)
            op_costs: Dict[int, OpCost] = {}
            values: Dict[int, Any] = {}
            errors: Dict[int, str] = {}
            accounted = OpCost()
            for idx, comps, pks, _tid in group:
                ok, ancestors, target, parent_id = per_op[idx]
                if not ok or target is None:
                    # stale hints (rename/delete moved a row): repair + redo
                    if cachev := fsops.cache:
                        for pk in pks:
                            cachev.invalidate(*pk)
                    fallback.append(idx)
                    continue
                before = txn.cost.copy()
                try:
                    values[idx] = spec.batch_payload(fsops, txn, target)
                    for row in ancestors:
                        fsops._check_subtree_lock(row, txn)
                    fsops._check_subtree_lock(target, txn)
                    if fsops.cache:
                        # repair under the VALIDATED ids — a recreated
                        # ancestor keeps its composite PK but gets a new
                        # inode id, and the hinted ids may be stale
                        for pk, row in zip(pks, ancestors):
                            fsops.cache.put(pk[0], pk[1], row["id"])
                        fsops.cache.put(parent_id, comps[-1], target["id"])
                    op_costs[idx] = txn.cost.diff(before)
                    accounted.merge(op_costs[idx])
                except SubtreeLockedError:
                    # voluntary abort (§6.3): re-run sequentially w/ retry
                    values.pop(idx, None)
                    fallback.append(idx)
                except StoreError as e:
                    errors[idx] = type(e).__name__
                    values.pop(idx, None)
            total = txn.commit()
            # The shared validation batch, commit flush, and any reads done
            # for ops that errored/fell back are attributed to the FIRST
            # successful op, so Σ outcome costs == the cost aggregated per
            # namenode. (Like the sequential path, cost of a transaction
            # that served no op at all is dropped from the accounting.)
            unattributed = total.diff(accounted)
            served = OpCost()
            first_done = True
            for idx, *_ in group:
                if idx in values:
                    cost = op_costs[idx]
                    if first_done:
                        cost.merge(unattributed)
                        first_done = False
                    results[idx] = OpOutcome(
                        OpResult(values[idx], cost), batched=True)
                    served.merge(cost)
                    self.ops_served += 1
                    self.batched_ops += 1
                elif idx in errors:
                    results[idx] = OpOutcome(None, errors[idx],
                                             batched=True)
            self.agg_cost.merge(served)
        except StoreError:
            txn.abort()
            fallback = [idx for idx, *_ in group]
        for idx in fallback:
            results[idx] = self._safe_exec(wops[idx])


class NamenodeCluster:
    """A fleet of stateless namenodes over one store, plus the election."""

    def __init__(self, store: MetadataStore, n_namenodes: int, **ops_kw):
        self.store = store
        self.election = LeaderElection(store)
        self.namenodes = [Namenode(store, i, self.election, **ops_kw)
                          for i in range(n_namenodes)]
        for nn in self.namenodes:
            self.election.heartbeat(nn.nn_id)

    def tick(self) -> None:
        """One heartbeat round: alive namenodes prove liveness."""
        self.election.tick()
        for nn in self.namenodes:
            if nn.alive:
                self.election.heartbeat(nn.nn_id)

    def kill(self, nn_id: int) -> None:
        self.namenodes[nn_id].alive = False

    def restart(self, nn_id: int) -> None:
        self.namenodes[nn_id].alive = True
        self.election.heartbeat(nn_id)

    def alive_namenodes(self) -> List[Namenode]:
        return [nn for nn in self.namenodes if nn.alive]

    def leader(self) -> Optional[Namenode]:
        lid = self.election.leader()
        return self.namenodes[lid] if lid is not None else None


class Client:
    """HopsFS client with namenode selection policies (§3) and transparent
    retry on namenode failure (§7.6.1) or subtree-lock conflicts (§6.3) —
    both implemented by the shared :mod:`~repro.core.middleware` stack the
    ``DFSClient`` facade uses."""

    def __init__(self, cluster: NamenodeCluster, policy: str = "sticky",
                 seed: int = 0):
        assert policy in ("random", "round_robin", "sticky")
        self.cluster = cluster
        self.policy = policy
        self.rng = random.Random(seed)
        self._rr = self.rng.randrange(1 << 16)
        self._sticky: Optional[int] = None
        self.retries = 0

        def _on_failover(ctx: CallContext) -> None:
            self._sticky = None

        self._middleware = [failover(on_failover=_on_failover),
                            subtree_retry(backoff=0.0)]

    def _pick(self) -> Namenode:
        alive = self.cluster.alive_namenodes()
        if not alive:
            raise StoreError("no alive namenodes")
        if self.policy == "random":
            return self.rng.choice(alive)
        if self.policy == "round_robin":
            nn = alive[self._rr % len(alive)]
            self._rr += 1
            return nn
        # sticky: stay with one namenode (better hint-cache locality §5.1.1)
        if self._sticky is None or not self.cluster.namenodes[
                self._sticky].alive:
            self._sticky = self.rng.choice(alive).nn_id
        return self.cluster.namenodes[self._sticky]

    def execute(self, op: str, *args, **kw) -> OpResult:
        def terminal(ctx: CallContext) -> OpResult:
            nn = self._pick()
            ctx.namenode = nn
            ctx.attempts += 1
            return nn.perform(op, *args, **kw)

        ctx = CallContext(op=op)
        try:
            return compose(self._middleware, terminal)(ctx)
        finally:
            self.retries += ctx.retries


# ---------------------------------------------------------------------------
# batched multi-namenode request pipeline
# ---------------------------------------------------------------------------


@dataclass
class PipelineStats:
    """Result of one :class:`RequestPipeline` run. ``per_nn_cost`` is each
    namenode's committed-transaction cost during this run; the pipeline
    conserves accounting: merging ``per_nn_cost`` over namenodes equals
    ``total_cost`` equals the merge of every successful outcome's cost."""
    outcomes: List[OpOutcome]
    per_nn_cost: Dict[int, OpCost]
    per_nn_ops: Dict[int, int]
    total_cost: OpCost
    ok: int
    failed: int
    wall_s: float
    batch_size: int
    n_batches: int

    @property
    def throughput(self) -> float:
        return self.ok / self.wall_s if self.wall_s else 0.0

    @property
    def batched_fraction(self) -> float:
        if not self.outcomes:
            return 0.0
        return sum(1 for o in self.outcomes if o.batched) / len(self.outcomes)


class RequestPipeline:
    """Shared client queue feeding a fleet of namenodes in fixed batches.

    ``concurrent=False`` drains the queue round-robin on the calling thread
    — fully deterministic (ops execute in submission order regardless of
    namenode count or batch size), which is what the state-equivalence
    tests rely on. ``concurrent=True`` runs one worker thread per alive
    namenode against the same queue, exercising real row-lock contention
    on the shared store."""

    def __init__(self, cluster: NamenodeCluster, *, batch_size: int = 16,
                 concurrent: bool = False):
        self.cluster = cluster
        self.batch_size = max(1, batch_size)
        self.concurrent = concurrent

    def run(self, wops: Sequence[WorkloadOp]) -> PipelineStats:
        wops = list(wops)
        outcomes: List[Optional[OpOutcome]] = [None] * len(wops)
        q: deque = deque(range(len(wops)))
        qlock = threading.Lock()
        n_batches = [0]
        alive = self.cluster.alive_namenodes()
        if not alive:
            raise StoreError("no alive namenodes")
        cost0 = {nn.nn_id: nn.agg_cost.copy()
                 for nn in self.cluster.namenodes}
        served0 = {nn.nn_id: nn.ops_served for nn in self.cluster.namenodes}

        def pull() -> List[int]:
            with qlock:
                k = min(self.batch_size, len(q))
                return [q.popleft() for _ in range(k)]

        def requeue(idxs: List[int]) -> None:
            with qlock:
                q.extendleft(reversed(idxs))

        def run_one(nn: Namenode, idxs: List[int]) -> bool:
            """One batch on one namenode; False if the NN died mid-run (the
            batch is requeued for the survivors — §7.6.1 failover)."""
            try:
                res = nn.execute_batch([wops[i] for i in idxs])
            except StoreError:
                requeue(idxs)
                return False
            retry: List[int] = []
            for i, oc in zip(idxs, res):
                if not oc.ok and oc.error == "StoreError" and not nn.alive:
                    # op was in flight when this NN died: fail over (§7.6.1)
                    retry.append(i)
                else:
                    outcomes[i] = oc
            if retry:
                requeue(retry)
            with qlock:
                n_batches[0] += 1
            return not retry

        def drain(nn: Namenode) -> None:
            while True:
                idxs = pull()
                if not idxs:
                    return
                if not run_one(nn, idxs):
                    return

        t0 = time.perf_counter()
        if self.concurrent:
            # re-drain with the survivors if a dying namenode requeued its
            # batch after the other workers already saw an empty queue
            while True:
                live = self.cluster.alive_namenodes()
                with qlock:
                    pending = bool(q)
                if not pending or not live:
                    break
                workers = [threading.Thread(target=drain, args=(nn,))
                           for nn in live]
                for w in workers:
                    w.start()
                for w in workers:
                    w.join()
        else:
            rr = 0
            while q:
                alive = self.cluster.alive_namenodes()
                if not alive:
                    break
                nn = alive[rr % len(alive)]
                rr += 1
                idxs = pull()
                run_one(nn, idxs)
        wall = time.perf_counter() - t0
        # ops left without an outcome (every namenode died mid-run) fail
        # the way a client with no namenodes to fail over to would
        for i, oc in enumerate(outcomes):
            if oc is None:
                outcomes[i] = OpOutcome(None, "StoreError")

        per_nn_cost = {nn.nn_id: nn.agg_cost.diff(cost0[nn.nn_id])
                       for nn in self.cluster.namenodes}
        per_nn_ops = {nn.nn_id: nn.ops_served - served0[nn.nn_id]
                      for nn in self.cluster.namenodes}
        total = OpCost()
        ok = failed = 0
        for oc in outcomes:
            if oc.ok:
                ok += 1
                total.merge(oc.result.cost)  # type: ignore[union-attr]
            else:
                failed += 1
        return PipelineStats(outcomes=outcomes,  # type: ignore[arg-type]
                             per_nn_cost=per_nn_cost, per_nn_ops=per_nn_ops,
                             total_cost=total, ok=ok, failed=failed,
                             wall_s=wall, batch_size=self.batch_size,
                             n_batches=n_batches[0])


def namespace_snapshot(store: MetadataStore) -> Dict[str, Tuple]:
    """Logical namespace view: full path -> (is_dir, size, perm, owner,
    repl, n_blocks). Physical identifiers (inode/block ids, per-namenode
    mtime clocks) are deliberately absent, so two runs that dispatched ops
    to different namenodes — and therefore drew from different id-allocator
    blocks — can still be compared for namespace equivalence."""
    rows: Dict[int, Dict[str, Any]] = {}
    for part in store.table("inode").parts:
        for row in part.values():
            rows[row["id"]] = row
    blocks_per_inode: Dict[int, int] = {}
    for part in store.table("block").parts:
        for row in part.values():
            blocks_per_inode[row["inode_id"]] = \
                blocks_per_inode.get(row["inode_id"], 0) + 1

    paths: Dict[int, str] = {ROOT_ID: ""}

    def path_of(iid: int) -> Optional[str]:
        if iid in paths:
            return paths[iid]
        row = rows.get(iid)
        if row is None:
            return None
        parent = path_of(row["parent_id"])
        if parent is None:
            return None
        p = parent + "/" + row["name"]
        paths[iid] = p
        return p

    snap: Dict[str, Tuple] = {}
    for iid, row in rows.items():
        if iid == ROOT_ID:
            continue
        p = path_of(iid)
        if p is None:
            continue
        snap[p] = (row["is_dir"], row["size"], row["perm"], row["owner"],
                   row["repl"], blocks_per_inode.get(iid, 0))
    return snap


def materialize_namespace(nn: Namenode, ns) -> int:
    """Ensure a :class:`~repro.core.workload.SyntheticNamespace`'s dirs and
    files exist in the live store so trace replay targets resolve.
    Idempotent; returns the number of namespace paths ensured present."""
    for d in ns.dirs:
        try:
            nn.ops.mkdirs(d)
        except FSError:
            pass
    for f in ns.files:
        try:
            nn.ops.create(f)
        except FSError:
            pass
    return len(ns.dirs) + len(ns.files)
