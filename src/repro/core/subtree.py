"""Subtree operations protocol (paper §6).

Operations on directories of unknown (possibly millions) of inodes — delete,
move/rename, chmod, chown, set-quota — cannot lock millions of rows in one
OLTP transaction. HopsFS isolates the subtree with an **application-level
distributed lock** and then executes the operation as many small parallel
transactions:

  Phase 1 — take an exclusive row lock on the subtree root, verify *no other
            active subtree op* exists anywhere below (query of the
            ongoing-subtree-ops table), then set + persist the ``subtree_lock``
            flag (stamped with the owning namenode id). In-flight inode ops
            that encounter the flag voluntarily abort (§6.3).
  Phase 2 — quiesce: wave-by-wave down the tree, take-and-release write locks
            on every descendant in the same total order inode ops use, via
            parallel partition-pruned index scans (children of one directory
            live on one shard, §4.2); build the in-memory tree, reading only
            projections (inode ids) for efficiency.
  Phase 3 — execute: delete runs batched transactions **upward from the
            leaves (post-order)** so a namenode crash never orphans inodes
            (§6.2); rename/chmod/chown/quota mutate only the subtree root in
            a single small transaction, leaving inner inodes untouched.

Failure handling (§6.2): the flag holds the owner namenode's id; any other
namenode finding a flag owned by a dead namenode reclaims it. A delete that
died mid-way leaves a consistent (smaller) tree that the client retries on
another namenode.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .fs import (FSError, FileAlreadyExists, FileNotFound, HopsFSOps,
                 OpResult, SubtreeLockedError, split_path)
from .store import EXCLUSIVE, OpCost
from .transactions import Transaction


@dataclass
class TreeNode:
    inode_id: int
    parent_id: int
    name: str
    is_dir: bool
    children: List["TreeNode"] = field(default_factory=list)

    def count(self) -> int:
        return 1 + sum(c.count() for c in self.children)


class SubtreeOps:
    """Subtree operations for one namenode, layered over HopsFSOps."""

    def __init__(self, ops: HopsFSOps, *, batch_size: int = 1000,
                 parallelism: int = 8, crash_after_batches: Optional[int] = None):
        self.ops = ops
        self.store = ops.store
        self.batch_size = batch_size
        self.parallelism = parallelism
        # fault-injection hook: simulate the executing namenode dying after
        # N phase-3 batches (used by tests to verify §6.2 consistency)
        self.crash_after_batches = crash_after_batches
        #: generalized chaos hook (chaos.FaultInjector.install); fires the
        #: "subtree_chunk" site between phase-3 chunk commits
        self.chaos: Optional[Any] = None

    # ------------------------------------------------------------------
    # Phase 1: subtree lock
    # ------------------------------------------------------------------
    def _phase1_lock(self, path: str) -> Tuple[Dict[str, Any], OpCost]:
        comps = split_path(path)
        with self.ops._begin(self.ops._hint_for(comps, parent=False)) as txn:
            rp = self.ops._resolve(txn, comps, last_lock=EXCLUSIVE,
                                   path=path)
            root = rp.target
            if root is None:
                raise FileNotFound(path)
            if not root["is_dir"]:
                raise FSError(f"not a directory: {path}")
            # no active subtree operation anywhere below (or above) us:
            # the ongoing-subtree-ops table is small (subtree ops are a tiny
            # fraction of the workload) but the check is an all-shard IS.
            active = txn.full_scan("ongoing_subtree_ops", lambda r: True)
            for a in active:
                if self.ops._is_nn_alive(a["namenode_id"]):
                    if self._is_descendant_or_self(a["inode_id"], root["id"]) \
                            or self._is_descendant_or_self(root["id"],
                                                           a["inode_id"]):
                        raise SubtreeLockedError(
                            f"active subtree op on inode {a['inode_id']}")
                else:
                    txn.delete("ongoing_subtree_ops", (a["inode_id"],))
            locked = dict(root)
            locked["subtree_lock"] = self.ops.nn_id
            txn.write("inode", locked)
            txn.write("ongoing_subtree_ops",
                      {"inode_id": root["id"],
                       "namenode_id": self.ops.nn_id, "op": "subtree"})
            cost = txn.commit()
        return locked, cost

    def _is_descendant_or_self(self, node_id: int, ancestor_id: int) -> bool:
        t = self.store.table("inode")
        cur = node_id
        seen = 0
        while cur not in (0,) and seen < 10_000:
            if cur == ancestor_id:
                return True
            rows = t.scan_index("id", cur)
            if not rows:
                return False
            cur = rows[0]["parent_id"]
            seen += 1
        return False

    def _unlock(self, root: Dict[str, Any], cost: OpCost) -> None:
        with Transaction(self.store,
                         partition_hint=("inode", root["parent_id"]),
                         distribution_aware=self.ops.dat) as txn:
            cur = txn.read("inode", (root["parent_id"], root["name"]),
                           EXCLUSIVE)
            if cur is not None and cur.get("subtree_lock") == self.ops.nn_id:
                cur = dict(cur)
                cur["subtree_lock"] = None
                txn.write("inode", cur)
            txn.delete("ongoing_subtree_ops", (root["id"],))
            cost.merge(txn.commit())

    # ------------------------------------------------------------------
    # Phase 2: quiesce + build in-memory tree
    # ------------------------------------------------------------------
    def _phase2_build_tree(self, root: Dict[str, Any], cost: OpCost
                           ) -> TreeNode:
        """BFS down the tree; each directory's children are one
        partition-pruned scan (all children co-located, §4.2). Locks are
        taken-and-released per wave to wait out in-flight inode ops. A
        thread pool runs the per-directory scans of one level in parallel."""
        tree = TreeNode(root["id"], root["parent_id"], root["name"], True)
        frontier = [tree]
        while frontier:
            next_frontier: List[TreeNode] = []

            def scan_dir(node: TreeNode) -> List[TreeNode]:
                with Transaction(self.store,
                                 partition_hint=("inode", node.inode_id),
                                 distribution_aware=self.ops.dat) as txn:
                    # take-and-release write locks on the children wave
                    # (projection: ids only — §6.1 "reduce the overhead")
                    if self.ops.adp:
                        kids = txn.ppis("inode", "parent_id", node.inode_id,
                                        EXCLUSIVE,
                                        projection=("id", "parent_id",
                                                    "name", "is_dir"))
                    else:
                        kids = txn.index_scan("inode", "parent_id",
                                              node.inode_id, EXCLUSIVE)
                    cost.merge(txn.commit())
                return [TreeNode(k["id"], k["parent_id"], k["name"],
                                 k["is_dir"]) for k in kids]

            if len(frontier) > 1 and self.parallelism > 1:
                with ThreadPoolExecutor(self.parallelism) as pool:
                    results = list(pool.map(scan_dir, frontier))
            else:
                results = [scan_dir(n) for n in frontier]
            for node, kids in zip(frontier, results):
                node.children = kids
                next_frontier.extend(k for k in kids if k.is_dir)
            frontier = next_frontier
        return tree

    # ------------------------------------------------------------------
    # Phase 3 executors
    # ------------------------------------------------------------------
    def delete_subtree(self, path: str) -> OpResult:
        """Recursive delete, batched post-order (leaves first) so a crash
        leaves no orphans (§6.2). Returns #inodes deleted."""
        root, cost = self._phase1_lock(path)
        try:
            tree = self._phase2_build_tree(root, cost)
            order: List[TreeNode] = []

            def post(n: TreeNode) -> None:
                for c in n.children:
                    post(c)
                order.append(n)
            post(tree)

            deleted = 0
            batches = 0
            for i in range(0, len(order), self.batch_size):
                chunk = order[i:i + self.batch_size]
                if self.chaos is not None:
                    # chunk-commit boundary: a crash here leaves the
                    # subtree flag set and a consistent smaller tree
                    self.chaos.fire("subtree_chunk", self.ops.nn_id)
                if self.crash_after_batches is not None \
                        and batches >= self.crash_after_batches:
                    # simulated namenode crash: subtree lock flag remains,
                    # already-deleted leaves are gone, rest still attached.
                    return OpResult({"deleted": deleted, "crashed": True},
                                    cost)
                with Transaction(self.store,
                                 partition_hint=("inode",
                                                 chunk[0].parent_id),
                                 distribution_aware=self.ops.dat) as txn:
                    for n in chunk:
                        if not n.is_dir:
                            related = self.ops._file_scan(
                                txn, ("block", "replica", "ruc", "inv"),
                                n.inode_id, EXCLUSIVE)
                            for tname, rws in related.items():
                                schema = self.store.table(tname).schema
                                for r in rws:
                                    txn.delete(tname, tuple(
                                        r[c] for c in schema.pk))
                        txn.delete("inode", (n.parent_id, n.name))
                        if self.ops.cache:
                            self.ops.cache.invalidate(n.parent_id, n.name)
                        deleted += 1
                    cost.merge(txn.commit())
                batches += 1
            # root row is gone; update parent mtime + drop subtree-ops row
            with Transaction(self.store,
                             partition_hint=("inode", root["parent_id"]),
                             distribution_aware=self.ops.dat) as txn:
                txn.delete("ongoing_subtree_ops", (root["id"],))
                prow = self.store.table("inode").scan_index(
                    "id", root["parent_id"])
                if prow:
                    p = dict(prow[0])
                    p["mtime"] = next(self.ops.clock)
                    txn.write("inode", p)
                cost.merge(txn.commit())
            return OpResult({"deleted": deleted, "crashed": False}, cost)
        except Exception as e:
            if getattr(e, "chaos_crash", False):
                raise     # a crashed namenode cannot run cleanup: the
                          # subtree flag stays for a survivor to reclaim
            self._unlock(root, cost)
            raise

    def _root_only_op(self, path: str, mutate) -> OpResult:
        """chmod/chown/set-quota on a directory: phases 1-2 isolate and
        quiesce, phase 3 is a single small transaction updating only the
        subtree root (§6.2: inner inodes untouched => trivially
        failure-consistent)."""
        root, cost = self._phase1_lock(path)
        try:
            self._phase2_build_tree(root, cost)
            with Transaction(self.store,
                             partition_hint=("inode", root["parent_id"]),
                             distribution_aware=self.ops.dat) as txn:
                cur = txn.read("inode", (root["parent_id"], root["name"]),
                               EXCLUSIVE)
                if cur is None:
                    raise FileNotFound(path)
                cur = dict(cur)
                mutate(cur)
                cur["mtime"] = next(self.ops.clock)
                cur["subtree_lock"] = None
                txn.write("inode", cur)
                txn.delete("ongoing_subtree_ops", (root["id"],))
                cost.merge(txn.commit())
            return OpResult(None, cost)
        except Exception:
            self._unlock(root, cost)
            raise

    def chmod_subtree(self, path: str, perm: int) -> OpResult:
        return self._root_only_op(path, lambda n: n.update(perm=perm))

    def chown_subtree(self, path: str, owner: str) -> OpResult:
        return self._root_only_op(path, lambda n: n.update(owner=owner))

    def set_quota_subtree(self, path: str, *, ns_quota: int = -1,
                          ss_quota: int = -1) -> OpResult:
        def mut(n):
            pass
        root, cost = self._phase1_lock(path)
        try:
            self._phase2_build_tree(root, cost)
            with Transaction(self.store,
                             partition_hint=("inode", root["id"]),
                             distribution_aware=self.ops.dat) as txn:
                q = self.store.table("quota").get((root["id"],))
                qrow = dict(q) if q else {"inode_id": root["id"],
                                          "ns_used": 0, "ss_used": 0}
                qrow["ns_quota"], qrow["ss_quota"] = ns_quota, ss_quota
                txn.write("quota", qrow)
                cost.merge(txn.commit())
            self._unlock(root, cost)
            return OpResult(None, cost)
        except Exception:
            self._unlock(root, cost)
            raise

    def rename_subtree(self, src: str, dst: str) -> OpResult:
        """Directory move: phases 1-2, then a single phase-3 transaction
        that re-parents ONLY the subtree root (children keep their
        parent-id; their absolute paths change implicitly). The root's
        composite PK changes => delete+insert of one row."""
        root, cost = self._phase1_lock(src)
        try:
            self._phase2_build_tree(root, cost)
            dc = split_path(dst)
            with Transaction(self.store, partition_hint=(
                    "inode", self.ops._hint_for(dc, parent=True)),
                    distribution_aware=self.ops.dat) as txn:
                drp = self.ops._resolve(txn, dc, last_lock=EXCLUSIVE,
                                        lock_parent=True, path=dst)
                if drp.target is not None:
                    raise FileAlreadyExists(dst)
                cur = txn.read("inode", (root["parent_id"], root["name"]),
                               EXCLUSIVE)
                if cur is None:
                    raise FileNotFound(src)
                txn.delete("inode", (root["parent_id"], root["name"]))
                moved = dict(cur)
                moved["parent_id"], moved["name"] = drp.parent["id"], dc[-1]
                moved["mtime"] = next(self.ops.clock)
                moved["subtree_lock"] = None
                txn.write("inode", moved)
                dp = dict(drp.parent)
                dp["mtime"] = next(self.ops.clock)
                txn.write("inode", dp)
                txn.delete("ongoing_subtree_ops", (root["id"],))
                if self.ops.cache:
                    self.ops.cache.invalidate(root["parent_id"],
                                              root["name"])
                    self.ops.cache.put(drp.parent["id"], dc[-1], root["id"])
                cost.merge(txn.commit())
            return OpResult(None, cost)
        except Exception:
            self._unlock(root, cost)
            raise
