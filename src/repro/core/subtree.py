"""Subtree operations protocol (paper §6).

Operations on directories of unknown (possibly millions) of inodes — delete,
move/rename, chmod, chown, set-quota — cannot lock millions of rows in one
OLTP transaction. HopsFS isolates the subtree with an **application-level
distributed lock** and then executes the operation as many small parallel
transactions:

  Phase 1 — take an exclusive row lock on the subtree root, verify *no other
            active subtree op* exists anywhere below (query of the
            ongoing-subtree-ops table), then set + persist the ``subtree_lock``
            flag (stamped with the owning namenode id). In-flight inode ops
            that encounter the flag voluntarily abort (§6.3).
  Phase 2 — quiesce: wave-by-wave down the tree, take-and-release write locks
            on every descendant in the same total order inode ops use, via
            parallel partition-pruned index scans (children of one directory
            live on one shard, §4.2), reading only projections (inode ids)
            for efficiency.  The default **incremental** mode streams the
            waves — at most :attr:`SubtreeOps.wave_cap` directories are
            expanded per scan round and file rows are flushed to phase 3 as
            soon as a chunk fills, so memory stays bounded by one wave + one
            chunk instead of the whole subtree.  The legacy mode
            (``incremental=False``) still materializes the full
            :class:`TreeNode` tree for callers that want it.
  Phase 3 — execute: delete runs grouped chunk transactions **leaves first**
            so a namenode crash never orphans inodes (§6.2): files are
            deleted during the descent (they are always leaves), directories
            deepest level first afterwards, and the root row — the one
            carrying the subtree flag — commits last, alone.  Chunks whose
            anchor partitions differ commit in parallel ("many small
            parallel transactions"); a :attr:`SubtreeOps.pace` hook runs
            between chunk commits so adjacent inode ops interleave with a
            long-running subtree op.  Rename/chmod/chown/quota mutate only
            the subtree root in a single small transaction.

On the columnar store each BFS wave is additionally resolved by ONE fused
``kernels.treeagg`` launch over the struct-of-arrays inode columns.  The
launch is ADVISORY here — the transactional scans stay authoritative (and
charge identical :class:`OpCost` on both backends) — but it exercises and
cross-checks the exact kernel the ``du`` aggregation trusts.

Failure handling (§6.2): the flag holds the owner namenode's id; any other
namenode finding a flag owned by a dead namenode reclaims it. A delete that
died mid-way leaves a consistent (smaller) tree that the client retries on
another namenode.  Chunk boundaries are the crash points: every chunk is
all-or-nothing, and the leaves-first order means whatever committed before
the crash is a forest of complete deletions.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .fs import (FSError, FileAlreadyExists, FileNotFound, HopsFSOps,
                 OpResult, SubtreeLockedError, split_path)
from .store import EXCLUSIVE, OpCost
from .transactions import Transaction

#: phase-2/3 node record: (inode_id, parent_id, name, is_dir) — a plain
#: tuple, NOT a TreeNode, so the streaming path holds four machine words
#: per resident inode and nothing else
NodeRow = Tuple[int, int, str, bool]


@dataclass
class TreeNode:
    inode_id: int
    parent_id: int
    name: str
    is_dir: bool
    children: List["TreeNode"] = field(default_factory=list)

    def count(self) -> int:
        # iterative: million-entry trees must not hit the recursion limit
        n = 0
        stack = [self]
        while stack:
            node = stack.pop()
            n += 1
            stack.extend(node.children)
        return n


def _post_order(tree: TreeNode) -> List[TreeNode]:
    """Iterative post-order (children before parents), identical ordering
    to the old recursive ``post()`` but safe for depth >> the Python
    recursion limit."""
    order: List[TreeNode] = []
    stack: List[Tuple[TreeNode, bool]] = [(tree, False)]
    while stack:
        node, expanded = stack.pop()
        if expanded:
            order.append(node)
            continue
        stack.append((node, True))
        for c in reversed(node.children):
            stack.append((c, False))
    return order


class _BoundedWaitPool:
    """Persistent worker pool where every wait is bounded.

    Functionally ``ThreadPoolExecutor.map``, with two robustness twists:
    workers poll the task queue with short timeouts (a timed-out waiter
    re-checks shared state, so a single missed wakeup costs milliseconds
    instead of hanging the op), and the submitting thread work-steals
    from the same queue while it waits, so a ``map`` completes even if
    every worker is wedged or has idled out. Workers exit after a couple
    of idle seconds and are respawned on the next ``map``, keeping the
    steady-state thread count proportional to recent subtree activity.
    """

    _POLL = 0.02
    _IDLE_EXIT = 2.0

    def __init__(self, n_workers: int):
        self.n = max(1, n_workers)
        self._tasks: "queue.Queue[Callable[[], None]]" = queue.Queue()
        self._threads: List[threading.Thread] = []

    def _worker(self) -> None:
        idle = 0.0
        while idle < self._IDLE_EXIT:
            try:
                task = self._tasks.get(timeout=self._POLL)
            except queue.Empty:
                idle += self._POLL
                continue
            idle = 0.0
            task()

    def _ensure_workers(self, wanted: int) -> None:
        self._threads = [t for t in self._threads if t.is_alive()]
        while len(self._threads) < min(self.n, wanted):
            t = threading.Thread(target=self._worker, daemon=True)
            t.start()
            self._threads.append(t)

    def map(self, fn: Callable[[Any], Any], items: Sequence[Any]
            ) -> List[Any]:
        items = list(items)
        if len(items) <= 1 or self.n <= 1:
            return [fn(x) for x in items]
        # workers take items[1:]; the submitter always runs one itself
        self._ensure_workers(len(items) - 1)
        results: List[Any] = [None] * len(items)
        errors: List[BaseException] = []
        pending = [len(items)]
        lock = threading.Lock()

        def run_one(i: int, x: Any) -> Callable[[], None]:
            def task() -> None:
                try:
                    results[i] = fn(x)
                except BaseException as exc:   # noqa: BLE001 — re-raised
                    errors.append(exc)
                finally:
                    with lock:
                        pending[0] -= 1
            return task

        for i, x in enumerate(items[1:], start=1):
            self._tasks.put(run_one(i, x))
        run_one(0, items[0])()
        while True:
            with lock:
                if pending[0] == 0:
                    break
            try:
                task = self._tasks.get_nowait()
            except queue.Empty:
                time.sleep(self._POLL / 4)
            else:
                task()
        if errors:
            raise errors[0]
        return results


def _empty_stats() -> Dict[str, Any]:
    return {"waves": 0, "scanned": 0, "peak_frontier": 0, "chunks": 0,
            "chunk_costs": []}


class SubtreeOps:
    """Subtree operations for one namenode, layered over HopsFSOps."""

    def __init__(self, ops: HopsFSOps, *, batch_size: int = 1000,
                 parallelism: int = 8,
                 crash_after_batches: Optional[int] = None,
                 incremental: bool = True, wave_cap: int = 4096):
        self.ops = ops
        self.store = ops.store
        self.batch_size = batch_size
        self.parallelism = parallelism
        # fault-injection hook: simulate the executing namenode dying after
        # N phase-3 batches (used by tests to verify §6.2 consistency)
        self.crash_after_batches = crash_after_batches
        #: generalized chaos hook (chaos.FaultInjector.install); fires the
        #: "subtree_chunk" site between phase-3 chunk commits
        self.chaos: Optional[Any] = None
        #: streaming phase 2 (bounded waves, files flushed during descent);
        #: False = legacy full-tree materialization
        self.incremental = incremental
        #: max directories expanded per phase-2 scan round
        self.wave_cap = wave_cap
        #: called between phase-3 chunk commits — the pacing point where
        #: adjacent (non-subtree) inode ops interleave with a long delete.
        #: Setting it forces chunks sequential (the hook IS the schedule).
        self.pace: Optional[Callable[[], None]] = None
        #: telemetry for the most recent subtree op (reset per op)
        self.last_stats: Dict[str, Any] = _empty_stats()
        #: lifetime ``scan_index("id", ...)`` hops spent on ancestor walks
        #: (the phase-1 overlap check) — what the scaling suite bounds
        self.ancestor_scans = 0
        # treeagg kernel telemetry (advisory phase-2 launches)
        self.treeagg_launches = 0
        self.treeagg_demotions = 0
        # one persistent pool per namenode, shared by wave scans and
        # parallel chunk commits (never nested), sized lazily at first use
        self._executor: Optional[_BoundedWaitPool] = None
        self.treeagg_mismatches = 0

    def _reset_stats(self) -> None:
        self.last_stats = _empty_stats()

    # ------------------------------------------------------------------
    # Phase 1: subtree lock
    # ------------------------------------------------------------------
    def _phase1_lock(self, path: str) -> Tuple[Dict[str, Any], OpCost]:
        comps = split_path(path)
        with self.ops._begin(self.ops._hint_for(comps, parent=False)) as txn:
            rp = self.ops._resolve(txn, comps, last_lock=EXCLUSIVE,
                                   path=path)
            root = rp.target
            if root is None:
                raise FileNotFound(path)
            if not root["is_dir"]:
                raise FSError(f"not a directory: {path}")
            # no active subtree operation anywhere below (or above) us:
            # the ongoing-subtree-ops table is small (subtree ops are a tiny
            # fraction of the workload) but the check is an all-shard IS.
            active = txn.full_scan("ongoing_subtree_ops", lambda r: True)
            overlaps = None
            for a in active:
                if self.ops._is_nn_alive(a["namenode_id"]):
                    if overlaps is None:
                        overlaps = self._overlap_check(root["id"])
                    if overlaps(a["inode_id"]):
                        raise SubtreeLockedError(
                            f"active subtree op on inode {a['inode_id']}")
                else:
                    txn.delete("ongoing_subtree_ops", (a["inode_id"],))
            locked = dict(root)
            locked["subtree_lock"] = self.ops.nn_id
            txn.write("inode", locked)
            txn.write("ongoing_subtree_ops",
                      {"inode_id": root["id"],
                       "namenode_id": self.ops.nn_id, "op": "subtree"})
            cost = txn.commit()
        return locked, cost

    def _overlap_check(self, root_id: int) -> Callable[[int], bool]:
        """Factory for the phase-1 conflict test: two subtree ops conflict
        iff one root lies on the other's ancestor chain.

        The naive test walked the parent chain twice per active row —
        O(active x depth) ``scan_index`` hops, quadratic on deep trees.
        This form walks the target's own chain ONCE into an ancestor set,
        then memoizes each active root's walk (every visited node learns
        whether its chain reaches ``root_id``), so k active rows on a
        depth-d tree cost O(d + k + distinct hops) total."""
        t = self.store.table("inode")
        anc = {root_id}
        cur = root_id
        hops = 0
        while cur != 0 and hops < 10_000:
            rows = t.scan_index("id", cur)
            self.ancestor_scans += 1
            if not rows:
                break
            cur = rows[0]["parent_id"]
            anc.add(cur)
            hops += 1
        memo: Dict[int, bool] = {}

        def overlaps(a_id: int) -> bool:
            # a_id above (or at) the target root => the target is inside a
            if a_id in anc:
                return True
            trail: List[int] = []
            cur = a_id
            verdict = False
            hops = 0
            while hops < 10_000:
                if cur == root_id:
                    verdict = True
                    break
                if cur in memo:
                    verdict = memo[cur]
                    break
                if cur in anc or cur == 0:
                    # joined the target's chain ABOVE the root (or hit the
                    # fs root): disjoint subtrees
                    verdict = False
                    break
                trail.append(cur)
                rows = t.scan_index("id", cur)
                self.ancestor_scans += 1
                if not rows:
                    verdict = False
                    break
                cur = rows[0]["parent_id"]
                hops += 1
            for nid in trail:
                memo[nid] = verdict
            return verdict

        return overlaps

    def _is_descendant_or_self(self, node_id: int, ancestor_id: int) -> bool:
        t = self.store.table("inode")
        cur = node_id
        seen = 0
        while cur not in (0,) and seen < 10_000:
            if cur == ancestor_id:
                return True
            rows = t.scan_index("id", cur)
            self.ancestor_scans += 1
            if not rows:
                return False
            cur = rows[0]["parent_id"]
            seen += 1
        return False

    def _unlock(self, root: Dict[str, Any], cost: OpCost) -> None:
        with Transaction(self.store,
                         partition_hint=("inode", root["parent_id"]),
                         distribution_aware=self.ops.dat) as txn:
            cur = txn.read("inode", (root["parent_id"], root["name"]),
                           EXCLUSIVE)
            if cur is not None and cur.get("subtree_lock") == self.ops.nn_id:
                cur = dict(cur)
                cur["subtree_lock"] = None
                txn.write("inode", cur)
            txn.delete("ongoing_subtree_ops", (root["id"],))
            cost.merge(txn.commit())

    # ------------------------------------------------------------------
    # Phase 2: quiesce (streaming waves / legacy full tree)
    # ------------------------------------------------------------------
    def _fused_wave(self, dir_ids: Sequence[int]) -> Optional[Any]:
        """ADVISORY columnar fast path: resolve the whole wave in one
        ``kernels.treeagg`` launch over the SoA columns.  Charges zero
        OpCost — the transactional scans remain authoritative and
        cost-identical across backends — but exercises and cross-checks
        the exact kernel the ``du`` aggregation trusts.  None on the dict
        backend / below the slot-count gate."""
        try:
            from .columnar import expand_wave
        except Exception:                    # pragma: no cover - import guard
            return None
        try:
            exp = expand_wave(self.store, dir_ids)
        except Exception:                    # pragma: no cover - advisory
            return None
        if exp is None:
            return None
        if exp.used:
            self.treeagg_launches += 1
        else:
            self.treeagg_demotions += 1
        return exp

    def _pool(self) -> _BoundedWaitPool:
        """The namenode's long-lived scan/commit pool. Spinning a fresh
        pool per wave churns thread create/join on every subtree op; one
        persistent pool amortizes it across the namenode's life. Wave
        scans and chunk commits never nest, so sharing is safe."""
        if self._executor is None:
            self._executor = _BoundedWaitPool(self.parallelism)
        return self._executor

    def _wave_scan(self, dir_ids: Sequence[int], cost: OpCost
                   ) -> List[List[Dict[str, Any]]]:
        """Take-and-release EXCLUSIVE child scans for one wave of
        directories — one partition-pruned scan per directory (all
        children co-located, §4.2), a thread pool across directories.
        Returns the child-row lists aligned with ``dir_ids``."""
        exp = self._fused_wave(dir_ids)

        def scan_dir(did: int) -> List[Dict[str, Any]]:
            with Transaction(self.store, partition_hint=("inode", did),
                             distribution_aware=self.ops.dat) as txn:
                # take-and-release write locks on the children wave
                # (projection: ids only — §6.1 "reduce the overhead")
                if self.ops.adp:
                    kids = txn.ppis("inode", "parent_id", did, EXCLUSIVE,
                                    projection=("id", "parent_id", "name",
                                                "is_dir"))
                else:
                    kids = txn.index_scan("inode", "parent_id", did,
                                          EXCLUSIVE)
                cost.merge(txn.commit())
            return kids

        if len(dir_ids) > 1 and self.parallelism > 1:
            kid_lists = list(self._pool().map(scan_dir, dir_ids))
        else:
            kid_lists = [scan_dir(d) for d in dir_ids]
        if exp is not None \
                and exp.n_children != sum(len(k) for k in kid_lists):
            # concurrent mutation between launch and scans: scans win
            self.treeagg_mismatches += 1
        return kid_lists

    def _phase2_build_tree(self, root: Dict[str, Any], cost: OpCost
                           ) -> TreeNode:
        """Legacy quiesce: BFS down the tree materializing the whole
        :class:`TreeNode` tree in memory (O(subtree) resident)."""
        tree = TreeNode(root["id"], root["parent_id"], root["name"], True)
        frontier = [tree]
        st = self.last_stats
        while frontier:
            st["waves"] += 1
            kid_lists = self._wave_scan([n.inode_id for n in frontier], cost)
            next_frontier: List[TreeNode] = []
            for node, kids in zip(frontier, kid_lists):
                st["scanned"] += len(kids)
                node.children = [TreeNode(k["id"], k["parent_id"], k["name"],
                                          k["is_dir"]) for k in kids]
                next_frontier.extend(c for c in node.children if c.is_dir)
            frontier = next_frontier
        return tree

    def _phase2_quiesce(self, root: Dict[str, Any], cost: OpCost) -> int:
        """Streaming wave quiesce for root-only phase-3 ops: identical
        take-and-release lock waves to the tree build, but nothing is
        retained beyond the next frontier's directory ids (and each scan
        round expands at most ``wave_cap`` directories)."""
        st = self.last_stats
        wave = [root["id"]]
        total = 0
        while wave:
            st["waves"] += 1
            nxt: List[int] = []
            for s in range(0, len(wave), self.wave_cap):
                kid_lists = self._wave_scan(wave[s:s + self.wave_cap], cost)
                for kids in kid_lists:
                    st["scanned"] += len(kids)
                    total += len(kids)
                    nxt.extend(k["id"] for k in kids if k["is_dir"])
                resident = len(nxt) + (len(wave) - s)
                if resident > st["peak_frontier"]:
                    st["peak_frontier"] = resident
            wave = nxt
        return total

    def _phase2(self, root: Dict[str, Any], cost: OpCost) -> None:
        if self.incremental:
            self._phase2_quiesce(root, cost)
        else:
            self._phase2_build_tree(root, cost)

    # ------------------------------------------------------------------
    # Phase 3: grouped chunk commits
    # ------------------------------------------------------------------
    def _commit_chunk(self, chunk: Sequence[NodeRow]) -> OpCost:
        """One phase-3 grouped transaction: every inode in the chunk
        shares the txn (the ``Namenode._write_group_txn`` discipline),
        anchored on the first node's parent partition."""
        with Transaction(self.store,
                         partition_hint=("inode", chunk[0][1]),
                         distribution_aware=self.ops.dat) as txn:
            for iid, pid, name, is_dir in chunk:
                if not is_dir:
                    related = self.ops._file_scan(
                        txn, ("block", "replica", "ruc", "inv"),
                        iid, EXCLUSIVE)
                    for tname, rws in related.items():
                        schema = self.store.table(tname).schema
                        for r in rws:
                            txn.delete(tname,
                                       tuple(r[c] for c in schema.pk))
                txn.delete("inode", (pid, name))
                if self.ops.cache:
                    self.ops.cache.invalidate(pid, name)
            return txn.commit()

    def _exec_chunks(self, nodes: Sequence[NodeRow], cost: OpCost,
                     progress: Dict[str, int], *,
                     allow_parallel: bool = False) -> bool:
        """Flush ``nodes`` in ``batch_size`` chunks.  Chunks with distinct
        anchor partitions commit concurrently when ``allow_parallel`` (the
        caller guarantees the nodes are deletion-order-independent, e.g.
        all leaves); pacing, chaos and simulated crashes force the
        sequential path so their per-chunk semantics stay deterministic.
        Per-chunk costs are attributed into ``last_stats["chunk_costs"]``
        via OpCost diffs.  Returns True on a simulated crash."""
        if not nodes:
            return False
        bs = self.batch_size
        chunks = [nodes[i:i + bs] for i in range(0, len(nodes), bs)]
        st = self.last_stats
        seq = (not allow_parallel or self.pace is not None
               or self.chaos is not None
               or self.crash_after_batches is not None
               or self.parallelism <= 1)
        t = self.store.table("inode")
        i = 0
        while i < len(chunks):
            if seq:
                group = [chunks[i]]
                i += 1
            else:
                # partition-disjoint run: consecutive chunks whose anchor
                # partitions differ commit concurrently (§6 "many small
                # parallel transactions"); a repeat partition ends the run
                group = [chunks[i]]
                parts = {t.partition_of(chunks[i][0][1])}
                i += 1
                while i < len(chunks) and len(group) < self.parallelism:
                    p = t.partition_of(chunks[i][0][1])
                    if p in parts:
                        break
                    parts.add(p)
                    group.append(chunks[i])
                    i += 1
            if len(group) == 1:
                chunk = group[0]
                if self.chaos is not None:
                    # chunk-commit boundary: a crash here leaves the
                    # subtree flag set and a consistent smaller tree
                    self.chaos.fire("subtree_chunk", self.ops.nn_id)
                if self.crash_after_batches is not None \
                        and progress["batches"] >= self.crash_after_batches:
                    # simulated namenode crash: subtree lock flag remains,
                    # already-deleted leaves are gone, rest still attached.
                    return True
                before = cost.copy()
                cost.merge(self._commit_chunk(chunk))
                st["chunk_costs"].append(cost.diff(before).as_dict())
                progress["batches"] += 1
                progress["deleted"] += len(chunk)
                if self.pace is not None:
                    self.pace()
            else:
                ccosts = list(self._pool().map(self._commit_chunk, group))
                for chunk, cc in zip(group, ccosts):
                    cost.merge(cc)
                    st["chunk_costs"].append(cc.as_dict())
                    progress["batches"] += 1
                    progress["deleted"] += len(chunk)
        return False

    # ------------------------------------------------------------------
    # Phase 3 executors
    # ------------------------------------------------------------------
    def delete_subtree(self, path: str) -> OpResult:
        """Recursive delete, grouped chunk commits leaves-first so a crash
        leaves no orphans (§6.2). Returns #inodes deleted."""
        self._reset_stats()
        root, cost = self._phase1_lock(path)
        progress = {"deleted": 0, "batches": 0}
        try:
            if self.incremental:
                crashed = self._delete_streamed(root, cost, progress)
            else:
                crashed = self._delete_legacy(root, cost, progress)
            self.last_stats["chunks"] = progress["batches"]
            if crashed:
                return OpResult({"deleted": progress["deleted"],
                                 "crashed": True}, cost)
            # root row is gone; update parent mtime + drop subtree-ops row
            with Transaction(self.store,
                             partition_hint=("inode", root["parent_id"]),
                             distribution_aware=self.ops.dat) as txn:
                txn.delete("ongoing_subtree_ops", (root["id"],))
                prow = self.store.table("inode").scan_index(
                    "id", root["parent_id"])
                if prow:
                    p = dict(prow[0])
                    p["mtime"] = next(self.ops.clock)
                    txn.write("inode", p)
                cost.merge(txn.commit())
            return OpResult({"deleted": progress["deleted"],
                             "crashed": False}, cost)
        except Exception as e:
            if getattr(e, "chaos_crash", False):
                raise     # a crashed namenode cannot run cleanup: the
                          # subtree flag stays for a survivor to reclaim
            self._unlock(root, cost)
            raise

    def _delete_streamed(self, root: Dict[str, Any], cost: OpCost,
                         progress: Dict[str, int]) -> bool:
        """Incremental delete: files flush to chunk commits DURING the
        descent (files are always leaves, so every prefix of commits is a
        consistent smaller tree), directory rows are retained per level
        and deleted deepest level first, the root row last and alone."""
        st = self.last_stats
        rootnode: NodeRow = (root["id"], root["parent_id"], root["name"],
                             True)
        pending: List[NodeRow] = []
        dir_levels: List[List[NodeRow]] = []
        wave: List[NodeRow] = [rootnode]
        retained = 1
        while wave:
            st["waves"] += 1
            next_wave: List[NodeRow] = []
            for s in range(0, len(wave), self.wave_cap):
                sl = wave[s:s + self.wave_cap]
                kid_lists = self._wave_scan([n[0] for n in sl], cost)
                for kids in kid_lists:
                    st["scanned"] += len(kids)
                    resident = (retained + len(next_wave) + len(pending)
                                + len(kids))
                    if resident > st["peak_frontier"]:
                        st["peak_frontier"] = resident
                    for k in kids:
                        node: NodeRow = (k["id"], k["parent_id"], k["name"],
                                         k["is_dir"])
                        if node[3]:
                            next_wave.append(node)
                        else:
                            pending.append(node)
                    while len(pending) >= self.batch_size:
                        flush = pending[:self.batch_size]
                        pending = pending[self.batch_size:]
                        if self._exec_chunks(flush, cost, progress,
                                             allow_parallel=True):
                            return True
            if next_wave:
                dir_levels.append(next_wave)
                retained += len(next_wave)
            wave = next_wave
        if self._exec_chunks(pending, cost, progress, allow_parallel=True):
            return True
        for level in reversed(dir_levels):   # deepest dirs first (§6.2)
            if self._exec_chunks(level, cost, progress, allow_parallel=True):
                return True
        # the root row goes LAST, alone: its delete clears the subtree
        # flag, so nothing below it may still exist when it commits
        return self._exec_chunks([rootnode], cost, progress)

    def _delete_legacy(self, root: Dict[str, Any], cost: OpCost,
                       progress: Dict[str, int]) -> bool:
        """Legacy delete: full tree materialization + one sequential
        post-order chunk pass (the pre-incremental behaviour, kept as the
        differential oracle for the streamed path)."""
        tree = self._phase2_build_tree(root, cost)
        order = _post_order(tree)
        st = self.last_stats
        st["peak_frontier"] = max(st["peak_frontier"], len(order))
        nodes = [(n.inode_id, n.parent_id, n.name, n.is_dir) for n in order]
        return self._exec_chunks(nodes, cost, progress)

    def _root_only_op(self, path: str, mutate) -> OpResult:
        """chmod/chown on a directory: phases 1-2 isolate and quiesce,
        phase 3 is a single small transaction updating only the subtree
        root (§6.2: inner inodes untouched => trivially
        failure-consistent)."""
        self._reset_stats()
        root, cost = self._phase1_lock(path)
        try:
            self._phase2(root, cost)
            with Transaction(self.store,
                             partition_hint=("inode", root["parent_id"]),
                             distribution_aware=self.ops.dat) as txn:
                cur = txn.read("inode", (root["parent_id"], root["name"]),
                               EXCLUSIVE)
                if cur is None:
                    raise FileNotFound(path)
                cur = dict(cur)
                mutate(cur)
                cur["mtime"] = next(self.ops.clock)
                cur["subtree_lock"] = None
                txn.write("inode", cur)
                txn.delete("ongoing_subtree_ops", (root["id"],))
                cost.merge(txn.commit())
            return OpResult(None, cost)
        except Exception:
            self._unlock(root, cost)
            raise

    def chmod_subtree(self, path: str, perm: int) -> OpResult:
        return self._root_only_op(path, lambda n: n.update(perm=perm))

    def chown_subtree(self, path: str, owner: str) -> OpResult:
        return self._root_only_op(path, lambda n: n.update(owner=owner))

    def set_quota_subtree(self, path: str, *, ns_quota: int = -1,
                          ss_quota: int = -1) -> OpResult:
        self._reset_stats()
        root, cost = self._phase1_lock(path)
        try:
            self._phase2(root, cost)
            with Transaction(self.store,
                             partition_hint=("inode", root["id"]),
                             distribution_aware=self.ops.dat) as txn:
                q = self.store.table("quota").get((root["id"],))
                qrow = dict(q) if q else {"inode_id": root["id"],
                                          "ns_used": 0, "ss_used": 0}
                qrow["ns_quota"], qrow["ss_quota"] = ns_quota, ss_quota
                txn.write("quota", qrow)
                cost.merge(txn.commit())
            self._unlock(root, cost)
            return OpResult(None, cost)
        except Exception:
            self._unlock(root, cost)
            raise

    def rename_subtree(self, src: str, dst: str) -> OpResult:
        """Directory move: phases 1-2, then a single phase-3 transaction
        that re-parents ONLY the subtree root (children keep their
        parent-id; their absolute paths change implicitly). The root's
        composite PK changes => delete+insert of one row."""
        self._reset_stats()
        root, cost = self._phase1_lock(src)
        try:
            self._phase2(root, cost)
            dc = split_path(dst)
            with Transaction(self.store, partition_hint=(
                    "inode", self.ops._hint_for(dc, parent=True)),
                    distribution_aware=self.ops.dat) as txn:
                drp = self.ops._resolve(txn, dc, last_lock=EXCLUSIVE,
                                        lock_parent=True, path=dst)
                if drp.target is not None:
                    raise FileAlreadyExists(dst)
                # a directory must never move under its own subtree — the
                # re-parent would cut the tree into an unreachable parent
                # cycle that phase-2 scans of any ancestor then chase
                # forever
                if self._is_descendant_or_self(drp.parent["id"],
                                               root["id"]):
                    raise FSError(
                        f"cannot rename {src} under its own subtree "
                        f"({dst})")
                cur = txn.read("inode", (root["parent_id"], root["name"]),
                               EXCLUSIVE)
                if cur is None:
                    raise FileNotFound(src)
                txn.delete("inode", (root["parent_id"], root["name"]))
                moved = dict(cur)
                moved["parent_id"], moved["name"] = drp.parent["id"], dc[-1]
                moved["mtime"] = next(self.ops.clock)
                moved["subtree_lock"] = None
                txn.write("inode", moved)
                dp = dict(drp.parent)
                dp["mtime"] = next(self.ops.clock)
                txn.write("inode", dp)
                txn.delete("ongoing_subtree_ops", (root["id"],))
                if self.ops.cache:
                    self.ops.cache.invalidate(root["parent_id"],
                                              root["name"])
                    self.ops.cache.put(drp.parent["id"], dc[-1], root["id"])
                cost.merge(txn.commit())
            return OpResult(None, cost)
        except Exception:
            self._unlock(root, cost)
            raise
