"""Overload-hardened request path: deadlines, fair-queue admission,
retry budgets, and circuit breakers.

The paper's latency claims (§7.3) and its no-downtime failover story
(§7.6) both assume namenodes that are either healthy or *dead*. Real
fleets also fail **gray** — a namenode that is alive, heartbeating, and
10x slower than its peers — and under a Zipfian client population
(arXiv:2005.06963's hot-spot taxonomy) naive bounded retry loops turn
one slow server into a metastable overload: every retry adds load,
every added load slows the server further. This module is the
protection layer:

Deadline propagation
    Every :class:`~repro.core.ops_registry.WorkloadOp` may carry a
    ``deadline`` on the election's logical clock (the one clock
    namenode liveness, lease liveness, and now request staleness all
    share). A namenode **sheds** work whose deadline already passed
    (:class:`DeadlineExpired`) instead of executing it — executing an
    op nobody is waiting for is pure amplification — and the planned
    pipeline deals only ops that can still make their deadline
    (``BatchPlanner.plan_window``). :func:`stamp_deadlines` tags a
    trace; goodput is then ``ok and completed_at <= deadline``
    (``OpResult.completed_at`` is stamped by the namenode RPC layer).

Weighted fair queueing + load shedding
    :class:`AdmissionController` sits at namenode admission
    (``Namenode.execute_batch`` / ``invoke``). Under queue pressure
    (:meth:`AdmissionController.observe_queue`) it sheds
    (:class:`OverloadShed`) in strict priority order: **reads from hot
    tenants first, lease-holding mutations never** — a shed read is a
    wasted round trip, but a shed mutation under lease risks losing a
    writer's progress. "Hot" is decided by per-tenant virtual time
    (classic WFQ): each admitted op advances its tenant's vtime by
    cost/weight, and tenants above their fair share shed first, so a
    Zipf s≈1.1 tenant mix cannot starve cold tenants. Per-client and
    per-partition telemetry (:meth:`AdmissionController.report`) feeds
    the bench's ``overload`` section.

Retry budgets
    :class:`RetryBudget` is a token bucket shared by EVERY retrying
    middleware on a client (``failover``/``txn_retry``/
    ``subtree_retry``): each logical call deposits ``refill_rate``
    tokens (:meth:`~RetryBudget.note_call`), each retry spends one
    (:meth:`~RetryBudget.try_spend`). The fleet-wide retry rate is
    thus bounded at ~``refill_rate`` of the call rate no matter how
    the per-middleware attempt counters multiply — the standard
    defence against retry storms.

Circuit breakers
    :class:`CircuitBreaker` per namenode (closed → open → half-open
    probes), aggregated in a :class:`BreakerBoard`. Transport-class
    failures (:data:`BREAKER_FAILURES`) trip the breaker; genuine FS
    outcomes (FileNotFound, quota, lease conflicts) never do. The
    board integrates with routing: ``BatchPlanner`` stops dealing free
    chunks to open namenodes, ``Client._pick`` avoids them, and
    ``ElasticNamenodePool`` prefers retiring a tripped namenode.

Everything runs on the deterministic logical clock — no wall-clock
reads — so chaos replays (``DELAY`` faults, docs/CHAOS.md) reproduce
bit-for-bit. See docs/ROBUSTNESS.md for the policy rationale.
"""
from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

from .fs import FSError
from .middleware import CallContext, Handler, Middleware
from .ops_registry import REGISTRY, WorkloadOp


class DeadlineExpired(FSError):
    """The op's deadline passed before a namenode could execute it: shed,
    not failed — the client already stopped waiting, so executing would
    only amplify overload. Retryable by the chaos recovery protocol
    (the op itself is valid; only its timing budget ran out)."""


class OverloadShed(FSError):
    """The admission controller refused the op under queue pressure
    (WFQ policy: hot-tenant reads first). Retryable — the op is valid
    and will be admitted once pressure clears."""


#: outcome error names that count as TRANSPORT failures for circuit
#: breaking — a server producing these is sick or unreachable. Genuine
#: FS outcomes (FileNotFound, LeaseConflict, quota...) never trip a
#: breaker: they are proof the server is working.
BREAKER_FAILURES = frozenset({
    "StoreError", "NetworkPartition", "LockTimeout", "TransactionAborted",
    "DeadlineExpired",
})


def stamp_deadlines(wops: Sequence[WorkloadOp], *, now: int, budget: int,
                    per_op: float = 0.0) -> Sequence[WorkloadOp]:
    """Tag every op with ``deadline = now + budget (+ i*per_op)`` on the
    election clock. ``per_op`` staggers deadlines for very long traces
    where later ops are naturally submitted later. Mutates in place
    (traces are built fresh) and returns ``wops`` for chaining."""
    for i, wop in enumerate(wops):
        wop.deadline = now + budget + int(i * per_op)
    return wops


def _is_lease_mutation(spec: Any) -> bool:
    """Lease-holding mutations — ops that carry or renew a client lease
    (create/append/add_block/...) — are never pressure-shed: shedding
    them stalls a writer mid-file and risks soft-limit takeover of its
    lease. They can still be deadline-shed (nobody is waiting)."""
    return spec is not None and not spec.read_only and (
        spec.has_client_arg or spec.renews_lease
        or spec.lease_order is not None)


@dataclass
class TenantLoad:
    """Per-tenant WFQ accounting + telemetry."""
    admitted: int = 0
    shed: int = 0
    vtime: float = 0.0      # virtual time: Σ cost/weight of admitted ops

    @property
    def offered(self) -> int:
        return self.admitted + self.shed


class AdmissionController:
    """Namenode-side admission: deadline shedding always, WFQ load
    shedding under queue pressure.

    Installed on every namenode of a cluster (:meth:`install`, the
    ``FaultInjector.install`` pattern); ``Namenode.execute_batch`` asks
    :meth:`admit_batch` before executing, ``Namenode.invoke`` asks
    :meth:`check_op` on the sequential path. The driving pipeline
    reports its backlog each window via :meth:`observe_queue`; pressure
    is ``queue_depth > queue_capacity``.

    Shed ordering under pressure (strict priority, docs/ROBUSTNESS.md):

    1. any op past its deadline (always shed, pressure or not),
    2. reads from tenants above fair share (largest vtime first),
    3. non-lease mutations from over-share tenants, only under severe
       pressure (queue > ``severe_factor`` x capacity),
    4. lease-holding mutations: never pressure-shed.

    A tenant at or below its fair share of admitted work is never
    pressure-shed, so cold tenants cannot be starved by a hot one.
    """

    def __init__(self, election: Any, *, queue_capacity: int = 256,
                 severe_factor: float = 2.0, n_partitions: int = 8,
                 weights: Optional[Dict[str, float]] = None):
        self.election = election
        self.queue_capacity = queue_capacity
        self.severe_factor = severe_factor
        self.n_partitions = max(1, n_partitions)
        self.weights = dict(weights or {})
        self.queue_depth = 0
        self.tenants: Dict[str, TenantLoad] = {}
        self.clients: Dict[str, int] = {}       # per-client admitted ops
        self.partition_load: Dict[int, int] = {}  # partition -> admitted
        self.admitted = 0
        self.shed_deadline = 0
        self.shed_pressure = 0
        self._mu = threading.Lock()
        self._installed: List[Any] = []

    # -- wiring ---------------------------------------------------------
    def install(self, cluster: Any) -> "AdmissionController":
        """Attach to every namenode of ``cluster`` (late joiners are NOT
        auto-attached — the pool's `add_namenode` copies chaos hooks,
        admission is per-experiment wiring)."""
        self.n_partitions = cluster.store.n_partitions
        for nn in cluster.namenodes:
            nn.admission = self
            self._installed.append(nn)
        return self

    def uninstall(self) -> None:
        for nn in self._installed:
            nn.admission = None
        self._installed.clear()

    def observe_queue(self, depth: int) -> None:
        """Pipeline backlog report — the pressure signal."""
        self.queue_depth = max(0, depth)

    # -- policy ---------------------------------------------------------
    def _weight(self, tenant: str) -> float:
        return self.weights.get(tenant, 1.0)

    def _fair_share(self) -> float:
        """Equal-weight fair share of admitted work per tenant."""
        n = max(1, len(self.tenants))
        return max(1.0, self.admitted / n)

    def _over_share(self, tenant: str) -> bool:
        load = self.tenants.get(tenant)
        if load is None:
            return False
        return load.admitted > self._fair_share()

    def _account(self, wop: WorkloadOp, spec: Any, shed: Optional[str]
                 ) -> None:
        tenant = wop.tenant or "-"
        t = self.tenants.setdefault(tenant, TenantLoad())
        if shed is not None:
            t.shed += 1
            return
        t.admitted += 1
        cost = 1.0 if (spec is not None and spec.read_only) else 2.0
        t.vtime += cost / self._weight(tenant)
        self.admitted += 1
        client = str((wop.args or {}).get("client", "client"))
        self.clients[client] = self.clients.get(client, 0) + 1
        part = zlib.crc32(wop.path.encode()) % self.n_partitions
        self.partition_load[part] = self.partition_load.get(part, 0) + 1

    def check_op(self, wop: WorkloadOp, *, record: bool = True
                 ) -> None:
        """Sequential-path admission (``Namenode.invoke``): deadline
        shedding only — a single RPC carries no queue to fair-share.
        Raises :class:`DeadlineExpired`; ``record=False`` re-checks an
        already-admitted op (mid-batch) without double-counting."""
        spec = REGISTRY.get(wop.op)
        if wop.deadline is not None and self.election.now > wop.deadline:
            with self._mu:
                self.shed_deadline += 1
                if record:
                    self._account(wop, spec, "DeadlineExpired")
            raise DeadlineExpired(
                f"{wop.op} {wop.path}: deadline {wop.deadline} < "
                f"now {self.election.now}")
        if record:
            with self._mu:
                self._account(wop, spec, None)

    def admit_batch(self, wops: Sequence[WorkloadOp]
                    ) -> List[Optional[str]]:
        """Batch admission: one decision per op — None (admit) or the
        shed error name. Deadline sheds are unconditional; pressure
        sheds follow the WFQ priority order documented on the class."""
        now = self.election.now
        pressure = self.queue_depth > self.queue_capacity
        severe = self.queue_depth > self.severe_factor * self.queue_capacity
        # overload fraction decides how much of the batch we may shed
        max_shed = 0
        if pressure and self.queue_depth > 0:
            frac = min(0.9, (self.queue_depth - self.queue_capacity)
                       / self.queue_depth)
            max_shed = int(frac * len(wops))
        decisions: List[Optional[str]] = [None] * len(wops)
        with self._mu:
            sheddable: List[Any] = []   # (priority, vtime, idx)
            for i, wop in enumerate(wops):
                spec = REGISTRY.get(wop.op)
                if wop.deadline is not None and now > wop.deadline:
                    decisions[i] = "DeadlineExpired"
                    self.shed_deadline += 1
                    self._account(wop, spec, "DeadlineExpired")
                    continue
                if pressure and self._over_share(wop.tenant or "-") \
                        and not _is_lease_mutation(spec):
                    read = spec is not None and spec.read_only
                    if read or severe:
                        load = self.tenants.get(wop.tenant or "-")
                        sheddable.append(
                            (0 if read else 1,
                             -(load.vtime if load else 0.0), i))
            # reads before mutations, hottest tenant (largest vtime) first
            sheddable.sort()
            for _, _, i in sheddable[:max_shed]:
                decisions[i] = "OverloadShed"
                self.shed_pressure += 1
                self._account(wops[i], REGISTRY.get(wops[i].op),
                              "OverloadShed")
            for i, wop in enumerate(wops):
                if decisions[i] is None:
                    self._account(wop, REGISTRY.get(wop.op), None)
        return decisions

    # -- telemetry ------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        with self._mu:
            hot = sorted(self.partition_load.items(),
                         key=lambda kv: -kv[1])[:4]
            return {
                "admitted": self.admitted,
                "shed_deadline": self.shed_deadline,
                "shed_pressure": self.shed_pressure,
                "tenants": {
                    t: {"admitted": v.admitted, "shed": v.shed,
                        "vtime": round(v.vtime, 3)}
                    for t, v in sorted(self.tenants.items())},
                "clients": dict(sorted(self.clients.items())),
                "hot_partitions": [list(kv) for kv in hot],
            }


class RetryBudget:
    """Shared token-bucket retry budget (docs/ROBUSTNESS.md math):
    every logical call deposits ``refill_rate`` tokens (capped at
    ``capacity``), every retry — across ALL middleware sharing the
    bucket — spends one. Steady-state retry rate is therefore at most
    ``refill_rate`` x call rate (~10% with the default), which is what
    keeps bounded-attempt retry loops from amplifying a slow namenode
    into a metastable overload. ``capacity`` is the burst allowance."""

    def __init__(self, capacity: float = 20.0, refill_rate: float = 0.1):
        self.capacity = float(capacity)
        self.refill_rate = float(refill_rate)
        self.tokens = float(capacity)
        self.calls = 0
        self.spent = 0
        self.denied = 0
        self._mu = threading.Lock()

    def note_call(self) -> None:
        """One logical call = one deposit (clients call this per op)."""
        with self._mu:
            self.calls += 1
            self.tokens = min(self.capacity, self.tokens + self.refill_rate)

    def try_spend(self) -> bool:
        """Spend one token for a retry; False = budget exhausted, the
        caller must surface its error instead of retrying."""
        with self._mu:
            if self.tokens >= 1.0:
                self.tokens -= 1.0
                self.spent += 1
                return True
            self.denied += 1
            return False


class CircuitBreaker:
    """Per-namenode breaker on the election clock: ``failure_threshold``
    consecutive transport failures open it; after ``reset_after`` ticks
    it half-opens and admits ``half_open_probes`` probe routings; a
    probe success closes it, a probe failure re-opens (fresh timer)."""

    def __init__(self, *, failure_threshold: int = 3, reset_after: int = 8,
                 half_open_probes: int = 1, now: Any = None):
        self.failure_threshold = max(1, failure_threshold)
        self.reset_after = max(1, reset_after)
        self.half_open_probes = max(1, half_open_probes)
        self._now = now or (lambda: 0)
        self.state = "closed"
        self.failures = 0
        self.opened_at: Optional[int] = None
        self.probes_left = 0
        self.trips = 0

    def _maybe_half_open(self) -> None:
        if self.state == "open" and self.opened_at is not None \
                and self._now() - self.opened_at >= self.reset_after:
            self.state = "half_open"
            self.probes_left = self.half_open_probes

    def routable(self) -> bool:
        """May this namenode be dealt work right now? Non-consuming in
        ``closed``; in ``half_open`` each True consumes one probe slot
        (the router sends exactly that much traffic at a sick server)."""
        self._maybe_half_open()
        if self.state == "closed":
            return True
        if self.state == "half_open" and self.probes_left > 0:
            self.probes_left -= 1
            return True
        return False

    @property
    def is_open(self) -> bool:
        """Non-consuming peek (victim selection, telemetry)."""
        self._maybe_half_open()
        return self.state == "open"

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self.opened_at = None
        self.probes_left = 0

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half_open" \
                or self.failures >= self.failure_threshold:
            if self.state != "open":
                self.trips += 1
            self.state = "open"
            self.opened_at = self._now()
            self.probes_left = 0


class BreakerBoard:
    """One :class:`CircuitBreaker` per namenode id, lazily created on
    the shared election clock. The single integration point for the
    planner (free-chunk slots), the client selector, and the pool."""

    def __init__(self, election: Any, *, failure_threshold: int = 3,
                 reset_after: int = 8, half_open_probes: int = 1):
        self.election = election
        self._kw = dict(failure_threshold=failure_threshold,
                        reset_after=reset_after,
                        half_open_probes=half_open_probes)
        self.breakers: Dict[int, CircuitBreaker] = {}

    def for_nn(self, nn_id: int) -> CircuitBreaker:
        br = self.breakers.get(nn_id)
        if br is None:
            br = CircuitBreaker(now=lambda: self.election.now, **self._kw)
            self.breakers[nn_id] = br
        return br

    def routable(self, nn_id: int) -> bool:
        return self.for_nn(nn_id).routable()

    def is_open(self, nn_id: int) -> bool:
        return self.for_nn(nn_id).is_open

    def record(self, nn_id: int, *, ok: bool) -> None:
        br = self.for_nn(nn_id)
        br.record_success() if ok else br.record_failure()

    @property
    def trips(self) -> int:
        return sum(br.trips for br in self.breakers.values())

    def open_ids(self) -> List[int]:
        return sorted(i for i, br in self.breakers.items() if br.is_open)

    def states(self) -> Dict[int, str]:
        return {i: br.state for i, br in sorted(self.breakers.items())}


def circuit_breaker(board: BreakerBoard) -> Middleware:
    """Middleware recording per-attempt outcomes on the board: placed
    INSIDE ``failover`` so every attempt (not just the logical call)
    updates the breaker of the namenode that served it. Transport-class
    errors (:data:`BREAKER_FAILURES`) count as failures; genuine FS
    outcomes and successes close the breaker."""
    def mw(nxt: Handler) -> Handler:
        def handler(ctx: CallContext) -> Any:
            try:
                res = nxt(ctx)
            except Exception as e:
                nn = ctx.namenode
                if nn is not None:
                    board.record(nn.nn_id,
                                 ok=type(e).__name__ not in BREAKER_FAILURES)
                raise
            nn = ctx.namenode
            if nn is not None:
                board.record(nn.nn_id, ok=True)
            return res
        return handler
    return mw
