"""Leader election and membership through the database (paper §3, ref [57]).

HopsFS uses the database as shared memory: every namenode periodically
writes a heartbeat row; a namenode is *alive* iff it has written within a
bounded number of ticks; the leader is the alive namenode with the smallest
id. The leader runs housekeeping (replication manager, block-report load
balancing, lease recovery).

Client liveness rides the SAME logical clock: lease renewals (`lease`
table, ``fs.HopsFSOps.renew_lease``) are stamped with ``now``, and a lease
not renewed within the lease limit is expired — which is what
``Namenode.recover_leases`` (leader-only housekeeping) reclaims, unblocking
other writers' ``append``/``add_block``. Dead clients are thus detected
exactly like dead namenodes: bounded heartbeat staleness against this
clock.

Time here is a logical clock advanced by the caller (the DES or the runtime
driver), which makes the protocol deterministic and testable.
"""
from __future__ import annotations

from typing import Any, List, Optional

from .store import MetadataStore
from .transactions import Transaction


class LeaderElection:
    #: logical liveness clock — namenode heartbeats AND client lease
    #: renewals are stamped against it (advanced by tick())
    now: int = 0

    def __init__(self, store: MetadataStore, *, max_missed: int = 2):
        self.store = store
        self.max_missed = max_missed
        self.now = 0
        #: chaos hook (chaos.FaultInjector.install): the "heartbeat" site —
        #: a crash here is a namenode dying WITH its liveness proof, the
        #: purest form of §7.6 failure (detected after max_missed ticks)
        self.chaos: Optional[Any] = None

    def tick(self) -> None:
        self.now += 1

    def heartbeat(self, namenode_id: int) -> None:
        """One bounded-time write to the DB == liveness proof ([57])."""
        if self.chaos is not None \
                and not self.chaos.allow_heartbeat(namenode_id):
            return      # the victim died instead of proving liveness
        with Transaction(self.store,
                         partition_hint=("leader", namenode_id)) as txn:
            txn.write("leader", {"namenode_id": namenode_id,
                                 "last_hb": self.now})

    def alive(self) -> List[int]:
        rows = self.store.table("leader").scan_all(
            lambda r: self.now - r["last_hb"] <= self.max_missed)
        return sorted(r["namenode_id"] for r in rows)

    def is_alive(self, namenode_id: int) -> bool:
        row = self.store.table("leader").get((namenode_id,))
        return row is not None and self.now - row["last_hb"] <= self.max_missed

    def leader(self) -> Optional[int]:
        a = self.alive()
        return a[0] if a else None

    def remove(self, namenode_id: int) -> None:
        self.store.table("leader").delete((namenode_id,))
