"""Client-side columnar batch planner + planned request pipeline.

The paper's throughput headline (Fig 7) comes from *distribution-aware,
batched* transactions (§2.2, §5.1). The reactive pipeline only discovers
batching opportunities after the fact: fixed-size FIFO batches are dealt to
namenodes and ``execute_batch`` groups whatever same-type, same-partition
runs happen to be adjacent. This module moves that discovery to the CLIENT
side of the metadata path (the λFS lesson — see PAPERS.md):

  1. **lower**   — a trace window is lowered to struct-of-arrays form
     (:func:`~repro.core.workload.lower_trace`): per-op type ids plus the
     hint-cache chain resolution broken out per path component;
  2. **hash**    — every op's component chain and hinted target are hashed
     in ONE fused ``phash_chain`` Pallas launch
     (:func:`~repro.kernels.phash.ops.phash_chains`), giving each op its
     coordinator partition and a chain signature;
  3. **pin**     — mutations whose paths collide (same path, or one a
     path-prefix of another, subtree ops included), destructive ops, and
     ops that did not resolve client-side are *pinned*: they keep their
     submission order, because reordering them could change the final
     namespace or spuriously fail an op. Read-only resolved ops are never
     pinned (they cannot change final state);
  4. **deal**    — free ops are sorted by (partition, type) and chunked
     into partition-aligned, type-sorted batches routed to the namenode
     slot owning that partition, each op carrying its client-side
     resolution as a :class:`~repro.core.namenode.PlanHint`. The namenode
     executors therefore see maximal groupable runs whose shared
     distribution-aware transactions land on their coordinator's node
     group (raising the local round-trip share, §7.7).

Planned execution guarantees *final-state* equivalence with sequential
execution (asserted by tests/test_batched_pipeline.py); per-op result
streams may differ for reads reordered across mutations, exactly as with
any concurrent client population. Deterministic mode executes the plan in
order, so window-scoped conflict analysis suffices; concurrent mode
interleaves windows across worker threads, so there EVERY mutation is
pinned onto one ordered queue (reads, which cannot change final state,
still deal partition-aligned to all workers).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from .namenode import (NamenodeCluster, OpOutcome, PipelineStats, PlanHint,
                       RequestPipeline)
from .ops_registry import REGISTRY, WorkloadOp
from .store import StoreError
from .workload import ColumnarTrace, lower_trace

__all__ = ["BatchPlanner", "MultiCacheResolver", "PlannedBatch",
           "PlannedRequestPipeline", "PlanReport"]


class MultiCacheResolver:
    """The client's hint view: the merge of every alive namenode's inode
    hint cache, probed side-effect-free (no LRU churn, no skewed hit/miss
    counters on the namenodes). In HopsFS terms this is the client-side
    cache the namenodes' piggybacked hints would populate."""

    def __init__(self, caches: Sequence[Any]):
        self.caches = [c for c in caches if c is not None]

    @classmethod
    def of_cluster(cls, cluster: NamenodeCluster) -> "MultiCacheResolver":
        return cls([nn.ops.cache for nn in cluster.alive_namenodes()])

    def peek(self, parent_id: int, name: str) -> Optional[int]:
        for c in self.caches:
            v = c.peek(parent_id, name)
            if v is not None:
                return v
        return None


@dataclass
class PlannedBatch:
    """One dealt batch: trace indices, their client-side resolutions, the
    namenode slot the dominant partition routes to, and whether the batch
    is order-pinned (conflicting mutations: must run in plan order)."""
    indices: List[int]
    hints: List[Optional[PlanHint]]
    nn_slot: int
    ordered: bool = False


@dataclass
class PlanReport:
    """Planner telemetry for the benchmark report. ``predicted_local`` /
    ``predicted_total`` come from the kernel's per-component partitions:
    the share of an op's own row accesses expected to land on its
    coordinator's node group — the client-side forecast of the measured
    ``local_rt`` split (§7.7)."""
    ops: int = 0
    planned_ops: int = 0        # ops dealt with a client-side resolution
    pinned_ops: int = 0         # mutations kept in submission order
    lease_ordered_ops: int = 0  # block writes kept FREE under lease order:
                                # same-file collisions that would have
                                # pinned, held in submission order by the
                                # stable (partition, type) sort instead
    windows: int = 0
    batches: int = 0
    kernel_launches: int = 0    # fused phash_chain calls that succeeded
    partitions_seen: Set[int] = field(default_factory=set)
    predicted_local: int = 0
    predicted_total: int = 0

    @property
    def predicted_local_share(self) -> float:
        return (self.predicted_local / self.predicted_total
                if self.predicted_total else 0.0)


def _chain_partitions(ct: ColumnarTrace, n_partitions: int
                      ) -> Tuple[Any, Any, Any, bool]:
    """One fused kernel launch for the whole window; the numpy oracle for
    small windows or while the Pallas stack is unavailable — same shared
    probe + size gate + fallback policy as the namenodes' own
    ``_partitions_for`` (identical results either way)."""
    from .namenode import _with_phash_kernel

    def kern():
        from ..kernels.phash.ops import phash_chains
        return phash_chains(ct.parent_ids, ct.name_hashes, ct.hint_ids,
                            ct.depths, n_partitions)

    def fallback():
        from ..kernels.phash.ref import phash_chain_ref
        return phash_chain_ref(ct.parent_ids, ct.name_hashes, ct.hint_ids,
                               ct.depths, n_partitions)

    (comp, hint_parts, sigs), used_kernel = _with_phash_kernel(
        kern, fallback, n_keys=ct.n)
    return comp, hint_parts, sigs, used_kernel


class BatchPlanner:
    """Plans a trace into partition-aligned, type-sorted batches.

    ``window`` ops are planned at a time (default: enough for several
    batches per alive namenode); planning never moves an op across a
    window boundary, which bounds both reordering distance and the
    columnar working set.
    """

    def __init__(self, cluster: NamenodeCluster, *, batch_size: int = 16,
                 window: Optional[int] = None,
                 pin_all_mutations: bool = False):
        self.cluster = cluster
        self.batch_size = max(1, batch_size)
        n_slots = max(1, len(cluster.alive_namenodes()))
        self.n_slots = n_slots
        self.window = window or self.batch_size * n_slots * 8
        # conflict pinning is window-scoped, which is sound only when the
        # plan executes in order (one thread). Concurrent execution
        # interleaves windows, so there every mutation is pinned — they
        # all flow through ONE ordered queue while reads (which cannot
        # change final state) still deal partition-aligned.
        self.pin_all_mutations = pin_all_mutations
        self.report = PlanReport()

    # -- conflict pinning ----------------------------------------------
    @staticmethod
    def _mutation_paths(wop: WorkloadOp, spec: Any
                       ) -> List[Tuple[str, ...]]:
        out = [tuple(c for c in wop.path.split("/") if c)]
        if spec is not None and spec.paths == 2:
            p2 = wop.path2 if wop.path2 is not None else wop.path + ".mv"
            out.append(tuple(c for c in p2.split("/") if c))
        return out

    def _pin_conflicts(self, wops: Sequence[WorkloadOp],
                       idxs: Sequence[int]) -> Set[int]:
        """Pin every mutation whose path collides with another mutation's
        path in the window — equality, or prefix in either direction (a
        ``mkdirs`` below a path another op creates/deletes must not cross
        it). Checked exactly on the (minority) mutation set's component
        tuples; read-only ops are never pinned.

        Lease-ordered exception (the block-write window rule): same-path
        collisions where EVERY colliding mutation is the same lease-ordered
        op type with the same ``OpSpec.lease_order`` key (e.g. a run of
        add_blocks growing one hot file) stay FREE — the deal's
        submission-stable (partition, type, i) sort already keeps
        same-file ops in submission order (same file ⇒ same hint
        partition and same type), so they can batch with block writes to
        other files instead of being exiled to the ordered queue. Any
        mixed-type or mixed-key collision pins conservatively."""
        muts: List[Tuple[int, Any, List[Tuple[str, ...]]]] = []
        for i in idxs:
            spec = REGISTRY.get(wops[i].op)
            if spec is not None and spec.read_only:
                continue
            muts.append((i, spec, self._mutation_paths(
                wops[i], spec) if spec is not None else []))
        path_count: Dict[Tuple[str, ...], int] = {}
        prefix_count: Dict[Tuple[str, ...], int] = {}
        # per colliding path: the (op name, lease-order key) pairs of its
        # mutations — freeing requires ONE pair, with a real key
        ops_on_path: Dict[Tuple[str, ...], Set[Tuple[str, Any]]] = {}
        for i, spec, paths in muts:
            name = spec.name if spec is not None else "?"
            key = (spec.lease_order(wops[i])
                   if spec is not None and spec.lease_order is not None
                   else None)
            for p in paths:
                path_count[p] = path_count.get(p, 0) + 1
                ops_on_path.setdefault(p, set()).add((name, key))
                for k in range(1, len(p)):
                    pref = p[:k]
                    prefix_count[pref] = prefix_count.get(pref, 0) + 1
        pinned: Set[int] = set()
        for i, spec, paths in muts:
            # unknown/0-path ops cannot be reasoned about; destructive ops
            # (delete/rename/truncate/concat) must never be hopped over by
            # a read that the trace issued before them: keep in order.
            # pin_all_mutations (concurrent execution) pins every mutation
            # — window-scoped conflict analysis cannot see across windows
            # that interleave on worker threads.
            if self.pin_all_mutations or spec is None or spec.paths == 0 \
                    or spec.destructive:
                pinned.add(i)
                continue
            lease_freed = False
            for p in paths:
                if prefix_count.get(p, 0) > 0 \
                        or any(p[:k] in path_count
                               for k in range(1, len(p))):
                    pinned.add(i)
                    break
                if path_count.get(p, 0) > 1:
                    pairs = ops_on_path[p]
                    if len(pairs) == 1 and spec.lease_order is not None \
                            and next(iter(pairs))[1] is not None:
                        lease_freed = True      # same-file, same-key run
                        continue
                    pinned.add(i)
                    break
            if lease_freed and i not in pinned:
                self.report.lease_ordered_ops += 1
        return pinned

    # -- planning -------------------------------------------------------
    def plan(self, wops: Sequence[WorkloadOp]) -> List[PlannedBatch]:
        n_partitions = self.cluster.store.n_partitions
        resolver = MultiCacheResolver.of_cluster(self.cluster)
        batches: List[PlannedBatch] = []
        self.report.ops += len(wops)
        for lo in range(0, len(wops), self.window):
            hi = min(lo + self.window, len(wops))
            window = list(range(lo, hi))
            ct = lower_trace([wops[i] for i in window], resolver)
            # _sigs: the kernel's path-equality probe, no consumer here yet
            comp_parts, hint_parts, _sigs, used_kernel = _chain_partitions(
                ct, n_partitions)
            if used_kernel:
                self.report.kernel_launches += 1
            pinned = self._pin_conflicts(wops, window)
            # ops whose chain did NOT resolve client-side stay in
            # submission order too — an unresolved read (or create) may
            # target a path another op in this window creates, and
            # hopping over that op would spuriously fail it. Unresolved
            # ops cannot group anyway, so ordering them costs nothing.
            for k, i in enumerate(window):
                if not ct.resolved[k]:
                    pinned.add(i)
            hints: Dict[int, Optional[PlanHint]] = {}
            parts: Dict[int, int] = {}
            n_groups = self.cluster.store.n_groups
            for k, i in enumerate(window):
                parts[i] = int(hint_parts[k])
                self.report.partitions_seen.add(parts[i])
                if ct.resolved[k]:
                    hints[i] = PlanHint(pks=ct.pks[k],
                                        target_id=ct.target_ids[k],
                                        hint_id=int(ct.hint_ids[k]))
                    self.report.planned_ops += 1
                    # client-side locality forecast: which of this op's
                    # component rows share the coordinator's node group
                    d = int(ct.depths[k])
                    coord_g = parts[i] % n_groups
                    self.report.predicted_local += sum(
                        1 for j in range(d)
                        if int(comp_parts[k, j]) % n_groups == coord_g)
                    self.report.predicted_total += d
                else:
                    hints[i] = None
            type_of = {i: int(ct.type_ids[k])
                       for k, i in enumerate(window)}
            # free ops: partition-aligned, type-sorted, submission-stable
            free = [i for i in window if i not in pinned]
            free.sort(key=lambda i: (parts[i], type_of[i], i))
            for c in range(0, len(free), self.batch_size):
                chunk = free[c:c + self.batch_size]
                slot = parts[chunk[0]] % self.n_slots
                batches.append(PlannedBatch(
                    indices=chunk, hints=[hints[i] for i in chunk],
                    nn_slot=slot))
            # pinned mutations LAST, strictly in submission order: free
            # reads of a window never spuriously fail against a
            # destructive op the trace issued later (a read the trace
            # issued after the delete may now succeed instead — benign,
            # final state is unaffected by reads)
            pin_order = [i for i in window if i in pinned]
            self.report.pinned_ops += len(pin_order)
            for c in range(0, len(pin_order), self.batch_size):
                chunk = pin_order[c:c + self.batch_size]
                batches.append(PlannedBatch(
                    indices=chunk, hints=[hints[i] for i in chunk],
                    nn_slot=0, ordered=True))
            self.report.windows += 1
        self.report.batches += len(batches)
        return batches


class PlannedRequestPipeline(RequestPipeline):
    """A :class:`RequestPipeline` whose dealing is driven by the client-side
    plan instead of FIFO slicing: each namenode receives partition-aligned,
    type-sorted batches with planner hints attached, so ``execute_batch``
    sees maximal groupable runs (reads AND group-mutable writes) and its
    shared transactions land on their coordinator's node group.

    ``concurrent=False`` executes batches in plan order (deterministic);
    ``concurrent=True`` runs one worker per alive namenode over per-slot
    queues — order-pinned batches all live on one queue, preserving their
    relative order. Ops on a namenode that dies mid-batch fail over to the
    survivors exactly like the reactive pipeline (§7.6.1)."""

    def __init__(self, cluster: NamenodeCluster, *, batch_size: int = 16,
                 concurrent: bool = False, window: Optional[int] = None):
        super().__init__(cluster, batch_size=batch_size,
                         concurrent=concurrent)
        self.window = window
        self.planner: Optional[BatchPlanner] = None

    @property
    def plan_report(self) -> Optional[PlanReport]:
        return self.planner.report if self.planner else None

    def run(self, wops: Sequence[WorkloadOp]) -> PipelineStats:
        import time
        wops = list(wops)
        if not self.cluster.alive_namenodes():
            raise StoreError("no alive namenodes")
        self.planner = BatchPlanner(self.cluster,
                                    batch_size=self.batch_size,
                                    window=self.window,
                                    pin_all_mutations=self.concurrent)
        batches = self.planner.plan(wops)
        outcomes: List[Optional[OpOutcome]] = [None] * len(wops)
        residual: deque = deque()      # ops orphaned by namenode deaths
        rlock = threading.Lock()
        n_batches = [0]
        cost0 = {nn.nn_id: nn.agg_cost.copy()
                 for nn in self.cluster.namenodes}
        served0 = {nn.nn_id: nn.ops_served
                   for nn in self.cluster.namenodes}

        def run_batch(nn, batch: PlannedBatch) -> bool:
            """Execute one planned batch; False if the namenode died (its
            unfinished ops go to the residual queue)."""
            try:
                res = nn.execute_batch([wops[i] for i in batch.indices],
                                       hints=batch.hints)
            except StoreError:
                with rlock:
                    residual.extend(batch.indices)
                return False
            died = []
            for i, oc in zip(batch.indices, res):
                if not oc.ok and oc.error == "StoreError" and not nn.alive:
                    died.append(i)
                else:
                    outcomes[i] = oc
            if died:
                with rlock:
                    residual.extend(died)
            with rlock:
                n_batches[0] += 1
            return not died

        t0 = time.perf_counter()
        if not self.concurrent:
            for batch in batches:
                alive = self.cluster.alive_namenodes()
                if not alive:
                    break
                run_batch(alive[batch.nn_slot % len(alive)], batch)
        else:
            alive = self.cluster.alive_namenodes()
            queues: List[deque] = [deque() for _ in alive]
            qlock = threading.Lock()
            for batch in batches:
                queues[batch.nn_slot % len(alive)].append(batch)

            def pull(k: int) -> Optional[PlannedBatch]:
                with qlock:
                    if queues[k]:
                        return queues[k].popleft()
                    # steal UNORDERED work, longest donor first — ordered
                    # batches (all on slot 0) are never stolen, but a
                    # pinned tail there must not blind us to other donors
                    for j in sorted(range(len(queues)),
                                    key=lambda q: -len(queues[q])):
                        if queues[j] and not queues[j][-1].ordered:
                            return queues[j].pop()
                    return None

            def drain(k: int, nn) -> None:
                while True:
                    batch = pull(k)
                    if batch is None:
                        return
                    if not run_batch(nn, batch):
                        with qlock:                     # orphan my queue
                            while queues[k]:
                                b = queues[k].popleft()
                                with rlock:
                                    residual.extend(b.indices)
                        return

            workers = [threading.Thread(target=drain, args=(k, nn))
                       for k, nn in enumerate(alive)]
            for w in workers:
                w.start()
            for w in workers:
                w.join()
        # failover pass: re-deal orphaned ops to the survivors, reactive
        while residual:
            alive = self.cluster.alive_namenodes()
            if not alive:
                break
            idxs = [residual.popleft()
                    for _ in range(min(self.batch_size, len(residual)))]
            run_batch(alive[n_batches[0] % len(alive)],
                      PlannedBatch(indices=idxs,
                                   hints=[None] * len(idxs), nn_slot=0))
        wall = time.perf_counter() - t0
        for i, oc in enumerate(outcomes):
            if oc is None:
                outcomes[i] = OpOutcome(None, "StoreError")
        return self._finalize_stats(wops, outcomes, cost0, served0, wall,
                                    n_batches[0])
