"""Client-side columnar batch planner + the closed-loop planned pipeline.

The paper's throughput headline (Fig 7) comes from *distribution-aware,
batched* transactions (§2.2, §5.1). The reactive pipeline only discovers
batching opportunities after the fact: fixed-size FIFO batches are dealt to
namenodes and ``execute_batch`` groups whatever same-type, same-partition
runs happen to be adjacent. This module moves that discovery to the CLIENT
side of the metadata path (the λFS lesson — see PAPERS.md):

  1. **lower**   — a trace window is lowered to struct-of-arrays form
     (:func:`~repro.core.workload.lower_trace`): per-op type ids plus the
     hint-cache chain resolution broken out per path component;
  2. **hash**    — every op's component chain and hinted target are hashed
     in ONE fused ``phash_chain`` Pallas launch
     (:func:`~repro.kernels.phash.ops.phash_chains`), giving each op its
     coordinator partition and a chain signature;
  3. **pin**     — mutations whose paths collide (same path, or one a
     path-prefix of another, subtree ops included), destructive ops, and
     ops that did not resolve client-side are *pinned*: they keep their
     submission order, because reordering them could change the final
     namespace or spuriously fail an op. Read-only resolved ops are never
     pinned (they cannot change final state);
  4. **deal**    — free ops are sorted by (partition, type) and chunked
     into partition-aligned, type-sorted batches routed to the namenode
     slot owning that partition, each op carrying its client-side
     resolution as a :class:`~repro.core.namenode.PlanHint`. The namenode
     executors therefore see maximal groupable runs whose shared
     distribution-aware transactions land on their coordinator's node
     group (raising the local round-trip share, §7.7).

The pipeline is **closed-loop** (see ``docs/HINTS.md``): the client's hint
view is its OWN :class:`~repro.core.hint_cache.InodeHintCache`, warmed
from the ``(parent_id, name) -> inode_id`` resolutions namenode responses
piggyback (``OpResult.hints``) and invalidated on destructive ops; the
merged namenode caches (:class:`MultiCacheResolver`) are only the
cold-start FALLBACK. Each window is planned, executed, and absorbed before
the next window is planned, and a :class:`WindowController` feedback loop
resizes the planning window from the observed conflict-pin rate and
round-trips-per-op — the window is a control variable, not a constant.

Planned execution guarantees *final-state* equivalence with sequential
execution (asserted by tests/test_batched_pipeline.py and
tests/test_closed_loop_pipeline.py); per-op result streams may differ for
reads reordered across mutations, exactly as with any concurrent client
population. Deterministic mode executes the plan in order; concurrent mode
runs one worker per alive namenode WITHIN each window (windows are
barriers, so window-scoped conflict analysis stays sound), with
lease-ordered same-key runs kept whole in one batch so same-file block
writes can never interleave across workers while distinct-file block
writes group concurrently.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from .columnar import lower_trace_fused, validate_window_pks
from .hint_cache import InodeHintCache, absorb_response
from .namenode import (NamenodeCluster, OpOutcome, PipelineStats, PlanHint,
                       RequestPipeline)
from .ops_registry import REGISTRY, WorkloadOp
from .store import StoreError
from .tables import split_path
from .workload import ColumnarTrace

__all__ = ["BatchPlanner", "HintResolver", "MultiCacheResolver",
           "PlannedBatch", "PlannedRequestPipeline", "PlanReport",
           "WindowController"]


class MultiCacheResolver:
    """The merged view of every alive namenode's inode hint cache, probed
    side-effect-free (no LRU churn, no skewed hit/miss counters on the
    namenodes). Since the closed-loop pipeline this is the cold-start
    FALLBACK behind the client's own response-warmed cache
    (:class:`HintResolver`) — not the primary resolution path."""

    def __init__(self, caches: Sequence[Any]):
        self.caches = [c for c in caches if c is not None]

    @classmethod
    def of_cluster(cls, cluster: NamenodeCluster) -> "MultiCacheResolver":
        return cls([nn.ops.cache for nn in cluster.alive_namenodes()])

    def peek(self, parent_id: int, name: str) -> Optional[int]:
        for c in self.caches:
            v = c.peek(parent_id, name)
            if v is not None:
                return v
        return None


class HintResolver:
    """The closed-loop client hint view: the client's OWN cache (warmed by
    response piggybacking, ``OpResult.hints``) first, a fallback resolver
    (the merged namenode caches) only on a miss. Probe-level telemetry:
    ``hits`` (client cache), ``fallback_hits`` (namenode caches vouched),
    ``misses`` (nobody knew — the op stays unresolved or resolves
    server-side)."""

    def __init__(self, cache: InodeHintCache, fallback: Any = None):
        self.cache = cache
        self.fallback = fallback
        self.hits = 0
        self.fallback_hits = 0
        self.misses = 0

    def peek(self, parent_id: int, name: str) -> Optional[int]:
        v = self.cache.peek(parent_id, name)
        if v is not None:
            self.hits += 1
            return v
        if self.fallback is not None:
            v = self.fallback.peek(parent_id, name)
            if v is not None:
                self.fallback_hits += 1
                return v
        self.misses += 1
        return None


class WindowController:
    """Feedback controller for the planning-window size (AIMD-flavoured
    hill climb). After each window executes, :meth:`observe` is fed the
    window's op count, conflict-pin count, and measured DB round trips:

      * a high pin rate means the window is wasting reordering freedom on
        conflicting mutations — SHRINK (less speculative lookahead, lower
        client-observed latency);
      * otherwise, if round-trips-per-op held steady or improved, the
        batching amortization is paying — GROW toward ``max_window``;
      * a regressing round-trip rate backs off.

    Deterministic (no randomness), clamped to [min_window, max_window],
    so planned runs stay reproducible. The same controller drives the
    DES mirror (``cluster_sim.BatchedHopsFSSim(adaptive=True)``).

    Since the elastic pool, the controller optionally drives a SECOND
    knob: per-namenode ``batch_size``, AIMD-adapted from the measured
    lock-wait fraction (``LockManager.wait_count / acquire_count`` over
    the window). Bigger batches mean longer grouped transactions holding
    more row locks at once; when peers start *waiting* on those locks the
    batch is the contention amplifier, so it backs off multiplicatively
    (divide by ``factor``) and regrows additively (``batch_step``) while
    contention stays under ``contention_shrink`` — classic AIMD, applied
    to transaction footprint instead of flow rate. Pass ``batch_base``
    to enable; without it the knob is inert and ``observe`` behaves
    exactly as before."""

    def __init__(self, base: int, *, min_window: int, max_window: int,
                 pin_shrink: float = 0.35, factor: int = 2,
                 rt_slack: float = 1.05, batch_base: Optional[int] = None,
                 min_batch: int = 1, max_batch: Optional[int] = None,
                 contention_shrink: float = 0.05, batch_step: int = 1):
        self.window = max(1, base)
        self.min_window = max(1, min_window)
        self.max_window = max(self.min_window, max_window)
        self.pin_shrink = pin_shrink
        self.factor = max(2, factor)
        self.rt_slack = rt_slack
        self._last_rt_per_op: Optional[float] = None
        self.history: List[int] = [self.window]
        # the batch-size knob (None = not controlled)
        self.batch_size: Optional[int] = (max(1, batch_base)
                                          if batch_base is not None else None)
        self.min_batch = max(1, min_batch)
        self.max_batch = (max(self.min_batch, max_batch)
                          if max_batch is not None
                          else (self.batch_size * 4
                                if self.batch_size is not None else None))
        self.contention_shrink = contention_shrink
        self.batch_step = max(1, batch_step)
        self.batch_history: List[int] = (
            [self.batch_size] if self.batch_size is not None else [])

    def observe(self, ops: int, pinned: int, round_trips: int,
                *, lock_wait_frac: float = 0.0) -> int:
        if ops <= 0:
            return self.window
        pin_rate = pinned / ops
        rt_per_op = round_trips / ops
        if pin_rate > self.pin_shrink:
            self.window = max(self.min_window, self.window // self.factor)
        elif (self._last_rt_per_op is None
              or rt_per_op <= self._last_rt_per_op * self.rt_slack):
            self.window = min(self.max_window, self.window * self.factor)
        else:
            self.window = max(self.min_window, self.window // self.factor)
        self._last_rt_per_op = rt_per_op
        self.history.append(self.window)
        if self.batch_size is not None:
            if lock_wait_frac > self.contention_shrink:
                self.batch_size = max(self.min_batch,
                                      self.batch_size // self.factor)
            else:
                self.batch_size = min(self.max_batch,  # type: ignore[arg-type]
                                      self.batch_size + self.batch_step)
            self.batch_history.append(self.batch_size)
        return self.window


@dataclass
class PlannedBatch:
    """One dealt batch: trace indices, their client-side resolutions, the
    namenode slot the dominant partition routes to, and whether the batch
    is order-pinned (conflicting mutations: must run in plan order).
    Lease-ordered same-key runs are never split across batches, so a batch
    is always an atomic unit of per-file block-write ordering; ``mutates``
    marks batches carrying any mutation — concurrent workers never steal
    those, so a partition's writes always land on its home namenode
    (warm hint cache, stable grouped-write engagement)."""
    indices: List[int]
    hints: List[Optional[PlanHint]]
    nn_slot: int
    ordered: bool = False
    mutates: bool = False


@dataclass
class PlanReport:
    """Planner telemetry for the benchmark report. ``predicted_local`` /
    ``predicted_total`` come from the kernel's per-component partitions:
    the share of an op's own row accesses expected to land on its
    coordinator's node group — the client-side forecast of the measured
    ``local_rt`` split (§7.7). The ``client_*`` fields are the closed-loop
    hint telemetry: probe-level hits on the client's own response-warmed
    cache vs fallback hits on the merged namenode caches vs misses, plus
    staleness evidence (absorbed hints contradicting cached ids, and
    client-side invalidations on destructive ops)."""
    ops: int = 0
    planned_ops: int = 0        # ops dealt with a client-side resolution
    pinned_ops: int = 0         # mutations kept in submission order
    lease_ordered_ops: int = 0  # block writes kept FREE under lease order:
                                # same-file collisions that would have
                                # pinned, held in submission order by the
                                # stable (partition, type, i) sort instead
    windows: int = 0
    batches: int = 0
    kernel_launches: int = 0    # fused phash_chain calls that succeeded
    hintchain_launches: int = 0  # fused hint-chain resolution launches
    pkval_launches: int = 0     # fused grouped-PK validation launches
    pkval_probes: int = 0       # composite-PK probes validated in them
    pkval_demotions: int = 0    # resolved ops demoted by stale chains
    partitions_seen: Set[int] = field(default_factory=set)
    predicted_local: int = 0
    predicted_total: int = 0
    # closed-loop client hint-cache telemetry (probe-level)
    client_hits: int = 0
    client_fallback_hits: int = 0
    client_misses: int = 0
    client_stale: int = 0          # absorbed hints contradicting cached ids
    client_invalidations: int = 0  # destructive-op invalidations
    hint_routed_batches: int = 0   # batches dealt to a warm namenode
                                   # instead of the partition-hash slot
    deadline_shed: int = 0         # ops never dealt: deadline already past
    breaker_rerouted: int = 0      # batches moved off an open-breaker slot
    window_sizes: List[int] = field(default_factory=list)

    @property
    def predicted_local_share(self) -> float:
        return (self.predicted_local / self.predicted_total
                if self.predicted_total else 0.0)

    @property
    def hint_hit_rate(self) -> float:
        """Share of resolver probes answered by the CLIENT's own cache —
        the closed-loop win: >0 means responses, not namenode-cache reads,
        are resolving paths."""
        probes = self.client_hits + self.client_fallback_hits \
            + self.client_misses
        return self.client_hits / probes if probes else 0.0


def _chain_partitions(ct: ColumnarTrace, n_partitions: int
                      ) -> Tuple[Any, Any, Any, bool]:
    """One fused kernel launch for the whole window; the numpy oracle for
    small windows or while the Pallas stack is unavailable — same shared
    probe + size gate + fallback policy as the namenodes' own
    ``_partitions_for`` (identical results either way)."""
    from .namenode import _with_phash_kernel

    def kern():
        from ..kernels.phash.ops import phash_chains
        return phash_chains(ct.parent_ids, ct.name_hashes, ct.hint_ids,
                            ct.depths, n_partitions)

    def fallback():
        from ..kernels.phash.ref import phash_chain_ref
        return phash_chain_ref(ct.parent_ids, ct.name_hashes, ct.hint_ids,
                               ct.depths, n_partitions)

    (comp, hint_parts, sigs), used_kernel = _with_phash_kernel(
        kern, fallback, n_keys=ct.n)
    return comp, hint_parts, sigs, used_kernel


class BatchPlanner:
    """Plans a trace into partition-aligned, type-sorted batches.

    ``window`` ops are planned at a time (default: enough for several
    batches per alive namenode); planning never moves an op across a
    window boundary, which bounds both reordering distance and the
    columnar working set. Under ``adaptive=True`` the window is live: the
    pipeline reports each executed window back through
    :meth:`observe_window` and the :class:`WindowController` resizes it.

    ``client_cache`` closes the loop: resolution probes hit the client's
    own response-warmed cache first (:class:`HintResolver`), with the
    merged namenode caches (:class:`MultiCacheResolver`) as fallback.
    Without one, the planner degrades to the PR-3 behaviour of reading
    namenode caches directly.
    """

    def __init__(self, cluster: NamenodeCluster, *, batch_size: int = 16,
                 window: Optional[int] = None,
                 pin_all_mutations: bool = False,
                 client_cache: Optional[InodeHintCache] = None,
                 adaptive: bool = False, hint_routing: bool = False,
                 breakers: Any = None):
        self.cluster = cluster
        self.batch_size = max(1, batch_size)
        n_slots = max(1, len(cluster.alive_namenodes()))
        self.n_slots = n_slots
        self.hint_routing = hint_routing
        #: optional admission.BreakerBoard — dealing skips namenodes
        #: whose circuit breaker is open (gray-failure protection)
        self.breakers = breakers
        #: indices the LAST plan_window refused to deal because their
        #: deadline already passed (the pipeline marks them shed)
        self.deadline_shed: List[int] = []
        base = window or self.batch_size * n_slots * 8
        self.window = base
        self.controller: Optional[WindowController] = (
            WindowController(base, min_window=self.batch_size,
                             max_window=base * 4,
                             batch_base=self.batch_size,
                             min_batch=max(1, self.batch_size // 8))
            if adaptive else None)
        # pin_all_mutations survives as an explicit conservative mode (and
        # for A/B tests); the closed-loop pipeline no longer needs it in
        # concurrent mode — windows are execution barriers there, so
        # window-scoped conflict analysis is sound (see
        # PlannedRequestPipeline).
        self.pin_all_mutations = pin_all_mutations
        self.client_cache = client_cache
        self._resolver: Optional[HintResolver] = (
            HintResolver(client_cache) if client_cache is not None else None)
        # the cache persists across runs (and is shared with a DFSClient),
        # so per-run telemetry must be DELTAS against its lifetime
        # counters at planner construction
        self._stale0 = client_cache.stale_overwrites \
            if client_cache is not None else 0
        self._inv0 = client_cache.invalidations \
            if client_cache is not None else 0
        self.report = PlanReport()

    # -- conflict pinning ----------------------------------------------
    @staticmethod
    def _mutation_paths(wop: WorkloadOp, spec: Any
                       ) -> List[Tuple[str, ...]]:
        if spec is None:
            return [tuple(split_path(wop.path))]
        # OpSpec.path_args applies rename's implicit ".mv" destination —
        # the one canonical place that rule lives
        return [tuple(split_path(p)) for p in spec.path_args(wop)]

    def _pin_conflicts(self, wops: Sequence[WorkloadOp],
                       idxs: Sequence[int]
                       ) -> Tuple[Set[int], Set[int], Dict[int, Any]]:
        """Pin every mutation whose path collides with another mutation's
        path in the window — equality, or prefix in either direction (a
        ``mkdirs`` below a path another op creates/deletes must not cross
        it). Checked exactly on the (minority) mutation set's component
        tuples; read-only ops are never pinned.

        Lease-ordered exception (the block-write window rule): same-path
        collisions where EVERY colliding mutation is the same lease-ordered
        op type with the same ``OpSpec.lease_order`` key (e.g. a run of
        add_blocks growing one hot file) stay FREE — the deal's
        submission-stable (partition, type, i) sort already keeps
        same-file ops in submission order (same file ⇒ same hint
        partition and same type), so they can batch with block writes to
        other files instead of being exiled to the ordered queue. Any
        mixed-type or mixed-key collision pins conservatively.

        Returns (pinned, lease_freed, lease_key_of): the pinned set, the
        ops freed under the lease exception, and each freed op's lease
        key — the deal never splits a same-key run across batches, which
        is what makes the exception safe under concurrent execution."""
        muts: List[Tuple[int, Any, List[Tuple[str, ...]]]] = []
        for i in idxs:
            spec = REGISTRY.get(wops[i].op)
            if spec is not None and spec.read_only:
                continue
            muts.append((i, spec, self._mutation_paths(
                wops[i], spec) if spec is not None else []))
        path_count: Dict[Tuple[str, ...], int] = {}
        prefix_count: Dict[Tuple[str, ...], int] = {}
        # per colliding path: the (op name, lease-order key) pairs of its
        # mutations — freeing requires ONE pair, with a real key
        ops_on_path: Dict[Tuple[str, ...], Set[Tuple[str, Any]]] = {}
        for i, spec, paths in muts:
            name = spec.name if spec is not None else "?"
            key = (spec.lease_order(wops[i])
                   if spec is not None and spec.lease_order is not None
                   else None)
            for p in paths:
                path_count[p] = path_count.get(p, 0) + 1
                ops_on_path.setdefault(p, set()).add((name, key))
                for k in range(1, len(p)):
                    pref = p[:k]
                    prefix_count[pref] = prefix_count.get(pref, 0) + 1
        pinned: Set[int] = set()
        lease_freed: Set[int] = set()
        lease_key_of: Dict[int, Any] = {}
        for i, spec, paths in muts:
            # unknown/0-path ops cannot be reasoned about; destructive ops
            # (delete/rename/truncate/concat) must never be hopped over by
            # a read that the trace issued before them: keep in order.
            # pin_all_mutations (explicit conservative mode) pins every
            # mutation.
            if self.pin_all_mutations or spec is None or spec.paths == 0 \
                    or spec.destructive:
                pinned.add(i)
                continue
            freed = False
            for p in paths:
                if prefix_count.get(p, 0) > 0 \
                        or any(p[:k] in path_count
                               for k in range(1, len(p))):
                    pinned.add(i)
                    break
                if path_count.get(p, 0) > 1:
                    pairs = ops_on_path[p]
                    if len(pairs) == 1 and spec.lease_order is not None \
                            and next(iter(pairs))[1] is not None:
                        freed = True            # same-file, same-key run
                        continue
                    pinned.add(i)
                    break
            if freed and i not in pinned:
                lease_freed.add(i)
                lease_key_of[i] = spec.lease_order(wops[i])
        return pinned, lease_freed, lease_key_of

    def _routable_slot(self, slot: int, alive: Sequence[Any]) -> int:
        """Breaker-aware dealing (docs/ROBUSTNESS.md): skip slots whose
        namenode has an OPEN circuit breaker — a tripped namenode stops
        receiving free chunks — falling to the deterministic next slot.
        Half-open breakers admit exactly their probe budget (``routable``
        consumes a probe per dealt batch). If the whole fleet tripped,
        the original slot is kept: routing must proceed somewhere, and
        the breakers re-probe as their reset timers expire."""
        if self.breakers is None or not alive:
            return slot
        n = len(alive)
        slot %= n
        for d in range(n):
            k = (slot + d) % n
            if self.breakers.routable(alive[k].nn_id):
                if d:
                    self.report.breaker_rerouted += 1
                return k
        return slot

    @staticmethod
    def _warm_slot(path: str, alive: Sequence[Any]) -> Optional[int]:
        """Slot index (into the alive list) of the first namenode whose
        hint cache resolves ``path``'s full chain — side-effect-free
        peeks, mirroring ``RequestPipeline._warm_namenode``."""
        from .tables import ROOT_ID
        comps = split_path(path)
        if not comps:
            return None
        for k, nn in enumerate(alive):
            cache = nn.ops.cache
            if cache is None:
                continue
            parent: Optional[int] = ROOT_ID
            for name in comps:
                parent = cache.peek(parent, name)
                if parent is None:
                    break
            if parent is not None:
                return k
        return None

    # -- planning -------------------------------------------------------
    def plan_window(self, wops: Sequence[WorkloadOp], lo: int, hi: int
                    ) -> List[PlannedBatch]:
        """Plan ONE window of the trace (global indices [lo, hi)). The
        closed-loop pipeline calls this per window — executing and
        absorbing response hints between calls — so each window resolves
        against the freshest client cache state."""
        n_partitions = self.cluster.store.n_partitions
        # membership is LIVE under the elastic pool: re-derive the slot
        # count per window so dealt batches spread over the namenodes
        # alive NOW (on a static fleet this is the frozen constructor
        # value). run_window maps slots onto the current alive list, so
        # a fleet that shrank between plan and execute stays safe.
        alive = self.cluster.alive_namenodes()
        self.n_slots = max(1, len(alive))
        fallback = MultiCacheResolver.of_cluster(self.cluster)
        if self._resolver is not None:
            self._resolver.fallback = fallback
            resolver: Any = self._resolver
        else:
            resolver = fallback
        batches: List[PlannedBatch] = []
        self.report.ops += hi - lo
        window = list(range(lo, hi))
        # deadline-aware dealing: deal only ops that can still make
        # their deadline — expired ops are shed client-side, sparing the
        # fleet a round trip that could not produce useful work
        now = self.cluster.election.now
        self.deadline_shed = [i for i in window
                              if wops[i].deadline is not None
                              and now > wops[i].deadline]
        if self.deadline_shed:
            self.report.deadline_shed += len(self.deadline_shed)
            expired = set(self.deadline_shed)
            window = [i for i in window if i not in expired]
        if not window:
            self.report.windows += 1
            self.report.window_sizes.append(hi - lo)
            self._refresh_client_telemetry()
            return batches
        # fused hint-chain resolution: one hintchain launch walks every
        # op's cached parent chain (bit-equivalent to the Python loop,
        # which small windows and non-HintResolver resolvers fall back to)
        ct, used_hintchain = lower_trace_fused(
            [wops[i] for i in window], resolver)
        if used_hintchain:
            self.report.hintchain_launches += 1
        # grouped-batch PK validation: one pkval launch checks every
        # client-resolved chain against the columnar store's hash index;
        # stale chains are demoted BEFORE the conflict/pinning pass so
        # they ride the exact sequential path (dict backend: no-op)
        validated = validate_window_pks(self.cluster.store, ct)
        if validated is not None:
            demoted, n_probes, used_pkval = validated
            self.report.pkval_probes += n_probes
            if used_pkval:
                self.report.pkval_launches += 1
            for k in demoted:
                self.report.pkval_demotions += 1
                ct.resolved[k] = False
                ct.pks[k] = None
                ct.target_ids[k] = None
        # _sigs: the kernel's path-equality probe, no consumer here yet
        comp_parts, hint_parts, _sigs, used_kernel = _chain_partitions(
            ct, n_partitions)
        if used_kernel:
            self.report.kernel_launches += 1
        pinned, lease_freed, lease_key_of = self._pin_conflicts(wops, window)
        # ops whose chain did NOT resolve client-side stay in
        # submission order too — an unresolved read (or create) may
        # target a path another op in this window creates, and
        # hopping over that op would spuriously fail it. Unresolved
        # ops cannot group anyway, so ordering them costs nothing.
        for k, i in enumerate(window):
            if not ct.resolved[k]:
                pinned.add(i)
                lease_freed.discard(i)
        self.report.lease_ordered_ops += len(lease_freed)
        hints: Dict[int, Optional[PlanHint]] = {}
        parts: Dict[int, int] = {}
        n_groups = self.cluster.store.n_groups
        for k, i in enumerate(window):
            parts[i] = int(hint_parts[k])
            self.report.partitions_seen.add(parts[i])
            if ct.resolved[k]:
                hints[i] = PlanHint(pks=ct.pks[k],
                                    target_id=ct.target_ids[k],
                                    hint_id=int(ct.hint_ids[k]))
                self.report.planned_ops += 1
                # client-side locality forecast: which of this op's
                # component rows share the coordinator's node group
                d = int(ct.depths[k])
                coord_g = parts[i] % n_groups
                self.report.predicted_local += sum(
                    1 for j in range(d)
                    if int(comp_parts[k, j]) % n_groups == coord_g)
                self.report.predicted_total += d
            else:
                hints[i] = None
        type_of = {i: int(ct.type_ids[k])
                   for k, i in enumerate(window)}
        # free ops: partition-aligned, type-sorted, submission-stable.
        # Lease-freed ops are anchored at their key's FIRST submission
        # index, so one file's block-write run is contiguous in the deal
        # order even when another same-partition file's ops interleave
        # with it in the trace — without the anchor, the cut-extension
        # below could not keep such a run whole (its pieces could land in
        # batches routed to different slots and execute concurrently).
        # Reordering across distinct keys is safe: freed ops collide only
        # within their own key, and within a key the i tiebreak keeps
        # submission order.
        anchor: Dict[int, int] = {}
        first_of_key: Dict[Any, int] = {}
        for i in sorted(lease_freed):
            k = lease_key_of[i]
            first_of_key.setdefault(k, i)
            anchor[i] = first_of_key[k]
        free = [i for i in window if i not in pinned]
        free.sort(key=lambda i: (parts[i], type_of[i],
                                 anchor.get(i, i), i))
        c = 0
        while c < len(free):
            end = min(c + self.batch_size, len(free))
            # never cut inside a lease-ordered same-key run: all block
            # writes to one file land in ONE (possibly oversized) batch,
            # executed by one namenode in submission order — so
            # concurrent workers (and work stealing) can never interleave
            # same-file block writes, while distinct files still deal to
            # distinct batches and run concurrently
            while 0 < end < len(free) and free[end - 1] in lease_freed \
                    and free[end] in lease_freed \
                    and lease_key_of[free[end - 1]] \
                    == lease_key_of[free[end]]:
                end += 1
            chunk = free[c:end]
            c = end
            slot = parts[chunk[0]] % self.n_slots
            if self.hint_routing and len(alive) > 1:
                # deal to the namenode already warm for this chunk's lead
                # path; the partition hash stays the cold-path fallback
                warm = self._warm_slot(wops[chunk[0]].path, alive)
                if warm is not None:
                    slot = warm
                    self.report.hint_routed_batches += 1
            slot = self._routable_slot(slot, alive)
            mutates = any(
                (s := REGISTRY.get(wops[i].op)) is None or not s.read_only
                for i in chunk)
            batches.append(PlannedBatch(
                indices=chunk, hints=[hints[i] for i in chunk],
                nn_slot=slot, mutates=mutates))
        # pinned mutations LAST, strictly in submission order: free
        # reads of a window never spuriously fail against a
        # destructive op the trace issued later (a read the trace
        # issued after the delete may now succeed instead — benign,
        # final state is unaffected by reads)
        pin_order = [i for i in window if i in pinned]
        self.report.pinned_ops += len(pin_order)
        pin_slot = self._routable_slot(0, alive)
        for c in range(0, len(pin_order), self.batch_size):
            chunk = pin_order[c:c + self.batch_size]
            batches.append(PlannedBatch(
                indices=chunk, hints=[hints[i] for i in chunk],
                nn_slot=pin_slot, ordered=True))
        self.report.windows += 1
        self.report.window_sizes.append(hi - lo)
        self.report.batches += len(batches)
        self._refresh_client_telemetry()
        return batches

    def _refresh_client_telemetry(self) -> None:
        """Copy the resolver's probe counters (per-planner, so per-run)
        and the cache's staleness counters (per-run DELTAS — the cache
        outlives runs) into the report."""
        if self._resolver is not None:
            self.report.client_hits = self._resolver.hits
            self.report.client_fallback_hits = self._resolver.fallback_hits
            self.report.client_misses = self._resolver.misses
        if self.client_cache is not None:
            self.report.client_stale = \
                self.client_cache.stale_overwrites - self._stale0
            self.report.client_invalidations = \
                self.client_cache.invalidations - self._inv0

    def observe_window(self, *, ops: int, pinned: int,
                       round_trips: int,
                       lock_wait_frac: float = 0.0) -> int:
        """Close the feedback loop after a window executed (and its hints
        were absorbed): the controller resizes the live window from the
        observed pin rate and measured round trips per op (no-op on a
        fixed window), and the client telemetry snapshot is refreshed so
        the final window's absorptions are counted too.
        ``lock_wait_frac`` is the window's measured lock-wait fraction
        (store-level ``wait_count``/``acquire_count`` deltas) — the signal
        the controller's second knob AIMD-adapts ``batch_size`` from."""
        self._refresh_client_telemetry()
        if self.controller is not None:
            self.window = self.controller.observe(
                ops, pinned, round_trips, lock_wait_frac=lock_wait_frac)
            if self.controller.batch_size is not None:
                self.batch_size = self.controller.batch_size
        return self.window

    def plan(self, wops: Sequence[WorkloadOp]) -> List[PlannedBatch]:
        """Plan a whole trace at the current (fixed) window size — the
        open-loop entry point, kept for direct planner use and tests. The
        closed-loop pipeline drives :meth:`plan_window` instead."""
        batches: List[PlannedBatch] = []
        for lo in range(0, len(wops), self.window):
            batches.extend(
                self.plan_window(wops, lo, min(lo + self.window,
                                               len(wops))))
        return batches


class PlannedRequestPipeline(RequestPipeline):
    """A :class:`RequestPipeline` whose dealing is driven by the client-side
    plan instead of FIFO slicing: each namenode receives partition-aligned,
    type-sorted batches with planner hints attached, so ``execute_batch``
    sees maximal groupable runs (reads AND group-mutable writes) and its
    shared transactions land on their coordinator's node group.

    The run loop is **closed-loop per window**: plan one window against
    the client's own hint cache, execute its batches, absorb the
    response-piggybacked hints (and invalidate on destructive ops), let
    the :class:`WindowController` resize the window, then plan the next.
    Windows are therefore execution BARRIERS, which is what makes
    window-scoped conflict analysis sound in concurrent mode — conflicts
    cannot span windows because no two windows are ever in flight at once.

    ``concurrent=False`` executes batches in plan order (deterministic);
    ``concurrent=True`` runs one worker per alive namenode over per-slot
    queues WITHIN each window — order-pinned batches all live on one
    queue, preserving their relative order, and same-file block-write runs
    are never split across batches (lease order), so distinct-file block
    writes group concurrently while same-path collisions stay ordered.
    Ops on a namenode that dies mid-batch fail over to the survivors
    exactly like the reactive pipeline (§7.6.1)."""

    def __init__(self, cluster: NamenodeCluster, *, batch_size: int = 16,
                 concurrent: bool = False, window: Optional[int] = None,
                 client_cache: Optional[InodeHintCache] = None,
                 adaptive: bool = True, pool: Any = None,
                 hint_routing: Optional[bool] = None,
                 admission: Any = None, breakers: Any = None):
        super().__init__(cluster, batch_size=batch_size,
                         concurrent=concurrent)
        self.window = window
        self.adaptive = adaptive
        #: optional admission.AdmissionController — fed the remaining
        #: queue depth per window (its pressure signal); the controller
        #: itself must be install()ed on the cluster by the caller
        self.admission = admission
        #: optional admission.BreakerBoard — batches are dealt away from
        #: open-breaker namenodes and every batch outcome is recorded
        self.breakers = breakers
        #: the client-side hint cache, persistent across run() calls (and
        #: shareable with a DFSClient so facade calls warm it too)
        self.client_cache = (client_cache if client_cache is not None
                             else InodeHintCache())
        #: elastic pool driving membership (optional): ticked once per
        #: executed window with the remaining queue depth so scale
        #: decisions ride the replay's own logical clock
        self.pool = pool
        # warm-NN routing defaults ON exactly when membership is elastic —
        # a pool invalidates the static partition→namenode affinity, and
        # on a fixed fleet the partition hash already IS the warm slot
        self.hint_routing = (hint_routing if hint_routing is not None
                             else pool is not None)
        self.planner: Optional[BatchPlanner] = None

    @property
    def plan_report(self) -> Optional[PlanReport]:
        return self.planner.report if self.planner else None

    # -- closing the loop ----------------------------------------------
    def _absorb_window(self, wops: Sequence[WorkloadOp],
                       outcomes: Sequence[Optional[OpOutcome]],
                       lo: int, hi: int) -> int:
        """Absorb the executed window's piggybacked hints into the client
        cache (the shared :func:`~repro.core.hint_cache.absorb_response`
        rule: invalidate-on-destructive per op, then warm), and return
        the window's measured DB round trips for the controller."""
        round_trips = 0
        for i in range(lo, hi):
            oc = outcomes[i]
            if oc is None or not oc.ok:
                continue
            round_trips += oc.result.cost.round_trips
            absorb_response(self.client_cache, wops[i],
                            REGISTRY.get(wops[i].op), oc.result.hints)
        return round_trips

    def run(self, wops: Sequence[WorkloadOp]) -> PipelineStats:
        import time
        wops = list(wops)
        if not self.cluster.alive_namenodes():
            raise StoreError("no alive namenodes")
        self.planner = BatchPlanner(self.cluster,
                                    batch_size=self.batch_size,
                                    window=self.window,
                                    client_cache=self.client_cache,
                                    adaptive=self.adaptive,
                                    hint_routing=self.hint_routing,
                                    breakers=self.breakers)
        planner = self.planner
        outcomes: List[Optional[OpOutcome]] = [None] * len(wops)
        residual: deque = deque()      # ops orphaned by namenode deaths
        rlock = threading.Lock()
        n_batches = [0]
        cost0 = {nn.nn_id: nn.agg_cost.copy()
                 for nn in self.cluster.namenodes}
        served0 = {nn.nn_id: nn.ops_served
                   for nn in self.cluster.namenodes}

        def run_batch(nn, batch: PlannedBatch) -> bool:
            """Execute one planned batch; False if the namenode died (its
            unfinished ops go to the residual queue)."""
            try:
                res = nn.execute_batch([wops[i] for i in batch.indices],
                                       hints=batch.hints)
            except StoreError:
                if self.breakers is not None:
                    self.breakers.record(nn.nn_id, ok=False)
                with rlock:
                    residual.extend(batch.indices)
                return False
            died = []
            for i, oc in zip(batch.indices, res):
                if not oc.ok and oc.error == "StoreError" and not nn.alive:
                    died.append(i)
                else:
                    outcomes[i] = oc
            if self.breakers is not None:
                # transport-class outcomes trip the breaker; genuine FS
                # outcomes count as proof of health
                from .admission import BREAKER_FAILURES
                sick = bool(died) or any(
                    oc is not None and not oc.ok
                    and oc.error in BREAKER_FAILURES for oc in res)
                self.breakers.record(nn.nn_id, ok=not sick)
            if died:
                with rlock:
                    residual.extend(died)
            with rlock:
                n_batches[0] += 1
            return not died

        def run_window(batches: List[PlannedBatch]) -> None:
            if not self.concurrent:
                for batch in batches:
                    alive = self.cluster.alive_namenodes()
                    if not alive:
                        return
                    run_batch(alive[batch.nn_slot % len(alive)], batch)
                return
            alive = self.cluster.alive_namenodes()
            if not alive:
                return
            # free batches fan out across one worker per namenode;
            # order-pinned batches run AFTER the workers join, exactly
            # where deterministic mode runs them (last in the window) —
            # pinned mutations therefore observe the same pre-state in
            # both modes
            free_batches = [b for b in batches if not b.ordered]
            queues: List[deque] = [deque() for _ in alive]
            qlock = threading.Lock()
            for batch in free_batches:
                queues[batch.nn_slot % len(alive)].append(batch)

            def pull(k: int) -> Optional[PlannedBatch]:
                with qlock:
                    if queues[k]:
                        return queues[k].popleft()
                    # steal READ-ONLY work, longest donor first —
                    # mutating batches stay on their home slot so a
                    # partition's writes always hit the namenode whose
                    # hint cache is warm for it (grouped-write engagement
                    # matches deterministic mode); a non-stealable tail
                    # must not blind us to other donors
                    for j in sorted(range(len(queues)),
                                    key=lambda q: -len(queues[q])):
                        if queues[j] and not queues[j][-1].mutates:
                            return queues[j].pop()
                    return None

            def drain(k: int, nn) -> None:
                while True:
                    batch = pull(k)
                    if batch is None:
                        return
                    if not run_batch(nn, batch):
                        with qlock:                     # orphan my queue
                            while queues[k]:
                                b = queues[k].popleft()
                                with rlock:
                                    residual.extend(b.indices)
                        return

            workers = [threading.Thread(target=drain, args=(k, nn))
                       for k, nn in enumerate(alive)]
            for w in workers:
                w.start()
            for w in workers:
                w.join()
            for batch in batches:
                if not batch.ordered:
                    continue
                alive = self.cluster.alive_namenodes()
                if not alive:
                    return
                run_batch(alive[batch.nn_slot % len(alive)], batch)

        def drain_residual() -> None:
            # failover pass: re-deal orphaned ops to survivors, reactive
            while residual:
                alive = self.cluster.alive_namenodes()
                if not alive:
                    return
                idxs = [residual.popleft()
                        for _ in range(min(self.batch_size,
                                           len(residual)))]
                run_batch(alive[n_batches[0] % len(alive)],
                          PlannedBatch(indices=idxs,
                                       hints=[None] * len(idxs),
                                       nn_slot=0))

        locks = self.cluster.store.locks
        t0 = time.perf_counter()
        lo = 0
        while lo < len(wops):
            if not self.cluster.alive_namenodes():
                break
            hi = min(lo + planner.window, len(wops))
            if self.admission is not None:
                # backlog report: the admission controllers' pressure
                # signal for WFQ load shedding
                self.admission.observe_queue(len(wops) - lo)
            pinned_before = planner.report.pinned_ops
            w0, a0 = locks.wait_count, locks.acquire_count
            batches = planner.plan_window(wops, lo, hi)
            # ops the planner refused to deal (deadline already passed)
            # are shed client-side — no round trip, no execution
            for i in planner.deadline_shed:
                outcomes[i] = OpOutcome(None, "DeadlineExpired")
            run_window(batches)
            drain_residual()
            rts = self._absorb_window(wops, outcomes, lo, hi)
            acquired = locks.acquire_count - a0
            planner.observe_window(
                ops=hi - lo,
                pinned=planner.report.pinned_ops - pinned_before,
                round_trips=rts,
                lock_wait_frac=((locks.wait_count - w0) / acquired
                                if acquired else 0.0))
            self.batch_size = planner.batch_size
            if self.pool is not None:
                self.pool.tick(queue_depth=len(wops) - hi)
            lo = hi
        wall = time.perf_counter() - t0
        for i, oc in enumerate(outcomes):
            if oc is None:
                outcomes[i] = OpOutcome(None, "StoreError")
        return self._finalize_stats(wops, outcomes, cost0, served0, wall,
                                    n_batches[0])
