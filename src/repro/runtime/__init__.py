from .fleet import FleetRuntime, WorkerState, elastic_remesh
