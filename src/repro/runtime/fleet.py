"""Fleet runtime: heartbeats, failover, elastic re-meshing.

The control loop a 1000-node deployment runs around the train step:

  * every worker heartbeats through the metadata plane's leader-election
    table (the paper's "alive = can write to the DB in bounded time");
  * the LEADER worker runs housekeeping (checkpoint GC, shard re-dispatch);
  * on worker loss: the fleet shrinks to the largest usable mesh
    (data-axis multiple), restores the latest committed checkpoint, and
    continues — `elastic_remesh` computes the new (data, model) shape;
  * on worker join: grow at the next checkpoint boundary.

This module is deliberately jax-free (pure control plane) so it is testable
deterministically; launch/train.py wires it to real pjit steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from ..core.leader import LeaderElection
from ..metaplane import MetadataPlane


@dataclass
class WorkerState:
    worker_id: int
    alive: bool = True
    step: int = 0


def elastic_remesh(n_workers: int, *, model_axis: int,
                   chips_per_worker: int = 4) -> Tuple[int, int]:
    """Largest (data, model) mesh using <= n_workers * chips_per_worker
    chips with the fixed model axis (TP degree is pinned by weight shapes;
    DP shrinks/grows elastically)."""
    chips = n_workers * chips_per_worker
    data = max(1, chips // model_axis)
    # data axis must divide the global batch in the caller; round down to a
    # power of two for predictable batch slicing
    p = 1
    while p * 2 <= data:
        p *= 2
    return p, model_axis


class FleetRuntime:
    def __init__(self, plane: MetadataPlane, n_workers: int, *,
                 model_axis: int = 16, chips_per_worker: int = 4,
                 hb_timeout: int = 2):
        self.plane = plane
        self.election = LeaderElection(plane.store, max_missed=hb_timeout)
        self.workers = {i: WorkerState(i) for i in range(n_workers)}
        self.model_axis = model_axis
        self.chips_per_worker = chips_per_worker
        self.mesh_shape = elastic_remesh(
            n_workers, model_axis=model_axis,
            chips_per_worker=chips_per_worker)
        self.remesh_events: List[Tuple[int, Tuple[int, int]]] = []
        self.now = 0
        for w in self.workers.values():
            self.election.heartbeat(w.worker_id)

    # -- heartbeat round ----------------------------------------------------
    def tick(self) -> None:
        self.now += 1
        self.election.tick()
        for w in self.workers.values():
            if w.alive:
                self.election.heartbeat(w.worker_id)

    def alive_workers(self) -> List[int]:
        return [w.worker_id for w in self.workers.values() if w.alive]

    def leader(self) -> Optional[int]:
        return self.election.leader()

    # -- failures / elasticity -----------------------------------------------
    def fail_worker(self, worker_id: int) -> None:
        self.workers[worker_id].alive = False

    def join_worker(self, worker_id: int) -> None:
        self.workers.setdefault(worker_id, WorkerState(worker_id))
        self.workers[worker_id].alive = True
        self.election.heartbeat(worker_id)

    def maybe_remesh(self) -> Optional[Tuple[int, int]]:
        """Called after heartbeats: if the alive set no longer matches the
        mesh, compute the new mesh and signal a restore-from-checkpoint."""
        n = len(self.alive_workers())
        new = elastic_remesh(n, model_axis=self.model_axis,
                             chips_per_worker=self.chips_per_worker)
        if new != self.mesh_shape:
            self.mesh_shape = new
            self.remesh_events.append((self.now, new))
            return new
        return None
