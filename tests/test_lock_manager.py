"""Striped LockManager: per-stripe mutexes, per-txn held-locks index
(O(locks held) release), waiter-safe entry reclamation, timeout behavior.
The concurrent request pipeline runs one thread per namenode against this
lock table, so these invariants are what test_batched_pipeline's
contention test leans on."""
import threading
import time

import pytest

from repro.core.store import (EXCLUSIVE, LockManager, LockTimeout,
                              READ_COMMITTED, SHARED)


def test_basic_shared_exclusive():
    lm = LockManager(timeout=0.05)
    lm.acquire(1, "inode", (1, "a"), SHARED)
    lm.acquire(2, "inode", (1, "a"), SHARED)     # shared coexists
    assert lm.held("inode", (1, "a")) == SHARED
    with pytest.raises(LockTimeout):
        lm.acquire(3, "inode", (1, "a"), EXCLUSIVE)
    lm.release_all(1)
    lm.release_all(2)
    lm.acquire(3, "inode", (1, "a"), EXCLUSIVE)
    assert lm.held("inode", (1, "a")) == EXCLUSIVE
    lm.release_all(3)
    assert lm.held("inode", (1, "a")) is None


def test_read_committed_takes_no_lock():
    lm = LockManager()
    lm.acquire(1, "inode", (1, "a"), READ_COMMITTED)
    assert lm.held("inode", (1, "a")) is None
    assert lm.held_count(1) == 0


def test_reentrant_and_upgrade():
    lm = LockManager(timeout=0.05)
    lm.acquire(1, "inode", (1, "a"), SHARED)
    lm.acquire(1, "inode", (1, "a"), EXCLUSIVE)  # sole holder may upgrade
    assert lm.held("inode", (1, "a")) == EXCLUSIVE
    assert lm.held_count(1) == 1                 # one row, one index entry
    lm.release_all(1)


def test_release_all_is_indexed_per_txn():
    """release_all walks only the txn's own held-locks index — other
    transactions' locks (any number of them) stay untouched."""
    lm = LockManager()
    n_other = 500
    for i in range(n_other):
        lm.acquire(100 + i, "inode", (i, "x"), EXCLUSIVE)
    lm.acquire(1, "inode", (9999, "mine"), EXCLUSIVE)
    lm.acquire(1, "block", (7,), SHARED)
    assert lm.held_count(1) == 2
    lm.release_all(1)
    assert lm.held_count(1) == 0
    assert lm.held("inode", (9999, "mine")) is None
    # everyone else still holds theirs
    for i in range(0, n_other, 97):
        assert lm.held("inode", (i, "x")) == EXCLUSIVE
    for i in range(n_other):
        lm.release_all(100 + i)
    assert all(not d for d in lm._locks)         # table fully reclaimed


def test_timeout_cleans_orphan_entry():
    lm = LockManager(timeout=0.02)
    lm.acquire(1, "inode", (1, "a"), EXCLUSIVE)
    with pytest.raises(LockTimeout):
        lm.acquire(2, "inode", (1, "a"), EXCLUSIVE)
    lm.release_all(1)
    assert all(not d for d in lm._locks)         # no leaked entries


def test_blocked_acquire_wakes_on_release():
    lm = LockManager(timeout=2.0)
    lm.acquire(1, "inode", (1, "a"), EXCLUSIVE)
    got = []

    def waiter():
        lm.acquire(2, "inode", (1, "a"), EXCLUSIVE)
        got.append(time.monotonic())
        lm.release_all(2)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    lm.release_all(1)
    t.join(timeout=2.0)
    assert got and got[0] - t0 < 0.5             # woke promptly, not at
    assert not t.is_alive()                      # the 2s timeout


def test_striped_concurrency_no_lost_locks():
    """Many threads acquiring/releasing across many rows concurrently:
    every acquisition is exclusive-correct (a shared counter per row never
    sees two writers) and the table drains clean."""
    lm = LockManager(timeout=5.0, n_stripes=8)
    rows = [("inode", (i, "r")) for i in range(16)]
    owners = {pk: 0 for _t, pk in rows}
    errs = []

    def worker(txn_id: int) -> None:
        try:
            for k in range(40):
                tname, pk = rows[(txn_id * 7 + k) % len(rows)]
                lm.acquire(txn_id, tname, pk, EXCLUSIVE)
                owners[pk] += 1
                assert owners[pk] == 1, "two writers on one row!"
                owners[pk] -= 1
                lm.release_all(txn_id)
        except Exception as e:                    # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i + 1,))
               for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert all(not d for d in lm._locks)
    assert not lm._held
