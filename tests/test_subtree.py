"""Subtree operations protocol tests (paper §6): isolation, batching,
failure consistency, lock reclaim."""
import pytest

from repro.core import (HopsFSOps, MetadataStore, SubtreeLockedError,
                        SubtreeOps, format_fs)


@pytest.fixture
def fs():
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    return HopsFSOps(store, 0)


def build_tree(fs, root="/proj", dirs=3, files=4, depth=2):
    fs.mkdirs(root)
    total = 1

    def rec(base, d):
        nonlocal total
        for i in range(files):
            fs.create(f"{base}/file{i}")
            total += 1
        if d < depth:
            for j in range(dirs):
                sub = f"{base}/dir{j}"
                fs.mkdir(sub)
                total += 1
                rec(sub, d + 1)
    rec(root, 1)
    return total


def test_delete_subtree_counts_and_cleans(fs):
    n = build_tree(fs)
    st = SubtreeOps(fs.ops if hasattr(fs, "ops") else fs)
    res = st.delete_subtree("/proj")
    assert res.value["deleted"] == n
    assert fs.listing("/").value == []
    assert fs.store.table("ongoing_subtree_ops").n_rows == 0


def test_delete_subtree_batched_transactions(fs):
    build_tree(fs)
    st = SubtreeOps(fs, batch_size=5)
    res = st.delete_subtree("/proj")
    # phase 3 executed in many small txns: round trips far exceed one
    # txn's worth but no txn touched more than batch_size inodes
    assert res.value["deleted"] > 5
    assert res.cost.round_trips > 10


def test_chmod_subtree_updates_root_only(fs):
    build_tree(fs)
    st = SubtreeOps(fs)
    st.chmod_subtree("/proj", 0o700)
    assert fs.stat("/proj").value["perm"] == 0o700
    # inner inodes untouched (paper §6.2) and lock released
    assert fs.stat("/proj/file0").value["perm"] == 0o755
    assert fs.store.table("inode").scan_index("id", 2)[0][
        "subtree_lock"] is None


def test_rename_subtree_preserves_descendants(fs):
    build_tree(fs)
    st = SubtreeOps(fs)
    st.rename_subtree("/proj", "/moved")
    assert "file0" in fs.listing("/moved").value
    assert "dir0" in fs.listing("/moved").value
    assert fs.listing("/moved/dir0").value  # children intact


def test_concurrent_inode_op_aborts_under_subtree_lock(fs):
    build_tree(fs)
    # another namenode is mid-subtree-op: lock flag set, NN 1 alive
    alive = {0, 1}
    fs._is_nn_alive = lambda nn: nn in alive
    root = fs.store.table("inode").get((1, "proj"))
    locked = dict(root)
    locked["subtree_lock"] = 1
    fs.store.table("inode").put(locked)
    with pytest.raises(SubtreeLockedError):
        fs.create("/proj/new-file")


def test_dead_namenode_lock_is_reclaimed(fs):
    build_tree(fs)
    fs._is_nn_alive = lambda nn: nn == 0          # NN 9 is dead
    root = fs.store.table("inode").get((1, "proj"))
    locked = dict(root)
    locked["subtree_lock"] = 9
    fs.store.table("inode").put(locked)
    fs.create("/proj/new-file")                    # reclaims + proceeds §6.2
    assert fs.store.table("inode").get((1, "proj"))["subtree_lock"] is None


def test_crashed_delete_leaves_consistent_tree(fs):
    """§6.2: post-order delete + crash => no orphans; remainder intact;
    retry on another namenode completes."""
    n = build_tree(fs)
    st = SubtreeOps(fs, batch_size=4, crash_after_batches=2)
    res = st.delete_subtree("/proj")
    assert res.value["crashed"]
    deleted = res.value["deleted"]
    assert 0 < deleted < n
    # every surviving inode is still reachable from the root (no orphans)
    t = fs.store.table("inode")
    survivors = t.scan_all(lambda r: r["id"] != 1)
    ids = {r["id"] for r in survivors} | {1}
    for r in survivors:
        assert r["parent_id"] in ids, f"orphan: {r}"
    # another namenode reclaims the dead NN's lock and finishes the job
    fs2 = HopsFSOps(fs.store, 1, is_nn_alive=lambda nn: nn == 1)
    st2 = SubtreeOps(fs2)
    res2 = st2.delete_subtree("/proj")
    assert res2.value["deleted"] == n - deleted
