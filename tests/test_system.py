"""End-to-end behaviour tests: metadata plane, checkpoint/restart,
failover, elasticity, data pipeline, cluster DES, serving engine."""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import Client, MetadataStore, NamenodeCluster, format_fs
from repro.core.cluster_sim import HDFSSim, HopsFSSim, profile_ops
from repro.core.workload import (NamespaceSpec, SpotifyWorkload,
                                 SyntheticNamespace)
from repro.ckpt import CheckpointManager
from repro.data import DataPipeline
from repro.metaplane import MetadataPlane
from repro.models import init_params, param_specs
from repro.runtime import FleetRuntime, elastic_remesh


# ---------------------------------------------------------------------------
# namenode fleet behaviour (paper §3, §7.6)
# ---------------------------------------------------------------------------

def test_multiple_namenodes_share_one_namespace():
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 3)
    c = Client(cluster, policy="round_robin")
    c.execute("mkdirs", "/a/b")
    c.execute("create", "/a/b/f1")       # possibly a different namenode
    assert c.execute("ls", "/a/b").value == ["f1"]
    served = [nn.ops_served for nn in cluster.namenodes]
    assert sum(served) >= 3


def test_client_failover_is_transparent():
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 3)
    c = Client(cluster, policy="sticky", seed=1)
    c.execute("mkdirs", "/x")
    sticky = c._sticky
    cluster.kill(sticky)
    cluster.tick()
    cluster.tick()
    cluster.tick()
    r = c.execute("create", "/x/after-failover")   # no exception = no downtime
    assert r.value
    assert c._sticky != sticky       # client silently moved off the dead NN


def test_leader_election_moves_off_dead_namenode():
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 3)
    assert cluster.leader().nn_id == 0
    cluster.kill(0)
    for _ in range(4):
        cluster.tick()
    assert cluster.leader().nn_id == 1


def test_ndb_node_failure_tolerated_with_replica():
    store = MetadataStore(n_datanodes=4, replication=2)
    format_fs(store)
    cluster = NamenodeCluster(store, 2)
    c = Client(cluster)
    c.execute("mkdirs", "/p")
    store.fail_datanode(0)               # group 0 keeps one replica
    c.execute("create", "/p/f")
    assert c.execute("ls", "/p").value == ["f"]


# ---------------------------------------------------------------------------
# metadata plane + checkpoint/restart
# ---------------------------------------------------------------------------

def test_checkpoint_commit_is_atomic_and_restorable():
    plane = MetadataPlane()
    cm = CheckpointManager(tempfile.mkdtemp(), plane, "j", keep=2)
    params = {"w": np.arange(6.0).reshape(2, 3)}
    opt = {"mu": {"w": np.zeros((2, 3))}, "step": np.int32(5)}
    cm.save(100, params, opt)
    step, p, o = cm.restore_latest()
    assert step == 100
    np.testing.assert_array_equal(p["w"], params["w"])
    man = plane.manifest("j", 100)
    assert man.complete and "params/w" in man.shards


def test_checkpoint_gc_uses_subtree_delete():
    plane = MetadataPlane()
    cm = CheckpointManager(tempfile.mkdtemp(), plane, "j2", keep=1)
    p = {"w": np.ones(2)}
    for s in (1, 2, 3):
        cm.save(s, p, {"m": np.zeros(2)})
    names = plane.client.execute("ls", "/ckpt/j2").value
    assert names == ["step-00000003"]


def test_restore_ignores_uncommitted_tmp():
    plane = MetadataPlane()
    cm = CheckpointManager(tempfile.mkdtemp(), plane, "j3", keep=3)
    cm.save(7, {"w": np.ones(1)}, {"m": np.ones(1)})
    # a crashed writer left a .tmp tree for step 9
    base = plane.begin_checkpoint("j3", 9)
    plane.add_shard(base, "params/w", 0)
    assert plane.latest_checkpoint("j3") == 7


# ---------------------------------------------------------------------------
# elastic runtime + stragglers
# ---------------------------------------------------------------------------

def test_elastic_remesh_shapes():
    assert elastic_remesh(128, model_axis=16, chips_per_worker=4) == (32, 16)
    assert elastic_remesh(127, model_axis=16, chips_per_worker=4) == (16, 16)
    assert elastic_remesh(3, model_axis=4, chips_per_worker=4) == (2, 4)


def test_fleet_failover_and_rejoin():
    plane = MetadataPlane()
    fleet = FleetRuntime(plane, 8, model_axis=4, chips_per_worker=4)
    assert fleet.mesh_shape == (8, 4)
    fleet.fail_worker(3)
    fleet.tick()
    assert fleet.maybe_remesh() == (4, 4)
    fleet.join_worker(3)
    fleet.tick()
    assert fleet.maybe_remesh() == (8, 4)
    assert fleet.remesh_events


def test_straggler_redispatch_and_idempotent_completion():
    plane = MetadataPlane()
    dp = DataPipeline(plane, "ds", n_shards=3, hb_timeout=2)
    s0 = dp.lease(0)
    dp.lease(1)
    dp.lease(1)
    assert dp.lease(2) is None           # all leased
    for _ in range(4):
        dp.tick()                        # worker 0 goes silent
    s_backup = dp.lease(2)
    assert s_backup == s0                # backup task on the straggler
    assert dp.complete(2, s0)            # backup finishes first
    assert not dp.complete(0, s0)        # straggler's completion: duplicate
    assert dp.duplicate_completions == 1


def test_data_determinism_across_restart():
    plane = MetadataPlane()
    dp = DataPipeline(plane, "ds2", n_shards=2)
    b1 = dp.read("shard-00000", batch=2, seq=8, vocab=100, step=5)
    dp2 = DataPipeline(plane, "ds2")     # "restarted" pipeline
    b2 = dp2.read("shard-00000", batch=2, seq=8, vocab=100, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


# ---------------------------------------------------------------------------
# cluster DES reproduces the paper's headline behaviours (fast subset;
# full curves live in benchmarks/)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def profiles():
    return profile_ops()


@pytest.fixture(scope="module")
def ns():
    return SyntheticNamespace(NamespaceSpec(), n_dirs=30)


def test_hopsfs_scales_with_namenodes(profiles, ns):
    tps = []
    for nn, cl in ((1, 200), (4, 600)):
        sim = HopsFSSim(n_namenodes=nn, n_ndb=4, profiles=profiles)
        sim.start_clients(cl, SpotifyWorkload(ns))
        tps.append(sim.run(0.8).throughput)
    assert tps[1] > 2.5 * tps[0]


def test_hopsfs_beats_hdfs_at_scale(profiles, ns):
    hd = HDFSSim()
    hd.start_clients(900, SpotifyWorkload(ns))
    hdfs_tp = hd.run(0.8).throughput
    hs = HopsFSSim(n_namenodes=12, n_ndb=8, profiles=profiles)
    hs.start_clients(1800, SpotifyWorkload(ns))
    hops_tp = hs.run(0.8).throughput
    assert hops_tp > 2.0 * hdfs_tp       # paper: 2.6x


def test_hopsfs_no_downtime_on_namenode_failure(profiles, ns):
    sim = HopsFSSim(n_namenodes=4, n_ndb=4, profiles=profiles)
    sim.start_clients(400, SpotifyWorkload(ns))
    sim.sim.after(0.4, lambda: sim.kill_namenode(0))
    res = sim.run(1.2)
    by_sec = dict(res.timeline)
    assert all(by_sec.get(s, 0) > 0 for s in range(1))  # never zero
    assert res.throughput > 0


def test_hdfs_failover_causes_downtime(ns):
    sim = HDFSSim()
    sim.start_clients(400, SpotifyWorkload(ns))
    sim.sim.after(0.2, sim.kill_active)
    res = sim.run(1.0)
    # ops completed in (0.2, 0.2+gap) should collapse to ~0
    assert sim.down_until > 0.2
    assert res.throughput < 400 / 1.0 / 0.001  # sanity


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serve_engine_generates_batched():
    from repro.serve import Request, ServeEngine
    cfg = get_smoke_config("qwen1_5_4b").derive(n_layers=2)
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=32)
    for rid in range(3):
        eng.submit(Request(rid, np.array([1, 2, 3 + rid]), max_new=4))
    done = eng.run(max_iters=40)
    assert len(done) == 3
    assert all(len(r.generated) == 4 for r in done)
