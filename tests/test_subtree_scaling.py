"""Scaling guards for the incremental subtree protocol (§6).

Three regressions this suite pins down:

  1. phase-2 work is LINEAR in subtree size — a 10x bigger directory
     costs ~10x the scanned rows and ~10x the chunk commits, never
     ~100x (the legacy engine re-walking state per wave would show up
     here);
  2. the streaming engine's peak resident frontier is bounded by level
     width + chunk size on multi-level trees — NOT by subtree size, the
     whole point of replacing materialize-the-whole-tree;
  3. deep trees: the phase-1 overlap check is O(depth + active rows)
     ``scan_index`` hops, not O(active x depth), and a depth-1100 chain
     deletes fine on BOTH engines (the legacy post-order is iterative —
     recursion would blow the 1000-frame default stack).
"""
import pytest

from repro.core import (MetadataStore, NamenodeCluster, WorkloadOp,
                        format_fs, materialize_big_dir)
from repro.core.tables import ROOT_ID, make_inode


def _cluster(n_namenodes=1):
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    return store, NamenodeCluster(store, n_namenodes)


def _flat_delete(n_children, *, batch_size=500):
    """Delete a flat n-child directory; return the subtree stats."""
    store, cluster = _cluster()
    nn = cluster.namenodes[0]
    materialize_big_dir(nn, "/big", n_children)
    nn.subtree.batch_size = batch_size
    res = nn.invoke(WorkloadOp("delete_subtree", "/big", on_dir=True))
    assert res.value["deleted"] == n_children + 1
    return dict(nn.subtree.last_stats)


def _make_chain(nn, depth, *, name="c"):
    """A depth-deep directory chain under /, via direct table puts.
    Returns the inode id of the DEEPEST directory."""
    t = nn.store.table("inode")
    parent = ROOT_ID
    for i in range(depth):
        iid = nn.ops.inode_ids.next_id()
        t.put(make_inode(iid, parent, f"{name}{i}", True))
        parent = iid
    return parent


def test_phase2_work_linear_in_children():
    n = 1000
    small = _flat_delete(n)
    big = _flat_delete(10 * n)
    assert small["scanned"] == n
    assert big["scanned"] == 10 * n
    # chunk commits scale with inodes/batch, not inodes^2
    assert big["chunks"] <= 11 * small["chunks"]
    # flat dirs arrive in one scan, so the frontier IS the directory —
    # linear in inode count, and exactly one wave each
    assert big["peak_frontier"] <= 11 * small["peak_frontier"]
    assert small["waves"] == big["waves"] == 1


def test_streaming_frontier_bounded_by_level_not_subtree():
    """100 dirs x 100 files: the whole tree is 10,101 inodes but the
    streaming engine should never hold more than one wave of dirs plus
    one chunk's worth of pending files resident."""
    store, cluster = _cluster()
    nn = cluster.namenodes[0]
    sub = nn.subtree
    sub.batch_size = 200
    t = store.table("inode")
    nn.ops.mkdirs("/big")
    big_id = t.get((ROOT_ID, "big"))["id"]
    total = 1
    for d in range(100):
        did = nn.ops.inode_ids.next_id()
        t.put(make_inode(did, big_id, f"d{d:03d}", True))
        total += 1
        for f in range(100):
            fid = nn.ops.inode_ids.next_id()
            t.put(make_inode(fid, did, f"f{f:03d}", False))
            total += 1
    res = nn.invoke(WorkloadOp("delete_subtree", "/big", on_dir=True))
    assert res.value["deleted"] == total == 10_101
    st = sub.last_stats
    # resident high-water mark: the 100-dir level + one dir's children +
    # a chunk of pending files — an order of magnitude under the subtree
    assert st["peak_frontier"] < total / 10, st["peak_frontier"]
    assert st["scanned"] == total - 1


def test_overlap_check_linear_on_deep_trees():
    """k live subtree ops against a depth-d target must cost O(d + k)
    ancestor hops, not O(k x d): the memoized walk learns each chain."""
    store, cluster = _cluster(2)
    nn, nn2 = cluster.namenodes
    deep = _make_chain(nn, 1000)
    # 40 active subtree ops owned by a LIVE peer namenode, each rooted
    # at a node of a second deep chain — disjoint from the target, but
    # every naive descendant test would walk ~1000 hops for each
    other_top_rows = []
    t = store.table("inode")
    parent = ROOT_ID
    for i in range(1000):
        iid = nn.ops.inode_ids.next_id()
        t.put(make_inode(iid, parent, f"o{i}", True))
        parent = iid
        if i >= 960:
            other_top_rows.append(iid)
    ongoing = store.table("ongoing_subtree_ops")
    for iid in other_top_rows:
        ongoing.put({"inode_id": iid, "namenode_id": nn2.ops.nn_id,
                     "op": "subtree"})
    nn.subtree.ancestor_scans = 0
    deep_path = "/" + "/".join(f"c{i}" for i in range(1000))
    res = nn.invoke(WorkloadOp("chmod_subtree", deep_path,
                               args={"perm": 0o700}, on_dir=True))
    assert res is not None
    hops = nn.subtree.ancestor_scans
    # one walk up the target chain (~1000) + one walk up the longest
    # active chain (~1000, memoized for the other 39) + k memo lookups.
    # The quadratic form is ~40 x 1000 = 40,000.
    assert hops <= 4000, hops


@pytest.mark.parametrize("incremental", [True, False])
def test_deep_chain_delete_both_engines(incremental):
    """depth-1100 > the default recursion limit: post-order must be
    iterative, and the streaming engine must cap its waves."""
    store, cluster = _cluster()
    nn = cluster.namenodes[0]
    _make_chain(nn, 1100)
    nn.subtree.incremental = incremental
    nn.subtree.batch_size = 64
    res = nn.invoke(WorkloadOp("delete_subtree", "/c0", on_dir=True))
    assert res.value["deleted"] == 1100
    assert store.table("inode").get((ROOT_ID, "c0")) is None
    st = nn.subtree.last_stats
    if incremental:
        assert st["waves"] >= 1
    else:
        assert st["waves"] == 1100
