"""Pipeline-parallel (GPipe via shard_map + ppermute) correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline import pipeline_apply, pipeline_bubble_fraction


def test_bubble_fraction():
    assert pipeline_bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert pipeline_bubble_fraction(1, 8) == 0.0


def test_pipeline_matches_sequential():
    """The staged schedule must equal running all layers sequentially."""
    n = len(jax.devices())
    if n < 1:
        pytest.skip("no devices")
    S = 1                                  # stage axis size on this host
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((S,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:  # older jax: meshes are Auto by default
        mesh = jax.make_mesh((S,), ("stage",))
    L_per, M, mb, d = 3, 4, 2, 8
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (S, L_per, d, d)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

    def layer_fn(lp, h):
        return jnp.tanh(h @ lp)

    out = pipeline_apply(layer_fn, w, x, mesh=mesh)

    ref = x
    for s in range(S):
        for l in range(L_per):
            ref = jnp.tanh(ref @ w[s, l])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
