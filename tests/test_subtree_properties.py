"""Differential property suite for subtree operations (§6): ARBITRARY
interleaved sequences of subtree ops (delete_subtree / rename_subtree /
chmod_subtree / chown_subtree) and plain namespace ops (create / mkdirs /
stat / ls / delete_file) leave every execution strategy equivalent.

Two pairings are locked against each other:

  1. dict vs columnar — the ``differential_replay`` conftest fixture
     replays the same trace on both store backends; ``dump_state`` must
     stay byte-equal and the total OpCost identical (the columnar treeagg
     launch in subtree phase 2 is advisory and charges zero cost).
  2. incremental vs legacy — the same trace replayed on two dict-backed
     clusters, one with the streaming-wave subtree engine
     (``SubtreeOps.incremental = True``, small ``wave_cap`` / chunk size
     to force many waves and chunk commits) and one with the legacy
     build-the-whole-tree engine. Namespaces and ``dump_state`` must be
     byte-equal; chunk-count-dependent cost counters may differ, but each
     run's OpCost must still conserve (per-op merge == pipeline total).

Both pairings also assert zero orphan rows afterwards: no surviving
``ongoing_subtree_ops`` row, no inode left with ``subtree_lock`` set, no
block row referencing a missing inode, and no lease_path row surviving
the leader scrub.

Fixed-seed regressions run everywhere; the hypothesis property suite at
the bottom engages only where hypothesis is installed, under the pinned
derandomized "chaos" profile from conftest.
"""
import random

import pytest

from repro.core import (MetadataStore, NamenodeCluster, OpCost,
                        RequestPipeline, WorkloadOp, format_fs,
                        namespace_snapshot)

# Small closed path universe with TWO levels of directories so subtree
# ops regularly hit non-trivial trees, and collisions (delete of a miss,
# rename onto a live target, chmod of a just-deleted root) stay frequent.
ROOTS = [f"/s{i}" for i in range(3)]
SUBS = [f"d{j}" for j in range(3)]
NAMES = [f"f{k}" for k in range(4)]
CLIENTS = ["c0", "c1"]


def _op_from(rng):
    root = rng.choice(ROOTS)
    sub = f"{root}/{rng.choice(SUBS)}"
    d = rng.choice((root, sub))
    f = f"{d}/{rng.choice(NAMES)}"
    kind = rng.randrange(10)
    if kind == 0:
        return WorkloadOp("mkdirs", sub)
    if kind == 1:
        return WorkloadOp("create", f,
                          args={"client": rng.choice(CLIENTS)})
    if kind == 2:
        return WorkloadOp("delete_file", f)
    if kind == 3:
        return WorkloadOp("delete_subtree", d, on_dir=True)
    if kind == 4:
        dst_root = rng.choice(ROOTS)
        return WorkloadOp("rename_subtree", d,
                          f"{dst_root}/m{rng.randrange(3)}", on_dir=True)
    if kind == 5:
        return WorkloadOp("chmod_subtree", d,
                          args={"perm": rng.choice((0o750, 0o700))},
                          on_dir=True)
    if kind == 6:
        return WorkloadOp("chown_subtree", d,
                          args={"owner": rng.choice(CLIENTS)},
                          on_dir=True)
    if kind == 7:
        return WorkloadOp("stat", f)
    if kind == 8:
        return WorkloadOp("ls", d, on_dir=True)
    return WorkloadOp("content_summary", d, on_dir=True)


def _random_trace(seed, n_ops=40):
    rng = random.Random(seed)
    # always re-create the roots early so subtree ops have targets even
    # after an early delete_subtree wipes one out
    trace = [WorkloadOp("mkdirs", r) for r in ROOTS]
    trace += [_op_from(rng) for _ in range(n_ops)]
    return trace


def _inode_ids(store):
    ids = set()
    for part in store.table("inode").parts:
        for row in part.values():
            ids.add(row["id"])
    return ids


def _subtree_orphans(store, cluster):
    """(ongoing rows, locked inodes, orphan blocks, orphan lease_paths)."""
    ids = _inode_ids(store)
    ongoing = [r for part in store.table("ongoing_subtree_ops").parts
               for r in part.values()]
    locked = [r["id"] for part in store.table("inode").parts
              for r in part.values() if r.get("subtree_lock")]
    blocks = [r for part in store.table("block").parts
              for r in part.values() if r["inode_id"] not in ids]
    for _ in range(10):
        if cluster.scrub_leases() == 0:
            break
    lps = [r for part in store.table("lease_path").parts
           for r in part.values() if r["inode_id"] not in ids]
    return ongoing, locked, blocks, lps


def _assert_clean(store, cluster):
    ongoing, locked, blocks, lps = _subtree_orphans(store, cluster)
    assert ongoing == [], f"orphan ongoing_subtree_ops rows: {ongoing}"
    assert locked == [], f"inodes left subtree-locked: {locked}"
    assert blocks == [], f"orphan block rows: {blocks}"
    assert lps == [], f"orphan lease_path rows survived scrub: {lps}"


def _check_cost_conserved(stats):
    per_nn = OpCost()
    for c in stats.per_nn_cost.values():
        per_nn.merge(c)
    per_op = OpCost()
    for o in stats.outcomes:
        if o.ok:
            per_op.merge(o.result.cost)
    assert per_nn.as_dict() == stats.total_cost.as_dict() \
        == per_op.as_dict()


def _check_backends_equivalent(dres, cres):
    (ds, dc, dstats), (cs, cc, cstats) = dres, cres
    assert ds.dump_state() == cs.dump_state()
    assert namespace_snapshot(ds) == namespace_snapshot(cs)
    assert [o.ok for o in dstats.outcomes] == \
        [o.ok for o in cstats.outcomes]
    for stats in (dstats, cstats):
        _check_cost_conserved(stats)
    # the advisory treeagg launch charges zero cost, so totals stay equal
    assert dstats.total_cost.as_dict() == cstats.total_cost.as_dict()
    for store, cluster in ((ds, dc), (cs, cc)):
        _assert_clean(store, cluster)
    assert ds.dump_state() == cs.dump_state()


def _replay_mode(wops, *, incremental, batch_size=3, wave_cap=4):
    """Replay on a fresh dict-backed cluster with the subtree engine
    forced to one mode. Tiny chunk / wave knobs make even these small
    trees exercise multi-chunk commits and multi-slice waves."""
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 1)
    for nn in cluster.namenodes:
        nn.subtree.incremental = incremental
        nn.subtree.batch_size = batch_size
        nn.subtree.wave_cap = wave_cap
    stats = RequestPipeline(cluster, batch_size=1).run(list(wops))
    return store, cluster, stats


def _check_modes_equivalent(wops):
    inc = _replay_mode(wops, incremental=True)
    leg = _replay_mode(wops, incremental=False)
    (is_, ic, istats), (ls_, lc, lstats) = inc, leg
    assert is_.dump_state() == ls_.dump_state()
    assert namespace_snapshot(is_) == namespace_snapshot(ls_)
    assert [o.ok for o in istats.outcomes] == \
        [o.ok for o in lstats.outcomes]
    for stats in (istats, lstats):
        _check_cost_conserved(stats)
    for store, cluster in ((is_, ic), (ls_, lc)):
        _assert_clean(store, cluster)


# ---------------------------------------------------------------------------
# fixed-seed regressions (run everywhere, hypothesis or not)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_subtree_differential_fixed_seeds(differential_replay, seed):
    d, c = differential_replay(_random_trace(seed),
                               pipeline="sequential")
    _check_backends_equivalent(d, c)


@pytest.mark.parametrize("seed", range(8))
def test_incremental_vs_legacy_fixed_seeds(seed):
    _check_modes_equivalent(_random_trace(seed))


@pytest.mark.parametrize("seed", [300, 301])
def test_subtree_differential_two_namenodes(differential_replay, seed):
    d, c = differential_replay(_random_trace(seed, n_ops=60),
                               pipeline="reactive", n_namenodes=2,
                               batch_size=4)
    _check_backends_equivalent(d, c)


# ---------------------------------------------------------------------------
# property suite (engages only where hypothesis is installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _root = st.sampled_from(ROOTS)
    _dir = st.one_of(_root, st.builds(lambda r, s: f"{r}/{s}",
                                      _root, st.sampled_from(SUBS)))
    _file = st.builds(lambda d, n: f"{d}/{n}",
                      _dir, st.sampled_from(NAMES))
    _client = st.sampled_from(CLIENTS)

    _op = st.one_of(
        st.builds(lambda d: WorkloadOp("mkdirs", d), _dir),
        st.builds(lambda f, c: WorkloadOp("create", f,
                                          args={"client": c}),
                  _file, _client),
        st.builds(lambda f: WorkloadOp("delete_file", f), _file),
        st.builds(lambda d: WorkloadOp("delete_subtree", d, on_dir=True),
                  _dir),
        st.builds(lambda s, r, i: WorkloadOp("rename_subtree", s,
                                             f"{r}/m{i}", on_dir=True),
                  _dir, _root, st.integers(min_value=0, max_value=2)),
        st.builds(lambda d, p: WorkloadOp("chmod_subtree", d,
                                          args={"perm": p}, on_dir=True),
                  _dir, st.sampled_from((0o750, 0o700))),
        st.builds(lambda d, c: WorkloadOp("chown_subtree", d,
                                          args={"owner": c}, on_dir=True),
                  _dir, _client),
        st.builds(lambda f: WorkloadOp("stat", f), _file),
        st.builds(lambda d: WorkloadOp("ls", d, on_dir=True), _dir),
        st.builds(lambda d: WorkloadOp("content_summary", d, on_dir=True),
                  _dir),
    )
    _trace = st.lists(_op, min_size=1, max_size=40).map(
        lambda ops: [WorkloadOp("mkdirs", r) for r in ROOTS] + ops)

    _SETTINGS = dict(
        suppress_health_check=[HealthCheck.function_scoped_fixture,
                               HealthCheck.too_slow],
        deadline=None)

    @given(wops=_trace)
    @settings(**_SETTINGS)
    def test_subtree_differential_property(differential_replay, wops):
        d, c = differential_replay(wops, pipeline="sequential")
        _check_backends_equivalent(d, c)

    @given(wops=_trace)
    @settings(max_examples=10, **_SETTINGS)
    def test_incremental_vs_legacy_property(wops):
        _check_modes_equivalent(wops)
