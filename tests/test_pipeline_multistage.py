"""True multi-stage pipeline-parallel test: runs in a subprocess with 4
host devices (XLA device count is process-global, so the main test process
— which must see 1 device — cannot host it)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply

    S, L_per, M, mb, d = 4, 2, 8, 2, 8
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((S,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:  # older jax: meshes are Auto by default
        mesh = jax.make_mesh((S,), ("stage",))
    w = jax.random.normal(jax.random.PRNGKey(0), (S, L_per, d, d)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

    def layer_fn(lp, h):
        return jnp.tanh(h @ lp)

    out = pipeline_apply(layer_fn, w, x, mesh=mesh)
    ref = x
    for s in range(S):
        for l in range(L_per):
            ref = jnp.tanh(ref @ w[s, l])
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, err
    print("PIPELINE_OK", err)
""") % str(SRC)


def test_pipeline_four_stages():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
