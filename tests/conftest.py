"""Shared fixtures. NB: XLA_FLAGS / device count is NOT set here — smoke
tests and benches must see the real (1-CPU) device; only dryrun.py forces
512 placeholder devices."""
import jax
import pytest

from repro.core import (MetadataStore, NamenodeCluster, RequestPipeline,
                        format_fs, materialize_namespace,
                        namespace_snapshot)
from repro.core.workload import NamespaceSpec, SyntheticNamespace

jax.config.update("jax_enable_x64", False)

# Chaos/property suites run under a pinned, derandomized profile so CI
# failures always reproduce locally (hypothesis is optional: the fixed-seed
# regression tests in test_chaos_recovery.py run without it).
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "chaos", derandomize=True, max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile("chaos")
except ImportError:
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "chaos: deterministic fault-injection / failover recovery suite")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def make_cluster():
    """Seeded cluster factory shared by the FS-layer suites.

    ``make_cluster(n)`` returns ``(store, cluster)``; pass ``dirs=`` /
    ``files=`` to pre-create paths, or ``namespace=True`` to materialize a
    :class:`~repro.core.workload.SyntheticNamespace` and get
    ``(store, cluster, ns)`` back — the setup every trace-replay test
    needs, deterministic via ``NamespaceSpec.seed``.
    """
    def factory(n_namenodes=1, *, dirs=(), files=(), namespace=False,
                n_dirs=16, files_per_dir=4, n_datanodes=4, **cluster_kw):
        store = MetadataStore(n_datanodes=n_datanodes)
        format_fs(store)
        cluster = NamenodeCluster(store, n_namenodes, **cluster_kw)
        nn = cluster.namenodes[0]
        for d in dirs:
            nn.ops.mkdirs(d)
        for f in files:
            nn.ops.create(f)
        if namespace:
            ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                                    files_per_dir=files_per_dir)
            materialize_namespace(nn, ns)
            return store, cluster, ns
        return store, cluster
    return factory


@pytest.fixture
def differential_replay(make_cluster):
    """Dict-vs-columnar oracle lock: replay the same trace on a fresh
    dict-backed cluster and a fresh columnar-backed cluster built
    identically, and return both ``(store, cluster, stats)`` triples.

    ``pipeline`` picks the execution path: ``"sequential"`` (batch=1),
    ``"reactive"`` (FIFO batches) or ``"planned"`` (closed-loop batch
    planner, where the fused kernels may engage). The caller asserts what
    the mode guarantees — ``dump_state`` byte-equality always holds; op-
    for-op cost equality additionally holds whenever both backends walk
    the identical code path (no pkval demotions)."""
    from repro.core import PlannedRequestPipeline
    from repro.core.columnar import ColumnarMetadataStore

    def replay(wops, *, n_namenodes=1, pipeline="sequential",
               batch_size=8, namespace=False, n_dirs=16, files_per_dir=4,
               window=None, **cluster_kw):
        out = []
        for store_cls in (MetadataStore, ColumnarMetadataStore):
            store = store_cls(n_datanodes=4)
            format_fs(store)
            cluster = NamenodeCluster(store, n_namenodes, **cluster_kw)
            if namespace:
                ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                                        files_per_dir=files_per_dir)
                materialize_namespace(cluster.namenodes[0], ns)
            if pipeline == "sequential":
                stats = RequestPipeline(cluster, batch_size=1).run(
                    list(wops))
            elif pipeline == "reactive":
                stats = RequestPipeline(cluster, batch_size=batch_size) \
                    .run(list(wops))
            elif pipeline == "planned":
                pipe = PlannedRequestPipeline(
                    cluster, batch_size=batch_size,
                    window=window or batch_size * 8)
                stats = pipe.run(list(wops))
            else:
                raise ValueError(pipeline)
            out.append((store, cluster, stats))
        return out[0], out[1]
    return replay


@pytest.fixture
def oracle_replay(make_cluster):
    """Fault-free sequential oracle: replay a trace on a fresh single
    namenode, one op per exchange, and return ``(snapshot, outcomes)``.
    Chaos and equivalence tests compare their final namespace against this
    snapshot byte-for-byte (the §7.6 'no metadata loss' check)."""
    def replay(wops, *, dirs=(), files=(), namespace=False, n_dirs=16,
               files_per_dir=4):
        built = make_cluster(1, dirs=dirs, files=files, namespace=namespace,
                             n_dirs=n_dirs, files_per_dir=files_per_dir)
        store, cluster = built[0], built[1]
        stats = RequestPipeline(cluster, batch_size=1).run(list(wops))
        return namespace_snapshot(store), stats.outcomes
    return replay
