"""Shared fixtures. NB: XLA_FLAGS / device count is NOT set here — smoke
tests and benches must see the real (1-CPU) device; only dryrun.py forces
512 placeholder devices."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
