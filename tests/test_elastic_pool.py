"""Elastic namenode pool (ISSUE 7): load-adaptive scale-out/in with warm
hint migration, hint-aware routing, cross-client invalidation push, and
the WindowController's second (batch-size) knob.

Layered like the subsystem itself:

  * epoch piggyback — destructive ops bump a store-level invalidation
    epoch that rides ``OpResult.hints``; OTHER clients' caches apply the
    invalidations (or wholesale-reset when the bounded log aged past
    them) without any server push channel;
  * contention telemetry — ``LockManager`` wait/acquire counters, and the
    ``WindowController`` batch-size AIMD that feeds on them;
  * the pool — scale-out under queue pressure (joiners pre-warmed from
    client caches), scale-in when idle (victims warm-migrate to
    survivors, leases survive via leader housekeeping), hysteresis and
    cooldown;
  * routing — batches dealt to the namenode already warm for their path;
  * equivalence — an elastic replay's namespace equals a fixed-size
    sequential oracle's, including under a namenode CRASH striking
    mid-scale-out (the chaos-compose case).
"""
import pytest

from repro.core import (DFSClient, ElasticNamenodePool, Fault,
                        FaultInjector, ChaosPlan, FaultSite,
                        PlannedRequestPipeline, RequestPipeline,
                        WindowController, WorkloadOp, namespace_snapshot)
from repro.core.chaos import CRASH, RETRYABLE_ERRORS, RecoveryInvariants
from repro.core.hint_cache import EPOCH_TAG, InodeHintCache
from repro.core.store import EXCLUSIVE, LockManager, LockTimeout
from repro.core.workload import (NamespaceSpec, SpotifyWorkload,
                                 SyntheticNamespace, make_phased_trace)


def _trace(n=400, seed=13, n_dirs=16, files_per_dir=4):
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                            files_per_dir=files_per_dir)
    return SpotifyWorkload(ns, seed=seed).make_trace(n)


# ---------------------------------------------------------------------------
# cross-client hint invalidation push (the epoch fold into OpResult.hints)
# ---------------------------------------------------------------------------

def test_destructive_op_bumps_store_epoch(make_cluster):
    store, cluster = make_cluster(1, dirs=("/w",), files=("/w/f",))
    assert store.hint_epoch == 0
    assert store.hint_piggyback() == ()
    cluster.namenodes[0].perform("delete_file", "/w/f")
    assert store.hint_epoch == 1
    pb = store.hint_piggyback()
    assert (EPOCH_TAG, "", 1) in pb
    assert (EPOCH_TAG, "/w/f", 1) in pb


def test_epoch_push_invalidates_other_clients_cache(make_cluster):
    """Client A cached /w/f; client B deletes it; client A's NEXT response
    (any op) carries the invalidation epoch and drops A's stale entry —
    no server-side staleness detection involved."""
    store, cluster = make_cluster(2, dirs=("/w",), files=("/w/f",))
    a, b = DFSClient(cluster), DFSClient(cluster)
    a.stat("/w/f")
    wid = a.hint_cache.peek(0, "w") or a.hint_cache.last_resolved_id(["w"])
    assert wid is not None
    assert a.hint_cache.peek(wid, "f") is not None
    b.delete("/w/f")
    a.ls("/w")                       # unrelated op; epoch rides its hints
    assert a.hint_cache.seen_epoch == store.hint_epoch > 0
    assert a.hint_cache.peek(wid, "f") is None


def test_epoch_gap_forces_wholesale_reset(make_cluster):
    """A client that slept through more invalidations than the bounded
    log retains cannot apply them one-by-one — it must clear wholesale
    (correctness over retention)."""
    files = tuple(f"/w/f{i}" for i in range(12))
    store, cluster = make_cluster(2, dirs=("/w",), files=files)
    a, b = DFSClient(cluster), DFSClient(cluster)
    a.stat(files[-1])
    assert a.hint_cache.entries > 0
    assert a.hint_cache.seen_epoch == 0
    for f in files[:-1]:             # 11 epochs while A sleeps
        b.delete(f)
    assert store.hint_epoch == 11 > store.HINT_LOG_TAIL
    a.ls("/w")
    assert a.hint_cache.epoch_resets == 1
    assert a.hint_cache.seen_epoch == store.hint_epoch


def test_epoch_entries_never_pollute_absorb():
    cache = InodeHintCache()
    cache.absorb([(EPOCH_TAG, "", 3), (EPOCH_TAG, "/a", 2), (0, "a", 7)])
    assert cache.entries == 1
    assert cache.peek(0, "a") == 7


# ---------------------------------------------------------------------------
# lock-wait telemetry + the WindowController's batch-size knob (AIMD)
# ---------------------------------------------------------------------------

def _measured_wait_frac(locks, fn):
    w0, a0 = locks.wait_count, locks.acquire_count
    fn()
    da = locks.acquire_count - a0
    return (locks.wait_count - w0) / da if da else 0.0


def test_lock_manager_counts_waits_under_contention():
    locks = LockManager(timeout=0.01)
    locks.acquire(1, "inodes", (0, "a"), EXCLUSIVE)

    def contend():
        with pytest.raises(LockTimeout):
            locks.acquire(2, "inodes", (0, "a"), EXCLUSIVE)
    frac = _measured_wait_frac(locks, contend)
    assert locks.wait_count == 1
    assert frac == 1.0
    locks.release_all(1)
    # uncontended acquire: counted, but no wait
    frac = _measured_wait_frac(
        locks, lambda: locks.acquire(3, "inodes", (0, "a"), EXCLUSIVE))
    assert frac == 0.0


def test_batch_size_shrinks_under_induced_contention_and_regrows():
    """Satellite: the controller's second knob. The lock-wait fraction is
    MEASURED from a real LockManager — a held exclusive row forces the
    competing acquire to wait (contended phase), then the same row
    uncontended (calm phase) — and fed to the controller: multiplicative
    shrink under contention, additive regrowth after."""
    locks = LockManager(timeout=0.01)
    ctl = WindowController(128, min_window=16, max_window=512,
                           batch_base=16, min_batch=2,
                           contention_shrink=0.05)
    assert ctl.batch_size == 16

    # contended: holder pins the row, every competing acquire waits
    locks.acquire(1, "inodes", (0, "hot"), EXCLUSIVE)

    def contended():
        for t in range(2, 6):
            try:
                locks.acquire(t, "inodes", (0, "hot"), EXCLUSIVE)
            except LockTimeout:
                pass
    frac = _measured_wait_frac(locks, contended)
    assert frac > 0.05
    shrunk = []
    for _ in range(3):
        ctl.observe(128, 0, 128, lock_wait_frac=frac)
        shrunk.append(ctl.batch_size)
    assert shrunk[0] < 16                  # multiplicative decrease
    assert shrunk == sorted(shrunk, reverse=True)
    assert ctl.batch_size >= ctl.min_batch

    # calm: row released, acquires sail through -> additive regrowth
    locks.release_all(1)
    frac = _measured_wait_frac(
        locks, lambda: locks.acquire(9, "inodes", (0, "hot"), EXCLUSIVE))
    assert frac == 0.0
    low = ctl.batch_size
    for _ in range(4):
        ctl.observe(128, 0, 128, lock_wait_frac=frac)
    assert ctl.batch_size == min(ctl.max_batch, low + 4 * ctl.batch_step)
    assert ctl.batch_history[0] == 16      # full trajectory recorded


def test_batch_knob_disabled_without_batch_base():
    ctl = WindowController(64, min_window=8, max_window=256)
    assert ctl.batch_size is None
    ctl.observe(64, 0, 64, lock_wait_frac=0.9)   # must be a no-op knob
    assert ctl.batch_size is None
    assert ctl.batch_history == []


def test_planned_pipeline_propagates_adapted_batch_size(make_cluster):
    store, cluster, ns = make_cluster(2, namespace=True)
    trace = SpotifyWorkload(ns, seed=3).make_trace(300)
    pipe = PlannedRequestPipeline(cluster, batch_size=16, window=64)
    pipe.run(trace)
    ctl = pipe.planner.controller
    assert ctl is not None and ctl.batch_size is not None
    # the live knob is threaded back to planner AND pipeline every window
    assert pipe.batch_size == pipe.planner.batch_size == ctl.batch_size
    assert len(ctl.batch_history) >= 2


# ---------------------------------------------------------------------------
# the pool: scale-out under load, scale-in when idle, warm migration
# ---------------------------------------------------------------------------

def _elastic_setup(make_cluster, *, n=2, **pool_kw):
    store, cluster, ns = make_cluster(n, namespace=True)
    kw = dict(min_namenodes=n, max_namenodes=4, high_load=60,
              low_load=20, hysteresis=2, cooldown=2)
    kw.update(pool_kw)
    return store, cluster, ns, ElasticNamenodePool(cluster, **kw)


def test_pool_scales_out_under_load_and_prewarms(make_cluster):
    store, cluster, ns, pool = _elastic_setup(make_cluster)
    client = DFSClient(cluster)
    client.attach_pool(pool)
    trace = SpotifyWorkload(ns, seed=13).make_trace(600)
    stats = client.run_trace(trace, planned=True, window=100,
                             adaptive=False)
    assert stats.failed == 0
    assert pool.scale_outs >= 1
    assert len(cluster.alive_namenodes()) > 2
    joiner = cluster.namenodes[2]
    # pre-warmed from the client cache BEFORE serving: the scale_out
    # event records the migrated entries and the joiner's cache is hot
    ev = next(e for e in pool.events if e.action == "scale_out")
    assert ev.migrated_entries > 0
    assert joiner.ops.cache.entries > 0


def test_pool_scales_in_when_idle_with_warm_migration(make_cluster):
    store, cluster, ns, pool = _elastic_setup(make_cluster, n=3,
                                              min_namenodes=2,
                                              hysteresis=2, cooldown=1)
    victim = cluster.namenodes[2]
    victim.perform("stat", ns.files[-1])   # give the victim cache warmth
    assert victim.ops.cache.entries > 0
    migrated_to = cluster.namenodes[1].ops.cache.entries
    for _ in range(8):
        if len(cluster.alive_namenodes()) <= 2:
            break
        pool.tick(queue_depth=0)
    assert pool.scale_ins == 1
    assert not victim.alive
    # retirement left the election immediately (planned, not a crash)
    assert cluster.election.leader() != victim.nn_id
    # the victim's working set moved to the survivors
    assert pool.migrated_entries > 0
    assert cluster.namenodes[1].ops.cache.entries > migrated_to


def test_pool_scale_in_preserves_renewed_leases(make_cluster):
    """Membership changes must not drop in-flight leases: a client
    writing through a scale-in (and renewing, as real clients do) keeps
    its lease; the leader's housekeeping only reclaims EXPIRED holders."""
    store, cluster, ns, pool = _elastic_setup(make_cluster, n=3,
                                              min_namenodes=2,
                                              hysteresis=2, cooldown=1)
    client = DFSClient(cluster)
    client.create("/w_lease", client="writer")
    client.add_block("/w_lease", client="writer")
    for _ in range(8):
        if len(cluster.alive_namenodes()) <= 2:
            break
        client.renew_lease(client="writer")
        pool.tick(queue_depth=0)
    assert pool.scale_ins == 1
    # the lease survived: the same writer can keep writing, and complete
    client.add_block("/w_lease", client="writer")
    client.complete_block("/w_lease", size=1024, client="writer")


def test_pool_hysteresis_and_cooldown_prevent_thrash(make_cluster):
    store, cluster, ns, pool = _elastic_setup(
        make_cluster, high_load=10, low_load=5, hysteresis=3, cooldown=4)
    # constant high load: first action only after `hysteresis` ticks ...
    pool.tick(queue_depth=1000)
    pool.tick(queue_depth=1000)
    assert pool.scale_outs == 0
    pool.tick(queue_depth=1000)
    assert pool.scale_outs == 1
    # ... and the next not before `cooldown` more ticks
    pool.tick(queue_depth=1000)
    pool.tick(queue_depth=1000)
    pool.tick(queue_depth=1000)
    assert pool.scale_outs == 1
    pool.tick(queue_depth=1000)
    assert pool.scale_outs == 2
    assert len(cluster.alive_namenodes()) == 4
    # at max_namenodes: high load never scales past the ceiling
    for _ in range(8):
        pool.tick(queue_depth=1000)
    assert len(cluster.alive_namenodes()) == 4


def test_membership_epoch_rebalances_sticky_clients(make_cluster):
    store, cluster, ns, pool = _elastic_setup(make_cluster)
    client = DFSClient(cluster, policy="sticky")
    client.attach_pool(pool)
    client.stat("/")
    assert client._selector._sticky is not None
    pool.scale_out("test")
    # the epoch moved: the next call re-picks instead of sticking
    before = pool.membership_epoch
    client.stat("/")
    assert pool.membership_epoch == before
    # rebalanced without dropping the call (it succeeded above); sticky
    # re-pins AFTER the refresh, so subsequent calls are stable again
    assert client._selector._sticky is not None


# ---------------------------------------------------------------------------
# hint-aware routing
# ---------------------------------------------------------------------------

def test_warm_namenode_lookup_prefers_warm_cache(make_cluster):
    store, cluster = make_cluster(3, dirs=("/w",), files=("/w/f",))
    # the fixture created the paths through NN 0, warming it: make the
    # warmth exclusive to NN 2 so the lookup has exactly one answer
    cluster.namenodes[0].ops.cache.clear()
    cluster.namenodes[1].ops.cache.clear()
    warm = cluster.namenodes[2]
    warm.perform("stat", "/w/f")         # only NN 2 resolves the chain
    alive = cluster.alive_namenodes()
    assert RequestPipeline._warm_namenode("/w/f", alive) is warm
    # unknown path: no warm namenode -> caller falls back
    assert RequestPipeline._warm_namenode("/nope/x", alive) is None


def test_planner_routes_batches_to_warm_slots(make_cluster):
    store, cluster, ns = make_cluster(3, namespace=True)
    cluster.namenodes[0].ops.cache.clear()   # NN 0 built the namespace
    warm = cluster.namenodes[1]
    for f in ns.files[:8]:
        warm.perform("stat", f)
    trace = [WorkloadOp("stat", f) for f in ns.files[:8]]
    pipe = PlannedRequestPipeline(cluster, batch_size=4, window=8,
                                  adaptive=False, hint_routing=True)
    stats = pipe.run(trace)
    assert stats.failed == 0
    assert pipe.plan_report.hint_routed_batches > 0
    # the warm namenode actually served the routed work
    assert stats.per_nn_ops[warm.nn_id] > 0


def test_hint_routing_off_by_default_on_static_fleet(make_cluster):
    store, cluster, ns = make_cluster(2, namespace=True)
    pipe = PlannedRequestPipeline(cluster, batch_size=8, window=32,
                                  adaptive=False)
    pipe.run(SpotifyWorkload(ns, seed=5).make_trace(64))
    assert pipe.hint_routing is False
    assert pipe.plan_report.hint_routed_batches == 0


# ---------------------------------------------------------------------------
# equivalence: elastic replay == fixed-size sequential oracle
# ---------------------------------------------------------------------------

def test_elastic_replay_namespace_equals_sequential(make_cluster,
                                                    oracle_replay):
    store, cluster, ns, pool = _elastic_setup(make_cluster)
    client = DFSClient(cluster)
    client.attach_pool(pool)
    trace, bounds = make_phased_trace(ns, [300, 300], seed=13)
    client.run_trace(trace[:bounds[0]], planned=True, window=100,
                     adaptive=False)
    for _ in range(12):                  # idle: scale back in + migrate
        if len(cluster.alive_namenodes()) <= 2:
            break
        pool.tick(queue_depth=0)
    client.run_trace(trace[bounds[0]:], planned=True, window=100,
                     adaptive=False)
    assert pool.scale_outs >= 1 and pool.scale_ins >= 1
    oracle_snap, _ = oracle_replay(trace, namespace=True)
    assert namespace_snapshot(store) == oracle_snap


# ---------------------------------------------------------------------------
# chaos-compose: a namenode CRASH strikes DURING scale-out
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_kill_during_scale_out_recovers_to_oracle(make_cluster,
                                                  oracle_replay):
    """The composed failure mode: the pool admits a (cold-ish) joiner
    under load, and an established namenode CRASHES at the batch exchange
    in the very next window. Survivors + joiner must drain the replay,
    and the §7.6 recovery protocol must converge to the fault-free
    sequential oracle's namespace with all RecoveryInvariants holding.
    The chaos hook propagation in ``add_namenode`` is load-bearing here:
    the injector must be able to see (and strike) late joiners too."""
    store, cluster, ns = make_cluster(2, namespace=True)
    pool = ElasticNamenodePool(cluster, min_namenodes=2, max_namenodes=4,
                               high_load=1, low_load=0.5, hysteresis=1,
                               cooldown=0)
    trace = SpotifyWorkload(ns, seed=7).make_trace(300)
    # window 1 (50 ops, ~7+ exchanges) -> pool tick -> scale-out; the
    # 10th batch exchange lands in window 2, right after the join
    plan = ChaosPlan((Fault(FaultSite.BATCH_EXCHANGE, at=9, victim=0,
                            kind=CRASH),))
    inj = FaultInjector(plan, cluster)
    pipe = PlannedRequestPipeline(cluster, batch_size=8, window=50,
                                  adaptive=False, pool=pool)
    with inj:
        stats = pipe.run(trace)
    assert pool.scale_outs >= 1
    crash = [e for e in inj.events if e.kind == CRASH]
    assert crash and crash[0].nn_id == 0
    scale_t = next(e.t for e in pool.events if e.action == "scale_out")
    assert not cluster.namenodes[0].alive
    assert len(cluster.alive_namenodes()) >= 2

    # §7.6 recovery: election past the staleness bound, leader
    # housekeeping, re-drive transients on survivors, final scrub
    outcomes = list(stats.outcomes)
    for _ in range(3):
        todo = [i for i, oc in enumerate(outcomes)
                if not oc.ok and oc.error in RETRYABLE_ERRORS]
        if not todo:
            break
        for _ in range(cluster.election.max_missed + 1):
            cluster.tick()
        cluster.recover_leases()
        rstats = RequestPipeline(cluster, batch_size=8).run(
            [trace[i] for i in todo])
        for i, oc in zip(todo, rstats.outcomes):
            outcomes[i] = oc
    cluster.scrub_leases()
    assert all(oc.ok or oc.error not in RETRYABLE_ERRORS
               for oc in outcomes)

    oracle_snap, oracle_outcomes = oracle_replay(trace, namespace=True)
    RecoveryInvariants(store, cluster).assert_all(oracle_snap)
    # the crash struck after the scale-out, i.e. the fault genuinely
    # composed with an elastic membership change
    assert scale_t <= cluster.election.now


# ---------------------------------------------------------------------------
# DES mirror: scale events in the cluster simulator
# ---------------------------------------------------------------------------

def test_des_scale_out_adds_capacity_without_zero_bins():
    from repro.core.cluster_sim import BatchedHopsFSSim, profile_ops
    from repro.core.workload import TraceReplay
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=20)
    trace = SpotifyWorkload(ns, seed=13).make_trace(800)
    sim = BatchedHopsFSSim(n_namenodes=2, n_ndb=4,
                           profiles=profile_ops(), batch_size=8,
                           seed=1, planned=True, timeline_bin=0.01)
    sim.start_clients(400, TraceReplay(trace))
    sim.schedule_scale_out(0.03, 2)
    sim.schedule_scale_in(0.07, 1)
    res = sim.run(0.1)
    assert [e[1:] for e in sim.fault_events] == [
        ("scale_out", 2), ("scale_out", 3), ("scale_in", 3)]
    assert len(sim.nn_handlers) == 4
    assert sim.nn_alive == [True, True, True, False]
    # joiners actually served work, and service never stopped
    assert sim.nn_ops_completed[2] > 0
    counts = dict(res.timeline)
    series = [counts.get(round(b * 0.01, 10), 0) for b in range(10)]
    assert all(c > 0 for c in series[1:])
