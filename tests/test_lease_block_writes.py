"""Lease-ordered grouped block-write path (ISSUE 4 tentpole).

Contract, mirroring what PR 3 asserted for the setattr path:
  1. grouped execution of add_block/append/complete_block runs leaves the
     store BYTE-IDENTICAL to sequential execution (single namenode
     dump_state), conserves OpCost, and saves round trips;
  2. same-file block ops never reorder — in the grouped executor (strict
     submission order) and under the batch planner (lease-ordered free
     dealing keeps submission order without pinning same-type runs);
  3. leases gate block writes: a second client cannot write a file under
     construction by a live holder; once the holder stops renewing, the
     LEADER reclaims the lease against the shared liveness clock
     (leader.py) and the second client's append succeeds;
  4. the write-heavy mix drives batched_write_fraction far above the PR 3
     read-mostly value (0.022) with fewer round trips than reactive.
"""
import pytest

from repro.core import (BatchPlanner, DFSClient, LeaseConflict, OpCost,
                        PlannedRequestPipeline, RequestPipeline, WorkloadOp,
                        namespace_snapshot)
from repro.core.ops_registry import REGISTRY
from repro.core.workload import (NamespaceSpec, SyntheticNamespace,
                                 WRITE_HEAVY_MIX, make_spotify_trace)

# setup recipes for the shared make_cluster fixture (tests/conftest.py)
SINGLE_NN = dict(dirs=("/a/b", "/a/c"),
                 files=tuple(f"/a/b/f{i}" for i in range(4)))
W_DIR = dict(dirs=("/w",))


def _block_indices(store, inode_id):
    rows = store.table("block").scan_all(
        lambda r: r["inode_id"] == inode_id)
    return sorted(r["index"] for r in rows)


# ---------------------------------------------------------------------------
# 1. grouped block writes == sequential execution, byte for byte
# ---------------------------------------------------------------------------

def test_grouped_block_writes_equal_sequential_state(make_cluster):
    """Runs of add_block/append/complete_block share one transaction; ids,
    sizes, block indices, ruc/replica rows and every other table must be
    byte-identical to sequential execution (execute phases run in
    submission order per file inside the group)."""
    wops = ([WorkloadOp("add_block", f"/a/b/f{i % 4}") for i in range(8)]
            + [WorkloadOp("append", f"/a/b/f{i}") for i in range(4)]
            + [WorkloadOp("complete_block", f"/a/b/f{i % 2}",
                          args={"block_id": -1, "size": 64 + i})
               for i in range(4)]
            + [WorkloadOp("add_block", "/a/b/f0"),
               WorkloadOp("add_block", "/a/b/missing")])   # in-group error
    store_b, cl_b = make_cluster(1, **SINGLE_NN)
    nn_b = cl_b.namenodes[0]
    out_b = nn_b.execute_batch(wops)
    store_s, cl_s = make_cluster(1, **SINGLE_NN)
    nn_s = cl_s.namenodes[0]
    out_s = [nn_s._safe_exec(w) for w in wops]
    assert store_b.dump_state() == store_s.dump_state()
    assert [(o.ok, o.error) for o in out_b] == \
           [(o.ok, o.error) for o in out_s]
    # the grouped write path actually served the block ops
    assert nn_b.batched_write_ops >= 12
    assert [o.error for o in out_b].count("FileNotFound") == 1
    # conserved accounting
    agg = OpCost()
    for o in out_b:
        if o.ok:
            agg.merge(o.result.cost)
    assert agg.as_dict() == nn_b.agg_cost.as_dict()


def test_grouped_block_writes_save_round_trips(make_cluster):
    wops = [WorkloadOp("add_block", f"/a/b/f{i % 4}") for i in range(8)]
    store_b, cl_b = make_cluster(1, **SINGLE_NN)
    nn_b = cl_b.namenodes[0]
    for o in nn_b.execute_batch(wops):
        assert o.ok and o.batched
    store_s, cl_s = make_cluster(1, **SINGLE_NN)
    nn_s = cl_s.namenodes[0]
    for w in wops:
        assert nn_s._safe_exec(w).ok
    assert nn_b.agg_cost.round_trips < nn_s.agg_cost.round_trips


def test_same_file_block_ops_keep_submission_order_grouped(make_cluster):
    """Ten add_blocks on ONE file in one grouped transaction must produce
    indices 0..9 exactly — each op sees the blocks written by the ops
    before it (read-your-writes inside the shared transaction)."""
    store, cl = make_cluster(1, **SINGLE_NN)
    nn = cl.namenodes[0]
    fid = nn.ops.stat("/a/b/f0").value["id"]
    out = nn.execute_batch([WorkloadOp("add_block", "/a/b/f0")
                            for _ in range(10)])
    assert all(o.ok and o.batched for o in out)
    assert _block_indices(store, fid) == list(range(10))


# ---------------------------------------------------------------------------
# 2. planner: lease-ordered dealing never reorders same-file block ops
# ---------------------------------------------------------------------------

def test_planner_frees_same_type_block_runs(make_cluster):
    """A run of add_blocks on one file is NOT pinned (lease-ordered free
    dealing): it stays groupable, and the dealt order preserves
    submission order."""
    store, cluster = make_cluster(2, **W_DIR)
    nn = cluster.namenodes[0]
    nn.ops.create("/w/hot")
    planner = BatchPlanner(cluster, batch_size=4)
    wops = [WorkloadOp("add_block", "/w/hot") for _ in range(6)]
    batches = planner.plan(wops)
    assert not any(b.ordered for b in batches)
    dealt = [i for b in batches for i in b.indices]
    assert dealt == sorted(dealt)                  # submission order kept
    assert planner.report.lease_ordered_ops == 6
    assert planner.report.pinned_ops == 0


def test_planner_pins_mixed_type_block_ops(make_cluster):
    """Mixed block-op types on ONE file (append → add_block → complete)
    would be reordered by the type sort, so they pin to submission order;
    block ops on OTHER files stay free."""
    store, cluster = make_cluster(2, **W_DIR)
    nn = cluster.namenodes[0]
    nn.ops.create("/w/mixed")
    nn.ops.create("/w/other")
    planner = BatchPlanner(cluster, batch_size=4)
    wops = [
        WorkloadOp("append", "/w/mixed"),                       # 0 pinned
        WorkloadOp("add_block", "/w/mixed"),                    # 1 pinned
        WorkloadOp("complete_block", "/w/mixed",
                   args={"block_id": -1, "size": 10}),          # 2 pinned
        WorkloadOp("add_block", "/w/other"),                    # 3 free
    ]
    batches = planner.plan(wops)
    pinned = {i for b in batches if b.ordered for i in b.indices}
    assert pinned == {0, 1, 2}
    ordered = [i for b in batches if b.ordered for i in b.indices]
    assert ordered == sorted(ordered)
    dealt = sorted(i for b in batches for i in b.indices)
    assert dealt == list(range(len(wops)))


def test_planned_same_file_block_ops_never_reorder(make_cluster):
    """End to end through the planned pipeline on one namenode: a hot file
    growing by 20 blocks (interleaved with other files' writes and reads)
    ends with indices exactly 0..19 — no duplicate or skipped index, which
    is what any reordering of same-file add_blocks would produce."""
    store, cluster = make_cluster(1, **W_DIR)
    nn = cluster.namenodes[0]
    nn.ops.create("/w/hot")
    for i in range(4):
        nn.ops.create(f"/w/cold{i}")
    hot_id = nn.ops.stat("/w/hot").value["id"]
    trace = []
    for i in range(20):
        trace.append(WorkloadOp("add_block", "/w/hot"))
        trace.append(WorkloadOp("add_block", f"/w/cold{i % 4}"))
        trace.append(WorkloadOp("read", f"/w/cold{i % 4}"))
    stats = PlannedRequestPipeline(cluster, batch_size=8).run(trace)
    assert stats.failed == 0
    assert stats.batched_write_fraction > 0
    assert _block_indices(store, hot_id) == list(range(20))
    for i in range(4):
        cid = nn.ops.stat(f"/w/cold{i}").value["id"]
        assert _block_indices(store, cid) == list(range(5))


# ---------------------------------------------------------------------------
# 3. leases: conflict, renewal, leader-driven recovery
# ---------------------------------------------------------------------------

def test_lease_conflict_blocks_second_writer(make_cluster):
    store, cluster = make_cluster(2, **W_DIR)
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")
    with pytest.raises(LeaseConflict):
        dfs.append("/w/f", client="c2")
    with pytest.raises(LeaseConflict):
        dfs.add_block("/w/f", client="c2")
    # the holder itself writes freely
    assert dfs.add_block("/w/f", client="c1") > 0


def test_leader_reclaims_dead_client_lease(make_cluster):
    """The ISSUE scenario: a client dies (stops heartbeating), the leader
    reclaims its lease against the shared liveness clock, and a second
    client's append succeeds."""
    store, cluster = make_cluster(2, **W_DIR)
    dfs = DFSClient(cluster)
    fid = dfs.create("/w/f", client="c1")
    dfs.add_block("/w/f", client="c1")
    limit = cluster.namenodes[0].ops.lease_limit
    # while c1 renews, its lease survives recovery and still conflicts
    for _ in range(limit + 2):
        cluster.tick()
        dfs.renew_lease(client="c1")
    assert cluster.recover_leases() == 0
    with pytest.raises(LeaseConflict):
        dfs.append("/w/f", client="c2")
    # c1 dies: stops renewing; the lease expires after > lease_limit ticks
    for _ in range(limit + 2):
        cluster.tick()
    # bare expiry does NOT silently admit non-takeover block writes —
    # add_block never writes under another client's inode; only the
    # leader's sweep (or an append takeover) clears the holder
    with pytest.raises(LeaseConflict):
        dfs.add_block("/w/f", client="c2")
    # a non-leader never reclaims
    assert cluster.namenodes[1].recover_leases() == 0
    assert cluster.recover_leases() >= 1
    assert store.table("lease").get(("c1",)) is None
    row = store.table("inode").scan_index("id", fid)[0]
    assert row["under_construction"] is False and row["client"] is None
    # the second client takes over, and now holds the lease itself
    assert dfs.append("/w/f", client="c2") == fid
    with pytest.raises(LeaseConflict):
        dfs.add_block("/w/f", client="c1")


def test_append_takes_over_expired_lease_without_recovery(make_cluster):
    """append acquires the lease itself, so it may take over an EXPIRED
    lease before the leader's sweep runs — and the takeover re-fences the
    file under the new holder."""
    store, cluster = make_cluster(2, **W_DIR)
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")
    for _ in range(cluster.namenodes[0].ops.lease_limit + 2):
        cluster.tick()                    # c1 never renews
    assert dfs.append("/w/f", client="c2") > 0
    with pytest.raises(LeaseConflict):
        dfs.add_block("/w/f", client="c1")
    # c2 now owns the lease row and the lease_path row
    assert store.table("lease").get(("c2",)) is not None
    assert dfs.add_block("/w/f", client="c2") > 0


def test_auto_lease_recovery_on_tick(make_cluster):
    store, cluster = make_cluster(2, auto_lease_recovery=True, **W_DIR)
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")
    for _ in range(cluster.namenodes[0].ops.lease_limit + 2):
        cluster.tick()
    assert store.table("lease").get(("c1",)) is None
    assert dfs.append("/w/f", client="c2") > 0


# ---------------------------------------------------------------------------
# 4. the write-heavy mix through the three execution modes
# ---------------------------------------------------------------------------

def test_write_heavy_mix_batches_block_writes(make_cluster):
    """The ISSUE acceptance bar: on the write-heavy mix the planned
    pipeline serves a batched_write_fraction STRICTLY above the PR 3
    read-mostly value (0.022), with fewer DB round trips than the
    reactive pipeline, and all three modes converge to the same logical
    namespace."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = make_spotify_trace(ns_ref, 400, seed=5, mix=WRITE_HEAVY_MIX)

    def build():
        return make_cluster(4, namespace=True)[:2]

    store_seq, cl = build()
    seq = RequestPipeline(cl, batch_size=1).run(trace)
    store_rea, cl = build()
    rea = RequestPipeline(cl, batch_size=16).run(trace)
    store_pln, cl = build()
    pipe = PlannedRequestPipeline(cl, batch_size=16)
    pln = pipe.run(trace)
    assert pln.ok + pln.failed == len(trace)
    assert pln.failed <= seq.failed
    assert pln.batched_write_fraction > 0.022           # the ISSUE bar
    assert pln.total_cost.round_trips < rea.total_cost.round_trips
    snap = namespace_snapshot(store_seq)
    assert snap == namespace_snapshot(store_rea)
    assert snap == namespace_snapshot(store_pln)
    rep = pipe.plan_report
    assert rep is not None and rep.lease_ordered_ops > 0


def test_block_ops_registered_group_mutable_and_lease_ordered():
    for name in ("add_block", "append", "complete_block"):
        spec = REGISTRY[name]
        assert spec.group_mutable and spec.group_apply is not None
        assert spec.lease_order is not None
        assert spec.lease_order(WorkloadOp(name, "/w/f")) == "/w/f"
    # lease ordering is a registry view, like the other derived tables
    assert set(REGISTRY.lease_ordered_ops()) == {
        "add_block", "append", "complete_block"}
