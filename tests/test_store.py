"""Unit tests: partitioned store, locks, transactions, cost accounting."""
import pytest

from repro.core import (EXCLUSIVE, READ_COMMITTED, SHARED, MetadataStore,
                        NodeGroupDown, Transaction, format_fs)
from repro.core.store import LockManager, _hash_key
from repro.core.tables import make_inode


@pytest.fixture
def store():
    s = MetadataStore(n_datanodes=4, replication=2, n_partitions=16)
    format_fs(s)
    return s


def test_partitioning_is_deterministic(store):
    t = store.table("inode")
    assert t.partition_of(42) == t.partition_of(42)
    # children co-located: same parent id -> same partition (paper §4.2)
    parts = {t.partition_of(7) for _ in range(10)}
    assert len(parts) == 1


def test_children_on_same_shard(store):
    t = store.table("inode")
    for i in range(50):
        t.put(make_inode(100 + i, 7, f"f{i}", False))
    part = t.partition_of(7)
    rows = t.scan_partition(part, lambda r: r["parent_id"] == 7)
    assert len(rows) == 50


def test_file_metadata_colocated(store):
    """Blocks/replicas of one file share a shard (distribution-aware read)."""
    bt, rt = store.table("block"), store.table("replica")
    assert bt.partition_of(12345) == rt.partition_of(12345)


def test_node_groups_and_failures(store):
    assert store.n_groups == 2
    store.fail_datanode(0)
    assert store.available()          # replica in the group survives
    store.fail_datanode(1)
    assert not store.available()      # group 0 fully down
    with pytest.raises(NodeGroupDown):
        for p in range(store.n_partitions):
            store.check_available(p)
    store.recover_datanode(0)
    assert store.available()


def test_transaction_commit_and_abort(store):
    txn = Transaction(store, partition_hint=("inode", 1))
    txn.write("inode", make_inode(50, 1, "a", True))
    txn.commit()
    assert store.table("inode").get((1, "a")) is not None

    txn2 = Transaction(store, partition_hint=("inode", 1))
    txn2.write("inode", make_inode(51, 1, "b", True))
    txn2.abort()
    assert store.table("inode").get((1, "b")) is None


def test_row_locks_block_conflicts(store):
    lm = LockManager(timeout=0.05)
    lm.acquire(1, "inode", (1, "x"), EXCLUSIVE)
    from repro.core import LockTimeout
    with pytest.raises(LockTimeout):
        lm.acquire(2, "inode", (1, "x"), SHARED)
    lm.release_all(1)
    lm.acquire(2, "inode", (1, "x"), SHARED)   # now fine
    lm.acquire(3, "inode", (1, "x"), SHARED)   # shared compatible


def test_batch_counts_one_round_trip(store):
    txn = Transaction(store, partition_hint=("inode", 1))
    txn.read_batch([("inode", (0, ""), READ_COMMITTED)] * 5)
    assert txn.cost.batches == 1
    assert txn.cost.batch_rows == 5
    assert txn.cost.round_trips == 1
    txn.abort()


def test_ppis_vs_is_cost(store):
    t = store.table("inode")
    for i in range(10):
        t.put(make_inode(200 + i, 9, f"c{i}", False))
    txn = Transaction(store, partition_hint=("inode", 9))
    txn.ppis("inode", "parent_id", 9)
    assert txn.cost.ppis == 1 and txn.cost.is_scans == 0
    txn.index_scan("inode", "parent_id", 9)
    assert txn.cost.is_scans == 1
    txn.abort()


def test_distribution_awareness_locality(store):
    """Hinted transactions read hint-partition rows locally (§2.2)."""
    t = store.table("inode")
    t.put(make_inode(300, 11, "kid", False))
    txn = Transaction(store, partition_hint=("inode", 11))
    txn.ppis("inode", "parent_id", 11)
    assert txn.cost.local_rt == 1 and txn.cost.remote_rt == 0
    txn.abort()
    txn2 = Transaction(store, partition_hint=("inode", 11),
                       distribution_aware=False)
    txn2.ppis("inode", "parent_id", 11)
    # round-robin coordinator: locality is accidental at best
    assert txn2.cost.local_rt + txn2.cost.remote_rt == 1
    txn2.abort()


def test_memory_accounting(store):
    before = store.memory_bytes()
    store.table("inode").put(make_inode(400, 1, "m", False))
    after = store.memory_bytes()
    assert after - before == 296 * store.replication
