"""Differential property suite: ARBITRARY interleaved op sequences
(create / mkdirs / rename / delete / block ops, multiple clients) leave
the dict-backed and columnar-backed stores equivalent.

Fixed-seed regressions below run everywhere; the hypothesis property
suite at the bottom engages only where hypothesis is installed, under the
pinned derandomized "chaos" profile from conftest (so CI failures always
reproduce locally). Three invariants per generated sequence:

  1. namespace equality — ``namespace_snapshot`` identical on both
     backends, and ``dump_state`` byte-equal;
  2. conserved OpCost — per-namenode merge == pipeline total == per-op
     merge, on BOTH backends, and the totals agree across backends;
  3. zero orphan rows — no block row referencing a missing inode, and no
     lease_path row surviving the leader scrub, on either backend.
"""
import random

import pytest

from repro.core import OpCost, WorkloadOp, namespace_snapshot

# A small closed path universe keeps collisions (create-over-create,
# rename onto a live target, delete of a miss) FREQUENT — that's where
# layout bugs hide, because both backends must fail identically too.
DIRS = [f"/p{i}" for i in range(4)]
NAMES = [f"f{i}" for i in range(5)]
CLIENTS = ["c0", "c1", "c2"]


def _op_from(rng):
    d = rng.choice(DIRS)
    f = f"{d}/{rng.choice(NAMES)}"
    c = rng.choice(CLIENTS)
    kind = rng.randrange(9)
    if kind == 0:
        return WorkloadOp("mkdirs", d)
    if kind == 1:
        return WorkloadOp("create", f, args={"client": c})
    if kind == 2:
        return WorkloadOp("add_block", f, args={"client": c})
    if kind == 3:
        return WorkloadOp("complete_block", f,
                          args={"block_id": -1, "size": 1 << 16,
                                "client": c})
    if kind == 4:
        return WorkloadOp("rename_file", f,
                          f"{rng.choice(DIRS)}/{rng.choice(NAMES)}")
    if kind == 5:
        return WorkloadOp("delete_file", f)
    if kind == 6:
        return WorkloadOp("delete_subtree", d, on_dir=True)
    if kind == 7:
        return WorkloadOp("stat", f)
    return WorkloadOp("ls", d, on_dir=True)


def _random_trace(seed, n_ops=40):
    rng = random.Random(seed)
    return [_op_from(rng) for _ in range(n_ops)]


def _inode_ids(store):
    ids = set()
    for part in store.table("inode").parts:
        for row in part.values():
            ids.add(row["id"])
    return ids


def _orphans(store, cluster):
    """(orphan blocks, orphan lease_paths after the leader scrub)."""
    ids = _inode_ids(store)
    blocks = [r for part in store.table("block").parts
              for r in part.values() if r["inode_id"] not in ids]
    # the model DEFERS orphaned-lease-path cleanup to the leader's scrub
    # (see Namenode docs) — drain it, then nothing may remain
    for _ in range(10):
        if cluster.scrub_leases() == 0:
            break
    lps = [r for part in store.table("lease_path").parts
           for r in part.values() if r["inode_id"] not in ids]
    return blocks, lps


def _check_equivalent(dres, cres):
    (ds, dc, dstats), (cs, cc, cstats) = dres, cres
    assert ds.dump_state() == cs.dump_state()
    assert namespace_snapshot(ds) == namespace_snapshot(cs)
    assert [o.ok for o in dstats.outcomes] == \
        [o.ok for o in cstats.outcomes]
    for stats in (dstats, cstats):
        per_nn = OpCost()
        for c in stats.per_nn_cost.values():
            per_nn.merge(c)
        per_op = OpCost()
        for o in stats.outcomes:
            if o.ok:
                per_op.merge(o.result.cost)
        assert per_nn.as_dict() == stats.total_cost.as_dict() \
            == per_op.as_dict()
    assert dstats.total_cost.as_dict() == cstats.total_cost.as_dict()
    for store, cluster in ((ds, dc), (cs, cc)):
        blocks, lps = _orphans(store, cluster)
        assert blocks == [], f"orphan block rows: {blocks}"
        assert lps == [], f"orphan lease_path rows survived scrub: {lps}"
    # scrubbing is itself namespace-neutral and must stay byte-equal
    assert ds.dump_state() == cs.dump_state()


# ---------------------------------------------------------------------------
# fixed-seed regressions (run everywhere, hypothesis or not)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_sequential_differential_fixed_seeds(differential_replay, seed):
    d, c = differential_replay(_random_trace(seed),
                               pipeline="sequential")
    _check_equivalent(d, c)


@pytest.mark.parametrize("seed", [100, 101, 102])
def test_reactive_differential_interleaved_namenodes(differential_replay,
                                                     seed):
    d, c = differential_replay(_random_trace(seed, n_ops=60),
                               pipeline="reactive", n_namenodes=2,
                               batch_size=4)
    _check_equivalent(d, c)


@pytest.mark.parametrize("seed", [200, 201, 202])
def test_planned_differential_fixed_seeds(differential_replay, seed):
    # default kernel gates (128) stay above these window sizes, so both
    # backends walk the identical pure-Python planner path; the
    # kernels-engaged differential lives in test_columnar_store
    d, c = differential_replay(_random_trace(seed, n_ops=60),
                               pipeline="planned", n_namenodes=2,
                               batch_size=4, window=16)
    _check_equivalent(d, c)


# ---------------------------------------------------------------------------
# property suite (engages only where hypothesis is installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _dir = st.sampled_from(DIRS)
    _name = st.sampled_from(NAMES)
    _client = st.sampled_from(CLIENTS)
    _file = st.builds(lambda d, n: f"{d}/{n}", _dir, _name)

    _op = st.one_of(
        st.builds(lambda d: WorkloadOp("mkdirs", d), _dir),
        st.builds(lambda f, c: WorkloadOp("create", f,
                                          args={"client": c}),
                  _file, _client),
        st.builds(lambda f, c: WorkloadOp("add_block", f,
                                          args={"client": c}),
                  _file, _client),
        st.builds(lambda f, c: WorkloadOp(
            "complete_block", f,
            args={"block_id": -1, "size": 1 << 16, "client": c}),
            _file, _client),
        st.builds(lambda s, d2, n2: WorkloadOp("rename_file", s,
                                               f"{d2}/{n2}"),
                  _file, _dir, _name),
        st.builds(lambda f: WorkloadOp("delete_file", f), _file),
        st.builds(lambda d: WorkloadOp("delete_subtree", d, on_dir=True),
                  _dir),
        st.builds(lambda f: WorkloadOp("stat", f), _file),
        st.builds(lambda d: WorkloadOp("ls", d, on_dir=True), _dir),
    )
    _trace = st.lists(_op, min_size=1, max_size=40)

    _SETTINGS = dict(
        suppress_health_check=[HealthCheck.function_scoped_fixture,
                               HealthCheck.too_slow],
        deadline=None)

    @given(wops=_trace)
    @settings(**_SETTINGS)
    def test_sequential_differential_property(differential_replay, wops):
        d, c = differential_replay(wops, pipeline="sequential")
        _check_equivalent(d, c)

    @given(wops=_trace)
    @settings(max_examples=10, **_SETTINGS)
    def test_planned_differential_property(differential_replay, wops):
        d, c = differential_replay(wops, pipeline="planned",
                                   n_namenodes=2, batch_size=4,
                                   window=16)
        _check_equivalent(d, c)
