"""Differential harness locking the columnar engine to the dict oracle.

The struct-of-arrays backend (``repro.core.columnar``) is pure layout:
every replay that runs on the dict-backed ``MetadataStore`` must leave a
``ColumnarMetadataStore`` in a byte-identical state (``dump_state``
equality), with identical OpCost accounting wherever both backends walk
the same code path, and conserved accounting always.  Three layers:

  1. table-interface parity — ``ColumnarTable`` mirrors ``Table`` row op
     by row op (updates, deletes, partition-key relocation, scans, parts
     views, secondary indexes);
  2. ``HashIndex`` — the kernel-facing open-addressing index agrees with
     the pkval numpy oracle probe-for-probe, survives growth/tombstone
     churn, and poisons crc-collided buckets with AMBIG;
  3. replay differentials — sequential / reactive / planned pipelines on
     the Spotify and write-heavy mixes, with and without the fused
     kernels engaged (gates monkeypatched down), plus namenode-side
     pkval demotion of genuinely stale hint chains.
"""
import numpy as np
import pytest

from repro.core import (MetadataStore, NamenodeCluster, OpCost,
                        PlannedRequestPipeline, RequestPipeline,
                        WorkloadOp, format_fs, materialize_namespace,
                        namespace_snapshot)
import repro.core.columnar as columnar
from repro.core.columnar import (AMBIG, ColumnarMetadataStore,
                                 ColumnarTable, EMPTY, HashIndex,
                                 MAX_PROBE)
from repro.core.store import Table
from repro.core.tables import BLOCK, INODE, make_block, make_inode
from repro.core.workload import (SyntheticNamespace, NamespaceSpec,
                                 WRITE_HEAVY_MIX, make_spotify_trace,
                                 name_hash32)

N_PARTS = 16


def _trace(n_ops=300, *, mix=None, seed=5, n_dirs=16):
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                            files_per_dir=4)
    kw = {"mix": mix} if mix is not None else {}
    return make_spotify_trace(ns, n_ops, seed=seed, **kw)


def _conserved(stats):
    per_nn = OpCost()
    for c in stats.per_nn_cost.values():
        per_nn.merge(c)
    per_op = OpCost()
    for o in stats.outcomes:
        if o.ok:
            per_op.merge(o.result.cost)
    assert per_nn.as_dict() == stats.total_cost.as_dict() \
        == per_op.as_dict()


# ---------------------------------------------------------------------------
# 1. table-interface parity
# ---------------------------------------------------------------------------

def _mirror_check(dt: Table, ct: ColumnarTable):
    assert dt.n_rows == ct.n_rows
    assert dt.parts == ct.parts
    assert dt.idx == ct.idx
    for part in dt.parts:
        for pk in part:
            assert dt.get(pk) == ct.get(pk)
            assert dt.partition_of_pk(pk) == ct.partition_of_pk(pk)


def test_inode_table_parity_under_churn():
    rng = np.random.default_rng(7)
    dt, ct = Table(INODE, N_PARTS), ColumnarTable(INODE, N_PARTS)
    live = []
    for step in range(400):
        r = rng.random()
        if r < 0.55 or not live:
            iid = 100 + step
            row = make_inode(iid, int(rng.integers(1, 40)),
                             f"n{step % 37}", bool(rng.random() < 0.3))
            dt.put(dict(row))
            ct.put(dict(row))
            pk = (row["parent_id"], row["name"])
            # (parent, name) can repeat across steps — put overwrites, so
            # live must stay duplicate-free or a delete strands a stale pk
            if pk not in live:
                live.append(pk)
        elif r < 0.8:
            pk = live[int(rng.integers(len(live)))]
            old = dt.get(pk)
            if old is not None:
                upd = dict(old)
                upd["size"] = int(rng.integers(1 << 20))
                upd["under_construction"] = bool(rng.random() < 0.5)
                dt.put(dict(upd))
                ct.put(dict(upd))
        else:
            pk = live.pop(int(rng.integers(len(live))))
            assert dt.delete(pk) == ct.delete(pk)
    _mirror_check(dt, ct)
    # scans agree (scan_index returns whatever set order — compare sorted)
    for parent in range(1, 40):
        a = sorted(dt.scan_index("parent_id", parent),
                   key=lambda r: r["name"])
        b = sorted(ct.scan_index("parent_id", parent),
                   key=lambda r: r["name"])
        assert a == b
    for p in range(N_PARTS):
        assert dt.scan_partition(p, lambda r: True) \
            == ct.scan_partition(p, lambda r: True)
    assert dt.scan_all(lambda r: r["size"] > 0) \
        == ct.scan_all(lambda r: r["size"] > 0)
    # the kernel-facing index resolves every live row
    for pk in live:
        row = dt.get(pk)
        got = ct.hindex.get(pk[0], name_hash32(pk[1]))
        assert got == row["id"] or got == AMBIG


def test_block_table_partition_key_relocation():
    dt, ct = Table(BLOCK, N_PARTS), ColumnarTable(BLOCK, N_PARTS)
    for b in range(40):
        row = make_block(1000 + b, 10 + (b % 4), b)
        dt.put(dict(row))
        ct.put(dict(row))
    # concat-style re-owning: the partition key (inode_id) changes, which
    # must move the row between shards without duplicating the PK
    for b in range(0, 40, 3):
        row = dict(dt.get((1000 + b,)))
        row["inode_id"] = 99
        dt.put(dict(row))
        ct.put(dict(row))
    _mirror_check(dt, ct)
    assert dt.n_rows == ct.n_rows == 40
    assert sorted(r["block_id"] for r in ct.scan_index("inode_id", 99)) \
        == sorted(r["block_id"] for r in dt.scan_index("inode_id", 99))
    # part_hint probes miss on the wrong shard, like the dict store
    pk = (1000,)
    right = ct.partition_of_pk(pk)
    assert ct.get(pk, part_hint=right) is not None
    assert ct.get(pk, part_hint=(right + 1) % N_PARTS) is None


def test_materialized_rows_are_pure_python():
    ct = ColumnarTable(INODE, N_PARTS)
    ct.put(make_inode(2, 1, "a", False, size=7))
    row = ct.get((1, "a"))
    for v in row.values():
        assert not isinstance(v, np.generic), (row, type(v))
    # dump_state sorts by repr(pk): tuples must hold plain ints/strs
    assert repr((1, "a")) == repr(tuple(ct.parts[
        ct.partition_of_pk((1, "a"))].keys())[0])


# ---------------------------------------------------------------------------
# 2. HashIndex (kernel-facing open addressing)
# ---------------------------------------------------------------------------

def test_hashindex_growth_tombstones_and_reuse():
    idx = HashIndex(cap=64)
    keys = [(p, name_hash32(f"k{p}")) for p in range(1, 400)]
    for p, h in keys:
        idx.set(p, h, p * 2)
    assert idx.cap & (idx.cap - 1) == 0 and idx.cap > 64
    for p, h in keys:
        assert idx.get(p, h) == p * 2
    for p, h in keys[::2]:
        assert idx.remove(p, h)
    for p, h in keys[::2]:
        assert idx.get(p, h) == EMPTY
    for p, h in keys[1::2]:
        assert idx.get(p, h) == p * 2          # survivors probe past tombs
    for p, h in keys[::2]:
        idx.set(p, h, p * 3)                   # tombstone slots reused
    for p, h in keys[::2]:
        assert idx.get(p, h) == p * 3


def test_hashindex_agrees_with_pkval_oracle():
    from repro.kernels.pkval.ref import pkval_ref
    idx = HashIndex()
    rng = np.random.default_rng(3)
    keys = [(int(rng.integers(1, 10_000)), name_hash32(f"f{i}"))
            for i in range(500)]
    for i, (p, h) in enumerate(keys):
        idx.set(p, h, i + 2)
    misses = [(int(rng.integers(10_001, 20_000)), name_hash32(f"m{i}"))
              for i in range(100)]
    probes = keys + misses
    out = pkval_ref(*idx.arrays(),
                    np.array([p for p, _ in probes], np.int32),
                    np.array([h for _, h in probes], np.uint32))
    for i, (p, h) in enumerate(probes):
        assert int(out[i]) == idx.get(p, h)


def test_hashindex_ambig_poisoning(monkeypatch):
    # force 32-bit name-hash collisions with a deliberately coarse hash
    monkeypatch.setattr(columnar, "name_hash32", lambda s: len(s) % 4)
    idx = HashIndex.from_entries([(1, "aa", 10), (1, "bb", 11),
                                  (1, "x", 12)])
    assert idx.get(1, 2) == AMBIG              # "aa"/"bb" collide
    assert idx.get(1, 1) == 12                 # "x" unambiguous
    # table maintenance keeps poisoning exact under delete churn
    ct = ColumnarTable(INODE, N_PARTS)
    ct.put(make_inode(5, 1, "aa", False))
    ct.put(make_inode(6, 1, "bb", False))
    assert ct.hindex.get(1, 2) == AMBIG
    ct.delete((1, "bb"))
    assert ct.hindex.get(1, 2) == 5            # back to unambiguous
    ct.delete((1, "aa"))
    assert ct.hindex.get(1, 2) == EMPTY


def test_sentinels_match_kernel_package():
    from repro.kernels.pkval import kernel as pk
    assert MAX_PROBE == pk.MAX_PROBE
    assert columnar._GOLDEN == pk.GOLDEN
    assert columnar._GOLDEN2 == pk.GOLDEN2


# ---------------------------------------------------------------------------
# 3. replay differentials (the oracle lock)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mix_name,mix", [("spotify", None),
                                          ("write_heavy",
                                           WRITE_HEAVY_MIX)])
def test_sequential_replay_byte_equal(differential_replay, mix_name, mix):
    wops = _trace(300, mix=mix)
    (sd, cd, st_d), (sc, cc, st_c) = differential_replay(
        wops, namespace=True, pipeline="sequential")
    assert sd.dump_state() == sc.dump_state()
    # identical code path => op-for-op identical cost accounting
    assert st_d.total_cost.as_dict() == st_c.total_cost.as_dict()
    for a, b in zip(st_d.outcomes, st_c.outcomes):
        assert a.ok == b.ok
        if a.ok:
            assert a.result.cost.as_dict() == b.result.cost.as_dict()
    _conserved(st_c)


def test_reactive_replay_byte_equal(differential_replay):
    wops = _trace(300)
    (sd, _, st_d), (sc, _, st_c) = differential_replay(
        wops, n_namenodes=2, namespace=True, pipeline="reactive")
    assert sd.dump_state() == sc.dump_state()
    assert st_d.total_cost.as_dict() == st_c.total_cost.as_dict()
    _conserved(st_c)


@pytest.mark.parametrize("mix_name,mix", [("spotify", None),
                                          ("write_heavy",
                                           WRITE_HEAVY_MIX)])
def test_planned_replay_byte_equal(differential_replay, mix_name, mix):
    wops = _trace(300, mix=mix)
    (sd, _, st_d), (sc, _, st_c) = differential_replay(
        wops, n_namenodes=2, namespace=True, pipeline="planned")
    assert sd.dump_state() == sc.dump_state()
    assert namespace_snapshot(sd) == namespace_snapshot(sc)
    _conserved(st_d)
    _conserved(st_c)


def test_planned_replay_with_kernels_engaged(monkeypatch):
    """Drop both fused-kernel gates to the floor so every window launches,
    and re-assert the oracle lock: the kernels are advisory, so final
    state stays byte-identical while launches actually happen."""
    monkeypatch.setattr(columnar, "HINTCHAIN_MIN_BATCH", 2)
    monkeypatch.setattr(columnar, "PKVAL_MIN_BATCH", 2)
    wops = _trace(240)
    states, reports = {}, {}
    for name, cls in (("dict", MetadataStore),
                      ("columnar", ColumnarMetadataStore)):
        store = cls(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, 2)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=16,
                                files_per_dir=4)
        materialize_namespace(cluster.namenodes[0], ns)
        pipe = PlannedRequestPipeline(cluster, batch_size=8, window=64)
        stats = pipe.run(list(wops))
        _conserved(stats)
        states[name] = store.dump_state()
        reports[name] = pipe.plan_report
    assert states["dict"] == states["columnar"]
    # hint-chain fusion is resolver-side: both backends launch it
    assert reports["dict"].hintchain_launches > 0
    assert reports["columnar"].hintchain_launches > 0
    # PK validation needs the columnar hash index: dict backend skips it
    assert reports["dict"].pkval_probes == 0
    assert reports["columnar"].pkval_probes > 0
    assert reports["columnar"].pkval_launches > 0


def test_namenode_prevalidation_demotes_stale_chains(monkeypatch):
    """A hint chain the cache still believes but the store no longer
    backs must be demoted by the fused pkval prevalidation — and the op
    still gets the exact sequential path's answer."""
    monkeypatch.setattr(columnar, "PKVAL_MIN_BATCH", 2)
    store = ColumnarMetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 1)
    nn = cluster.namenodes[0]
    nn.ops.mkdirs("/d")
    nn.ops.create("/d/f")
    nn.ops.create("/d/g")
    # warm the namenode hint cache through real reads
    reads = [WorkloadOp("read", "/d/f"), WorkloadOp("read", "/d/g")]
    nn.execute_batch(reads)
    # yank the rows out from under the cache (no invalidation piggyback)
    t = store.table("inode")
    fid = t.get((next(r["id"] for r in t.scan_index("parent_id", 1)
                      if r["name"] == "d"), "f"))
    assert fid is not None
    assert t.delete((fid["parent_id"], "f"))
    before = nn.pkval_demotions
    outcomes = nn.execute_batch(reads * 2)
    assert nn.pkval_demotions > before
    assert nn.pkval_launches >= 1
    # the stale-path reads fail exactly like a sequential miss would;
    # the intact chain still succeeds
    by_path = {}
    for wop, oc in zip(reads * 2, outcomes):
        by_path.setdefault(wop.path, []).append(oc)
    assert all(not oc.ok for oc in by_path["/d/f"])
    assert all(oc.ok for oc in by_path["/d/g"])


def test_store_construction_parity():
    sd = MetadataStore(n_datanodes=4)
    sc = ColumnarMetadataStore(n_datanodes=4)
    format_fs(sd)
    format_fs(sc)
    assert sd.dump_state() == sc.dump_state()
    assert sd.memory_bytes() == sc.memory_bytes()
    for name in ColumnarMetadataStore.COLUMNAR_TABLES:
        assert isinstance(sc.table(name), ColumnarTable)
