"""Deterministic chaos fault injection + failover/recovery (ISSUE 6).

Contract, per §7.6 ("failure of the leader or any other namenode does not
result in a metadata service downtime"):

  1. every scheduled fault — crash or partition, at any named write-path
     site — leaves a cluster that the recovery protocol (tick past the
     heartbeat staleness bound, leader housekeeping, re-drive transient
     failures on survivors) converges to EXACTLY the fault-free oracle's
     namespace, with conserved OpCost, zero orphan lease/UC/block rows
     and a fully-released LockManager;
  2. the injector itself is deterministic (same plan + same trace = same
     events and same final state) and safe (never kills the last alive
     namenode, partitions always heal);
  3. the client retry taxonomy is exact: txn_retry re-runs LockTimeout /
     TransactionAborted but never multi-transaction subtree ops; failover
     masks dead and unreachable namenodes and propagates genuine FS
     outcomes — and its one at-most-once gap (die AFTER commit) is
     pinned by a test, not hidden;
  4. ``recover_lease`` gives a new writer HDFS's recoverLease takeover:
     refused while the holder's lease is live, granted after the soft
     limit expires.

Fixed-seed regressions below run everywhere; the hypothesis property
suite at the bottom engages only where hypothesis is installed (the CI
``chaos`` step pins a derandomized profile in conftest.py).
"""
import pytest

from repro.core import (ChaosPlan, DFSClient, Fault, FaultInjector,
                        FaultSite, FileNotFound, LeaseConflict,
                        NetworkPartition, RecoveryInvariants, StoreError,
                        WorkloadOp, namespace_snapshot,
                        replay_with_recovery)
from repro.core.chaos import CRASH, DELAY, PARTITION, RETRYABLE_ERRORS
from repro.core.dfs_client import error_for
from repro.core.middleware import (CallContext, compose, failover,
                                   txn_retry)
from repro.core.ops_registry import REGISTRY
from repro.core.store import LockTimeout, TransactionAborted
from repro.core.workload import (NamespaceSpec, SpotifyWorkload,
                                 SyntheticNamespace, WRITE_HEAVY_MIX)

pytestmark = pytest.mark.chaos


def _write_heavy_trace(n=160, seed=7):
    """Deterministic write-heavy trace over the shared synthetic
    namespace (the one ``make_cluster(..., namespace=True)`` builds)."""
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    return SpotifyWorkload(ns, seed=seed, mix=WRITE_HEAVY_MIX).make_trace(n)


def _assert_converged(store, cluster, rep, oracle):
    inv = RecoveryInvariants(store, cluster)
    inv.assert_all(oracle, outcome_cost=rep.outcome_cost,
                   per_nn_delta=rep.per_nn_delta,
                   housekeeping=rep.housekeeping_cost)


# ---------------------------------------------------------------------------
# 1. the schedule language: sites, plans, determinism, safety
# ---------------------------------------------------------------------------

def test_fault_site_catalog_is_stable():
    """The site strings are the contract between the injector and the
    host modules (which fire them by name, never importing chaos.py)."""
    assert {s.value for s in FaultSite} == {
        "rpc", "batch_exchange", "group_txn_pre_lock",
        "group_txn_post_lock", "subtree_chunk", "heartbeat"}


def test_partitions_only_at_client_exchanges():
    with pytest.raises(AssertionError):
        Fault(FaultSite.SUBTREE_CHUNK, kind=PARTITION)
    with pytest.raises(AssertionError):
        Fault(FaultSite.RPC, kind=PARTITION, heal_after=0)  # must heal
    # crash is legal everywhere
    for site in FaultSite:
        Fault(site, kind=CRASH)


def test_seeded_plans_are_deterministic_and_seed_sensitive():
    a = ChaosPlan.seeded(11, n_namenodes=4, n_faults=3)
    b = ChaosPlan.seeded(11, n_namenodes=4, n_faults=3)
    assert a == b
    assert any(ChaosPlan.seeded(s, n_namenodes=4, n_faults=3) != a
               for s in range(5))


def test_injector_runs_are_deterministic(make_cluster):
    """Same plan, same trace, twin clusters: identical event streams and
    byte-identical final namespaces."""
    plan = ChaosPlan.seeded(3, n_namenodes=3, n_faults=2)

    def run():
        store, cluster, _ = make_cluster(3, namespace=True)
        inj = FaultInjector(plan, cluster)
        replay_with_recovery(cluster, _write_heavy_trace(120),
                             injector=inj, batch_size=8)
        return inj.events, namespace_snapshot(store)

    ev_a, snap_a = run()
    ev_b, snap_b = run()
    assert ev_a == ev_b
    assert snap_a == snap_b


def test_injector_never_kills_last_namenode(make_cluster):
    store, cluster = make_cluster(1, dirs=("/w",), files=("/w/f",))
    plan = ChaosPlan((Fault(FaultSite.RPC, at=0),))
    inj = FaultInjector(plan, cluster)
    with inj:
        cluster.namenodes[0].perform("stat", "/w/f")
    assert [e.action for e in inj.events] == ["skipped-last-nn"]
    assert cluster.namenodes[0].alive
    assert inj.injected == []


# ---------------------------------------------------------------------------
# 2. crash scenarios: group txn, subtree chunks, heartbeat/leader
# ---------------------------------------------------------------------------

def test_crash_before_group_txn_lock_recovers(make_cluster, oracle_replay):
    """A namenode dying just before the grouped transaction's lock phase:
    nothing was locked, nothing committed — recovery re-drives the whole
    batch on survivors and converges to the oracle."""
    trace = _write_heavy_trace(160)
    oracle, _ = oracle_replay(trace, namespace=True)
    store, cluster, _ = make_cluster(4, namespace=True)
    inj = FaultInjector(
        ChaosPlan((Fault(FaultSite.GROUP_TXN_PRE_LOCK, at=1),)), cluster)
    rep = replay_with_recovery(cluster, trace, injector=inj, batch_size=8)
    assert [e.action for e in inj.injected] == ["killed"]
    assert len(cluster.alive_namenodes()) == 3
    _assert_converged(store, cluster, rep, oracle)


def test_crash_holding_group_txn_locks_recovers(make_cluster,
                                                oracle_replay):
    """The hard case: the namenode dies HOLDING the group's row locks.
    The transaction aborts (locks released — lock_violations is part of
    the converged check), the in-flight ops fail over, and the namespace
    still equals the oracle."""
    trace = _write_heavy_trace(160)
    oracle, _ = oracle_replay(trace, namespace=True)
    store, cluster, _ = make_cluster(4, namespace=True)
    inj = FaultInjector(
        ChaosPlan((Fault(FaultSite.GROUP_TXN_POST_LOCK, at=2),)), cluster)
    rep = replay_with_recovery(cluster, trace, injector=inj, batch_size=8)
    assert [e.action for e in inj.injected] == ["killed"]
    _assert_converged(store, cluster, rep, oracle)


def test_crash_between_subtree_chunks_survivor_reclaims(make_cluster):
    """§6.2: a namenode dying between phase-3 chunk commits leaves the
    subtree flag set and a partially-deleted tree.  The survivor's retry
    finds the dead owner's ongoing-subtree-ops row, reclaims the lock,
    and completes the delete — no stale flag, no orphan rows."""
    files = tuple(f"/big/f{i:02d}" for i in range(12))
    store, cluster = make_cluster(2, dirs=("/big",), files=files)
    for nn in cluster.namenodes:
        nn.subtree.batch_size = 4          # force multiple chunks
    inj = FaultInjector(
        ChaosPlan((Fault(FaultSite.SUBTREE_CHUNK, at=1),)), cluster)
    rep = replay_with_recovery(
        cluster, [WorkloadOp("delete_subtree", "/big")], injector=inj,
        batch_size=1)
    assert [e.action for e in inj.injected] == ["killed"]
    assert rep.ok == 1 and rep.recovery_rounds >= 1
    assert store.table("inode").scan_index("name", "big") == []
    inv = RecoveryInvariants(store, cluster)
    assert inv.orphan_violations() == []   # flag + ongoing row reclaimed
    assert inv.lock_violations() == []


def test_crash_midway_through_paced_big_dir_delete(make_cluster):
    """Compose test for the incremental engine (ISSUE 10): the executing
    namenode dies at a ``subtree_chunk`` boundary midway through a PACED
    delete of a 10^4-inode directory.  The pace hook (the point where
    adjacent ops interleave) must have run before the crash, the survivor
    must reclaim the dead owner's stale flag and re-drive the delete to
    completion, and the final namespace must equal a fresh cluster that
    never held the big directory at all."""
    from repro.core import materialize_big_dir
    store, cluster = make_cluster(2, dirs=("/w",))
    materialize_big_dir(cluster.namenodes[0], "/big", 10_000)
    paces = [0]
    for nn in cluster.namenodes:
        nn.subtree.batch_size = 512
        nn.subtree.pace = lambda: paces.__setitem__(0, paces[0] + 1)
    inj = FaultInjector(
        ChaosPlan((Fault(FaultSite.SUBTREE_CHUNK, at=6),)), cluster)
    rep = replay_with_recovery(
        cluster, [WorkloadOp("delete_subtree", "/big")], injector=inj,
        batch_size=1)
    assert [e.action for e in inj.injected] == ["killed"]
    assert paces[0] >= 6                     # interleaving ran pre-crash
    assert rep.ok == 1 and rep.recovery_rounds >= 1
    assert store.table("inode").scan_index("name", "big") == []
    inv = RecoveryInvariants(store, cluster)
    assert inv.orphan_violations() == []
    assert inv.lock_violations() == []
    oracle_store, _ = make_cluster(1, dirs=("/w",))
    assert namespace_snapshot(store) == namespace_snapshot(oracle_store)


def test_heartbeat_fault_moves_leadership_and_lease_recovery(make_cluster):
    """Leader death detected through the election itself: the HEARTBEAT
    fault suppresses the victim's liveness proof (it dies instead of
    renewing), the lease-clock marches on, and the NEW leader performs
    the lease recovery the dead one owed."""
    store, cluster = make_cluster(3, dirs=("/w",))
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")        # c1 then silently dies too
    old = cluster.leader()
    inj = FaultInjector(
        ChaosPlan((Fault(FaultSite.HEARTBEAT, at=0, victim=old.nn_id),)),
        cluster)
    limit = cluster.namenodes[0].ops.lease_limit
    with inj:
        for _ in range(max(limit, cluster.election.max_missed) + 2):
            cluster.tick()
    assert [e.action for e in inj.injected] == ["killed"]
    assert not old.alive
    new = cluster.leader()
    assert new is not None and new.alive and new.nn_id != old.nn_id
    # the dead ex-leader refuses housekeeping; the new leader reclaims
    assert old.recover_leases() == 0
    assert cluster.recover_leases() >= 1
    assert store.table("lease").get(("c1",)) is None
    assert dfs.append("/w/f", client="c2") > 0


# ---------------------------------------------------------------------------
# 3. partitions: client-visible unreachability that always heals
# ---------------------------------------------------------------------------

def test_client_partition_masked_by_failover(make_cluster):
    """A partitioned namenode is indistinguishable from a dead one to the
    client (§7.6.1): DFSClient's failover middleware retries the op on
    another namenode; nothing surfaces to the caller."""
    store, cluster = make_cluster(2, dirs=("/w",), files=("/w/f",))
    dfs = DFSClient(cluster)
    inj = FaultInjector(
        ChaosPlan((Fault(FaultSite.RPC, at=0, kind=PARTITION,
                         heal_after=2),)), cluster)
    with inj:
        for _ in range(4):
            assert dfs.add_block("/w/f") > 0
    fid = dfs.stat("/w/f").inode_id
    idx = sorted(r["index"] for r in store.table("block").scan_all(
        lambda r: r["inode_id"] == fid))
    assert idx == [0, 1, 2, 3]               # all four landed exactly once
    assert dfs.retries >= 1
    assert "partitioned" in [e.action for e in inj.events]
    assert all(nn.alive for nn in cluster.namenodes)


def test_partition_during_block_write_run_heals_and_converges(
        make_cluster, oracle_replay):
    """A mid-run partition on batch exchanges: the pipeline requeues the
    refused batches, the partition heals after its budget, and the final
    state matches the fault-free oracle with all invariants intact."""
    files = tuple(f"/w/f{i}" for i in range(4))
    trace = [WorkloadOp("add_block", files[i % 4]) for i in range(24)]
    oracle, oouts = oracle_replay(trace, dirs=("/w",), files=files)
    store, cluster = make_cluster(2, dirs=("/w",), files=files)
    inj = FaultInjector(
        ChaosPlan((Fault(FaultSite.BATCH_EXCHANGE, at=1, kind=PARTITION,
                         heal_after=3),)), cluster)
    rep = replay_with_recovery(cluster, trace, injector=inj, batch_size=4)
    actions = [e.action for e in inj.events]
    assert "partitioned" in actions and "healed" in actions
    assert rep.ok == sum(1 for o in oouts if o.ok)
    _assert_converged(store, cluster, rep, oracle)
    for f in files:                          # exact per-file block indices
        fid = cluster.namenodes[0].ops.stat(f).value["id"]
        idx = sorted(r["index"] for r in store.table("block").scan_all(
            lambda r, fid=fid: r["inode_id"] == fid))
        assert idx == list(range(6))


def test_network_partition_taxonomy():
    """NetworkPartition is a StoreError (every transport guard catches
    it), rehydrates from batched outcomes by name, and is retryable."""
    assert issubclass(NetworkPartition, StoreError)
    assert isinstance(error_for("NetworkPartition"), NetworkPartition)
    assert "NetworkPartition" in RETRYABLE_ERRORS


# ---------------------------------------------------------------------------
# 4. fixed-seed regression per fault site (the per-site safety net)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site,kind", [
    (FaultSite.RPC, CRASH),
    (FaultSite.RPC, PARTITION),
    (FaultSite.BATCH_EXCHANGE, CRASH),
    (FaultSite.BATCH_EXCHANGE, PARTITION),
    (FaultSite.BATCH_EXCHANGE, DELAY),
    (FaultSite.GROUP_TXN_PRE_LOCK, CRASH),
    (FaultSite.GROUP_TXN_POST_LOCK, CRASH),
    (FaultSite.GROUP_TXN_POST_LOCK, DELAY),
    (FaultSite.SUBTREE_CHUNK, CRASH),
    (FaultSite.SUBTREE_CHUNK, DELAY),
    (FaultSite.RPC, DELAY),
], ids=lambda v: getattr(v, "value", v))
def test_fixed_seed_site_regression(make_cluster, oracle_replay, site,
                                    kind):
    """One fault at each write-path site over the same seeded write-heavy
    trace: recovery must always converge to the oracle.  (HEARTBEAT has
    its own scenario test above — it fires on ticks, not on the replay
    path.)"""
    trace = _write_heavy_trace(160)
    oracle, _ = oracle_replay(trace, namespace=True)
    store, cluster, _ = make_cluster(3, namespace=True)
    for nn in cluster.namenodes:
        nn.subtree.batch_size = 4
    inj = FaultInjector(
        ChaosPlan((Fault(site, at=2, kind=kind, heal_after=2),)), cluster)
    rep = replay_with_recovery(cluster, trace, injector=inj, batch_size=8)
    _assert_converged(store, cluster, rep, oracle)


# ---------------------------------------------------------------------------
# 4b. DELAY: gray failure — slow, not dead (ISSUE 8)
# ---------------------------------------------------------------------------

def test_delay_fault_legality():
    """DELAY lives on the request path: a slow heartbeat is just a missed
    one (the election covers that), so HEARTBEAT refuses the kind; delays
    must heal and must burn at least one tick."""
    with pytest.raises(AssertionError):
        Fault(FaultSite.HEARTBEAT, kind=DELAY)
    with pytest.raises(AssertionError):
        Fault(FaultSite.RPC, kind=DELAY, heal_after=0)
    with pytest.raises(AssertionError):
        Fault(FaultSite.RPC, kind=DELAY, delay_ticks=0)
    for site in FaultSite:
        if site is not FaultSite.HEARTBEAT:
            Fault(site, kind=DELAY)


def test_delay_fault_burns_clock_but_victim_survives(make_cluster):
    """The gray-failure contract: a DELAY exchange raises nothing and the
    victim keeps heartbeating — only the SHARED logical clock ages
    (delay_ticks per slowed exchange), exactly what deadline shedding and
    breaker timers key off."""
    store, cluster = make_cluster(3, dirs=("/w",), files=("/w/f",))
    victim = cluster.namenodes[1]
    inj = FaultInjector(ChaosPlan((Fault(
        FaultSite.BATCH_EXCHANGE, at=1, victim=1, kind=DELAY,
        heal_after=2, delay_ticks=3),)), cluster)
    t0 = cluster.election.now
    with inj:
        for _ in range(5):
            outs = victim.execute_batch([WorkloadOp("read", "/w/f")] * 2)
            assert all(oc.ok for oc in outs)
    # exchange 0 clean (at=1); exchanges 1..3 burn 3 ticks each (match,
    # then heal_after=2 slowed exchanges, the last of which heals)
    assert cluster.election.now - t0 == 9
    assert all(nn.alive for nn in cluster.namenodes)
    assert cluster.election.leader() is not None
    assert [e.action for e in inj.events] == [
        "slowed", "delayed", "delay-healed"]
    assert [e.kind for e in inj.injected] == [DELAY]


def test_delay_composes_with_planned_pipeline(make_cluster, oracle_replay):
    """A gray-slow namenode under the PLANNED pipeline (ISSUE 8): the
    write-heavy trace converges to the fault-free oracle with conserved
    costs even though the shared clock aged mid-replay."""
    trace = _write_heavy_trace(160)
    oracle, _ = oracle_replay(trace, namespace=True)
    store, cluster, _ = make_cluster(3, namespace=True)
    inj = FaultInjector(ChaosPlan((
        Fault(FaultSite.BATCH_EXCHANGE, at=2, victim=1, kind=DELAY,
              heal_after=4, delay_ticks=2),
        Fault(FaultSite.RPC, at=6, kind=DELAY, heal_after=2),
    )), cluster)
    rep = replay_with_recovery(cluster, trace, injector=inj, batch_size=8,
                               planned=True)
    assert any(e.kind == DELAY for e in inj.injected)
    _assert_converged(store, cluster, rep, oracle)


# ---------------------------------------------------------------------------
# 5. recover_lease: client-initiated soft-limit takeover (HDFS recoverLease)
# ---------------------------------------------------------------------------

def test_recover_lease_two_client_takeover(make_cluster):
    store, cluster = make_cluster(2, dirs=("/w",))
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")
    dfs.add_block("/w/f", client="c1")
    limit = cluster.namenodes[0].ops.lease_limit
    # c1 keeps renewing: recovery is refused, the lease is untouched
    for _ in range(limit + 2):
        cluster.tick()
        dfs.renew_lease(client="c1")
    with pytest.raises(LeaseConflict):
        dfs.call("recover_lease", "/w/f", client="c2")
    assert store.table("lease").get(("c1",)) is not None
    # c1 dies (stops renewing); past the soft limit c2 takes over
    for _ in range(limit + 2):
        cluster.tick()
    assert dfs.call("recover_lease", "/w/f", client="c2").value is True
    row = store.table("inode").scan_index(
        "id", dfs.stat("/w/f").inode_id)[0]
    assert row["under_construction"] is False and row["client"] is None
    assert store.table("lease").get(("c1",)) is None     # last path: gone
    # the file is writable by c2 — and fenced against the old holder
    assert dfs.append("/w/f", client="c2") > 0
    with pytest.raises(LeaseConflict):
        dfs.add_block("/w/f", client="c1")


def test_recover_lease_noop_and_error_cases(make_cluster):
    store, cluster = make_cluster(1, dirs=("/w",))
    nn = cluster.namenodes[0]
    assert "recover_lease" in REGISTRY
    with pytest.raises(FileNotFound):
        nn.ops.recover_lease("/w/missing", client="c2")
    with pytest.raises(FileNotFound):
        nn.ops.recover_lease("/w", client="c2")          # directories: no
    nn.ops.create("/w/f", client="c1")
    # recovering your own lease is a no-op, not a takeover
    assert nn.ops.recover_lease("/w/f", client="c1").value is False
    # a completed (not-under-construction) file has nothing to recover
    fid = nn.ops.create("/w/done", client="c1").value
    row = dict(store.table("inode").scan_index("id", fid)[0])
    row["under_construction"] = False
    row["client"] = None
    store.table("inode").put(row)          # model completion closing UC
    assert nn.ops.recover_lease("/w/done", client="c2").value is False


def test_recover_lease_keeps_holder_with_other_files(make_cluster):
    """Takeover of ONE of the holder's files must not drop the holder's
    lease row while other lease_path rows still reference it."""
    store, cluster = make_cluster(1, dirs=("/w",))
    nn = cluster.namenodes[0]
    nn.ops.create("/w/a", client="c1")
    nn.ops.create("/w/b", client="c1")
    for _ in range(nn.ops.lease_limit + 2):
        cluster.tick()
    assert nn.ops.recover_lease("/w/a", client="c2").value is True
    assert store.table("lease").get(("c1",)) is not None   # /w/b remains
    assert store.table("lease_path").get(
        (nn.ops.stat("/w/b").value["id"],)) is not None


# ---------------------------------------------------------------------------
# 6. retry taxonomy: what each middleware re-runs, skips, or leaks
# ---------------------------------------------------------------------------

def _counting_terminal(errors, result="done"):
    """Terminal that raises the queued errors first, then succeeds."""
    calls = []

    def terminal(ctx):
        calls.append(ctx.op)
        if len(calls) <= len(errors):
            raise errors[len(calls) - 1]
        return result
    return terminal, calls


def test_txn_retry_reruns_timeouts_and_aborts():
    for err in (LockTimeout("row lock wait"), TransactionAborted("abort")):
        terminal, calls = _counting_terminal([err, err])
        ctx = CallContext(op="add_block")
        assert compose([txn_retry(backoff=0)], terminal)(ctx) == "done"
        assert len(calls) == 3 and ctx.retries == 2


def test_txn_retry_never_reruns_subtree_ops():
    """delete_subtree spans many chunk transactions — earlier chunks may
    have committed, so a blind re-run is unsafe; the timeout surfaces."""
    terminal, calls = _counting_terminal([LockTimeout("chunk timed out")])
    handler = compose([txn_retry(backoff=0)], terminal)
    with pytest.raises(LockTimeout):
        handler(CallContext(op="delete_subtree"))
    assert len(calls) == 1                   # exactly one attempt


def test_failover_propagates_errors_from_live_namenodes():
    """StoreError from a live, reachable namenode is a genuine outcome."""
    class NN:
        alive = True
    calls = []

    def terminal(ctx):
        ctx.namenode = NN()
        calls.append(1)
        raise StoreError("node group down")
    with pytest.raises(StoreError):
        compose([failover()], terminal)(CallContext(op="stat"))
    assert len(calls) == 1


def test_failover_masks_death_before_commit_exactly_once(make_cluster):
    """The safe half of §7.6.1: the namenode dies BEFORE its transaction
    commits — nothing was applied, the retry on a survivor applies the
    mutation exactly once."""
    store, cluster = make_cluster(2, dirs=("/w",), files=("/w/f",))
    attempts = []

    def terminal(ctx):
        nn = cluster.alive_namenodes()[0]
        ctx.namenode = nn
        attempts.append(nn.nn_id)
        if len(attempts) == 1:
            cluster.kill(nn.nn_id)           # in-flight death, no commit
            raise StoreError("namenode died mid-transaction")
        return nn.ops.add_block("/w/f")
    res = compose([failover()], terminal)(CallContext(op="add_block"))
    assert res.value > 0 and len(attempts) == 2
    fid = cluster.alive_namenodes()[0].ops.stat("/w/f").value["id"]
    rows = store.table("block").scan_all(lambda r: r["inode_id"] == fid)
    assert sorted(r["index"] for r in rows) == [0]       # exactly once


def test_failover_at_most_once_gap_commit_then_die(make_cluster):
    """KNOWN GAP, pinned on purpose: when a namenode commits and THEN
    dies before replying, the client cannot distinguish it from an
    in-flight death and retries — the non-idempotent mutation applies
    twice (no client-supplied op id exists to dedupe on, in the paper or
    here).  HDFS closes this per-op (e.g. addBlock's previous-block
    argument); this model documents the gap instead of hiding it."""
    store, cluster = make_cluster(2, dirs=("/w",), files=("/w/f",))
    attempts = []

    def terminal(ctx):
        nn = cluster.alive_namenodes()[0]
        ctx.namenode = nn
        attempts.append(nn.nn_id)
        res = nn.ops.add_block("/w/f")       # commits...
        if len(attempts) == 1:
            cluster.kill(nn.nn_id)           # ...then dies pre-reply
            raise StoreError("namenode died after commit")
        return res
    res = compose([failover()], terminal)(CallContext(op="add_block"))
    assert res.value > 0 and len(attempts) == 2
    fid = cluster.alive_namenodes()[0].ops.stat("/w/f").value["id"]
    rows = store.table("block").scan_all(lambda r: r["inode_id"] == fid)
    assert sorted(r["index"] for r in rows) == [0, 1]    # applied TWICE


def test_retryable_error_taxonomy_is_exact():
    """The recovery protocol re-drives transport/abort failures only —
    genuine FS outcomes must never be retried (a second delete of an
    already-deleted file would diverge from the oracle)."""
    assert RETRYABLE_ERRORS == {"StoreError", "NetworkPartition",
                                "LockTimeout", "TransactionAborted",
                                "SubtreeLockedError",
                                # shed ops are valid work whose timing or
                                # admission budget ran out — re-drivable
                                "DeadlineExpired", "OverloadShed"}
    for genuine in ("FileNotFound", "FileAlreadyExists", "LeaseConflict",
                    "FSError"):
        assert genuine not in RETRYABLE_ERRORS


# ---------------------------------------------------------------------------
# 7. the invariant checker checks itself
# ---------------------------------------------------------------------------

def test_recovery_invariants_detect_planted_violations(make_cluster):
    store, cluster = make_cluster(1, dirs=("/w",), files=("/w/f",))
    inv = RecoveryInvariants(store, cluster)
    # clean baseline (the UC file's holder has a live lease row)
    assert inv.orphan_violations() == []
    assert inv.lock_violations() == []
    # plant an orphan lease_path row for a nonexistent inode
    store.table("lease_path").put({"inode_id": 99_999, "holder": "ghost"})
    got = inv.orphan_violations()
    assert any("99999" in v for v in got)
    assert any("ghost" in v for v in got)    # holder has no lease either
    # plant a stale subtree lock
    row = dict(store.table("inode").scan_all(
        lambda r: r["name"] == "w")[0])
    row["subtree_lock"] = 7
    store.table("inode").put(row)
    assert any("subtree lock" in v for v in inv.orphan_violations())
    # plant an unreleased lock
    store.locks._held.setdefault("txn-ghost", set()).add(("inode", (1,)))
    assert inv.lock_violations() != []
    # namespace divergence reports the exact path
    snap = namespace_snapshot(store)
    snap["/w/phantom"] = ("file",)
    assert any("/w/phantom" in v for v in inv.namespace_violations(snap))
    with pytest.raises(AssertionError, match="phantom"):
        inv.assert_all(snap)


# ---------------------------------------------------------------------------
# 8. DES mirror: crash/recovery in the cluster simulator (§7.6, Fig 11)
# ---------------------------------------------------------------------------

def test_sim_mirrors_crash_and_recovery():
    """schedule_kill/schedule_restart on the batched DES: throughput dips
    while the victim is down, recovers after restart, never collapses to
    zero (the paper's no-downtime failover shape), and the fault events
    are recorded for the bench's `failover` section."""
    from repro.core.cluster_sim import BatchedHopsFSSim, profile_ops

    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=8, files_per_dir=4)
    sim = BatchedHopsFSSim(n_namenodes=4, n_ndb=4, profiles=profile_ops(),
                           timeline_bin=0.05)
    sim.start_clients(200, SpotifyWorkload(ns))
    sim.schedule_kill(0.4, 1)
    sim.schedule_restart(0.8, 1)
    res = sim.run(1.2)
    assert sim.fault_events == [(0.4, "killed", 1), (0.8, "restarted", 1)]
    assert sim.nn_alive[1]                   # restarted by end of run
    counts = dict(res.timeline)
    bins = [counts.get(b * 0.05, 0) for b in range(24)]
    assert all(c > 0 for c in bins)          # no zero-throughput bins
    steady = sum(bins[2:8]) / 6              # pre-kill steady state
    down = bins[9:16]                        # victim dead: 3/4 capacity
    assert min(down) < steady                # visible dip...
    assert min(down) > 0.4 * steady          # ...but never a collapse
    assert max(bins[17:]) > 0.9 * steady     # recovers after restart
    assert res.completed > 0


def test_sim_timeline_bin_default_is_one_second():
    """Default-bin timelines keep integer-valued keys so legacy
    ``dict(res.timeline)[second]`` consumers are unaffected."""
    from repro.core.cluster_sim import HopsFSSim, profile_ops

    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=8, files_per_dir=4)
    sim = HopsFSSim(n_namenodes=2, n_ndb=2, profiles=profile_ops())
    sim.start_clients(50, SpotifyWorkload(ns))
    res = sim.run(1.5)
    by_sec = dict(res.timeline)
    assert by_sec.get(0, 0) > 0 and by_sec.get(1, 0) > 0
    assert all(t == int(t) for t, _ in res.timeline)


# ---------------------------------------------------------------------------
# 9. property suite (engages only where hypothesis is installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given

    from repro.core import fault_schedules
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core import (MetadataStore, NamenodeCluster, format_fs,
                            materialize_namespace)

    def _fresh(n_namenodes):
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, n_namenodes)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=8, files_per_dir=3)
        materialize_namespace(cluster.namenodes[0], ns)
        return store, cluster

    # order-insensitive trace (distinct fresh paths, no deletes): the
    # oracle namespace is reachable from ANY recovery interleaving, so
    # every generated schedule must converge exactly
    _PROP_TRACE = (
        [WorkloadOp("create", f"/w/px{i:03d}") for i in range(24)]
        + [WorkloadOp("add_block", f"/w/px{i:03d}") for i in range(24)]
        + [WorkloadOp("read", f"/w/px{i:03d}") for i in range(24)])
    _PROP_ORACLE = {}

    def _prop_oracle():
        if not _PROP_ORACLE:
            store, cluster = _fresh(1)
            rep = replay_with_recovery(cluster, _PROP_TRACE, batch_size=1)
            assert rep.failed == 0
            _PROP_ORACLE["snap"] = namespace_snapshot(store)
        return _PROP_ORACLE["snap"]

    @given(plan=fault_schedules(n_namenodes=3, max_at=12, max_faults=2))
    def test_random_fault_schedules_converge(plan):
        """site × trace-index × victim × kind: any generated schedule,
        after recovery, yields the oracle namespace with conserved costs,
        no orphans and no held locks."""
        oracle = _prop_oracle()
        store, cluster = _fresh(3)
        for nn in cluster.namenodes:
            nn.subtree.batch_size = 4
        inj = FaultInjector(plan, cluster)
        rep = replay_with_recovery(cluster, _PROP_TRACE, injector=inj,
                                   batch_size=6)
        assert rep.failed == 0
        _assert_converged(store, cluster, rep, oracle)

    @given(plan=fault_schedules(n_namenodes=3, max_at=12, max_faults=2,
                                kinds=(CRASH, PARTITION, DELAY)))
    def test_random_schedules_with_delay_converge_planned(plan):
        """ISSUE 8: the full kind alphabet — crash, partition AND
        gray-failure delay — composed with the PLANNED pipeline. The
        clock may age arbitrarily mid-replay; recovery must still land
        on the oracle namespace with conserved costs and no orphans."""
        oracle = _prop_oracle()
        store, cluster = _fresh(3)
        for nn in cluster.namenodes:
            nn.subtree.batch_size = 4
        inj = FaultInjector(plan, cluster)
        rep = replay_with_recovery(cluster, _PROP_TRACE, injector=inj,
                                   batch_size=6, planned=True)
        assert rep.failed == 0
        _assert_converged(store, cluster, rep, oracle)
