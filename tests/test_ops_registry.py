"""The typed operation protocol (op registry) — PR 2's tentpole contract.

  * one declaration per op: every spec resolves to a real handler on a
    live namenode, and the old parallel string tables are gone (derived
    views only);
  * workload records carry REAL arguments end-to-end (perm/owner/repl are
    no longer hardcoded by the executor; spec defaults fill the gaps);
  * extensibility: new ops (`truncate`, `concat`, and a test-registered
    one) execute through every layer with zero dispatch edits;
  * the deprecated `execute`/`execute_wop` shims still work, warning.
"""
import pytest

from repro.core import (BATCHABLE_READ_OPS, MetadataStore, NamenodeCluster,
                        OpResult, REGISTRY, RequestPipeline, WorkloadOp,
                        format_fs, materialize_namespace, register_op)
from repro.core.fs import HopsFSOps
from repro.core.namenode import Namenode
from repro.core.ops_registry import REQUIRED, ArgSpec, OpSpec
from repro.core.workload import (READ_ONLY_OPS, NamespaceSpec,
                                 SpotifyWorkload, SyntheticNamespace,
                                 make_spotify_trace)


def _cluster(n_nn=1):
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    return store, NamenodeCluster(store, n_nn)


# ---------------------------------------------------------------------------
# single source of truth
# ---------------------------------------------------------------------------

def test_every_spec_resolves_to_real_handler():
    _, cluster = _cluster()
    nn = cluster.namenodes[0]
    for spec in REGISTRY:
        fn = spec.resolve(nn)
        assert callable(fn), spec.name
        assert spec.holder in ("ops", "subtree")


def test_old_string_tables_are_gone_or_derived():
    assert not hasattr(Namenode, "_DISPATCH")
    # the surviving names are registry-derived views
    assert tuple(BATCHABLE_READ_OPS) == REGISTRY.batchable_ops()
    assert READ_ONLY_OPS == REGISTRY.read_only_ops()
    # semantics: batchable ops must be read-only; subtree flags line up
    assert set(REGISTRY.batchable_ops()) <= REGISTRY.read_only_ops()
    assert REGISTRY.subtree_ops() == {"delete_subtree", "rename_subtree",
                                      "chmod_subtree", "chown_subtree"}
    with pytest.raises(AssertionError):
        OpSpec(name="bad", holder="ops", method="x", batchable=True)
    with pytest.raises(AssertionError):   # batchable needs a payload phase
        OpSpec(name="bad2", holder="ops", method="x", read_only=True,
               batchable=True)
    # every batchable spec declares its grouped payload phase
    for name in REGISTRY.batchable_ops():
        assert REGISTRY[name].batch_payload is not None


def test_mix_synthesis_produces_registered_ops_with_args():
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=20)
    wl = SpotifyWorkload(ns, seed=3)
    trace = wl.make_trace(3000)
    assert all(w.op in REGISTRY for w in trace)
    perms = [w.args["perm"] for w in trace
             if w.op in ("chmod_file", "chmod_subtree")]
    owners = [w.args["owner"] for w in trace
              if w.op in ("chown_file", "chown_subtree")]
    repls = [w.args["repl"] for w in trace if w.op == "set_replication"]
    assert perms and owners and repls        # records carry real arguments
    assert len(set(owners)) > 1              # ... actually sampled
    assert all(r in (1, 2, 3) for r in repls)


def test_spec_defaults_and_required_args():
    spec = REGISTRY["chmod_file"]
    paths, kw = spec.call_args(WorkloadOp("chmod_file", "/f"))
    assert paths == ["/f"] and kw == {"perm": 0o640}
    paths, kw = spec.call_args(WorkloadOp("chmod_file", "/f",
                                          args={"perm": 0o700}))
    assert kw == {"perm": 0o700}
    # rename's destination defaults off the source path
    paths, _ = REGISTRY["rename_file"].call_args(WorkloadOp("rename_file",
                                                            "/a"))
    assert paths == ["/a", "/a.mv"]
    with pytest.raises(TypeError):
        REGISTRY["concat"].call_args(WorkloadOp("concat", "/t"))
    assert ArgSpec("x", 7).value_for(WorkloadOp("op", "/p")) == 7
    assert ArgSpec("x", REQUIRED).value_for(
        WorkloadOp("op", "/p", args={"x": 1})) == 1


# ---------------------------------------------------------------------------
# workload arguments flow end-to-end
# ---------------------------------------------------------------------------

def test_workload_args_applied_not_hardcoded():
    _, cluster = _cluster()
    nn = cluster.namenodes[0]
    nn.perform("mkdirs", "/w")
    nn.perform("create", "/w/f")
    nn.invoke(WorkloadOp("chmod_file", "/w/f", args={"perm": 0o711}))
    nn.invoke(WorkloadOp("chown_file", "/w/f", args={"owner": "eve"}))
    nn.invoke(WorkloadOp("set_replication", "/w/f", args={"repl": 1}))
    st = nn.perform("stat", "/w/f").value
    assert (st["perm"], st["owner"], st["repl"]) == (0o711, "eve", 1)
    # no args => the OpSpec defaults (the old executor-hardcoded values)
    nn.invoke(WorkloadOp("chmod_file", "/w/f"))
    assert nn.perform("stat", "/w/f").value["perm"] == 0o640


def test_generated_trace_args_survive_the_pipeline():
    store, cluster = _cluster(2)
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=12, files_per_dir=3)
    materialize_namespace(cluster.namenodes[0], ns)
    trace = make_spotify_trace(ns, 400, seed=23)
    chmods = [w for w in trace if w.op == "chmod_file"]
    assert chmods, "trace should contain chmod_file ops"
    RequestPipeline(cluster, batch_size=8).run(trace)
    # the LAST chmod touching each path must have stamped its sampled perm
    last_perm = {w.path: w.args["perm"] for w in chmods}
    later_mutated = {w.path for w in trace
                     if w.op in ("delete_file", "rename_file",
                                 "delete_subtree", "concat")}
    checked = 0
    for path, perm in last_perm.items():
        if path in later_mutated:
            continue
        try:
            st = cluster.namenodes[0].perform("stat", path).value
        except Exception:
            continue                       # killed by an unrelated subtree op
        assert st["perm"] == perm, path
        checked += 1
    assert checked > 0


# ---------------------------------------------------------------------------
# extensibility: new ops with zero dispatch edits
# ---------------------------------------------------------------------------

def test_truncate_and_concat_registered_without_dispatch_edits():
    _, cluster = _cluster()
    nn = cluster.namenodes[0]
    nn.perform("mkdirs", "/d")
    for name in ("a", "b"):
        nn.perform("create", f"/d/{name}")
        bid = nn.perform("add_block", f"/d/{name}").value
        nn.perform("complete_block", f"/d/{name}", bid, size=100)
    r = nn.invoke(WorkloadOp("concat", "/d/a", args={"srcs": ["/d/b"]}))
    assert r.value == {"blocks_moved": 1, "size": 200}
    assert nn.perform("ls", "/d").value == ["a"]
    blocks = nn.perform("read", "/d/a").value
    assert [b["size"] for b in blocks] == [100, 100]
    r = nn.invoke(WorkloadOp("truncate", "/d/a", args={"new_size": 150}))
    assert r.value == {"size": 150, "removed_blocks": 0}
    r = nn.invoke(WorkloadOp("truncate", "/d/a"))       # default: to zero
    assert r.value["size"] == 0
    assert nn.perform("read", "/d/a").value == []


def test_concat_moves_rows_across_partitions_consistently():
    """concat is the first op that updates a partition key (block/replica
    inode_id) without changing the PK — the store must relocate the row,
    not duplicate it."""
    store, cluster = _cluster()
    nn = cluster.namenodes[0]
    nn.perform("mkdirs", "/d")
    for name in ("t", "s1", "s2"):
        nn.perform("create", f"/d/{name}")
        for _ in range(2):
            bid = nn.perform("add_block", f"/d/{name}").value
            nn.perform("complete_block", f"/d/{name}", bid, size=10)
    n_before = store.table("block").n_rows
    nn.invoke(WorkloadOp("concat", "/d/t", args={"srcs": ["/d/s1",
                                                          "/d/s2"]}))
    assert store.table("block").n_rows == n_before      # moved, not copied
    blocks = nn.perform("read", "/d/t").value
    assert len(blocks) == 6
    assert nn.perform("stat", "/d/t").value["size"] == 60
    # every block row findable (and unique) by PK across all partitions
    t = store.table("block")
    for b in blocks:
        copies = sum(1 for part in t.parts if (b["block"],) in part)
        assert copies == 1, b


def test_runtime_registered_op_reaches_every_layer():
    def touch(self, path: str) -> OpResult:
        return self.chmod_file(path, 0o777)

    HopsFSOps.touch_exec = touch
    register_op("touch_exec", "ops", "touch_exec")
    try:
        store, cluster = _cluster(2)
        nn = cluster.namenodes[0]
        nn.perform("mkdirs", "/x")
        nn.perform("create", "/x/f")
        # positional layer
        nn.perform("touch_exec", "/x/f")
        # workload-record layer + batched pipeline layer
        stats = RequestPipeline(cluster, batch_size=4).run(
            [WorkloadOp("touch_exec", "/x/f")])
        assert stats.ok == 1
        assert nn.perform("stat", "/x/f").value["perm"] == 0o777
    finally:
        REGISTRY.unregister("touch_exec")
        del HopsFSOps.touch_exec


def test_runtime_registered_batchable_op_actually_batches():
    """The batching layers consult the registry LIVE: a batchable op
    registered after import groups through execute_batch like `stat`."""
    from repro.core.ops_registry import _payload_stat

    def stat_alias(self, path):
        return self.stat(path)

    HopsFSOps.stat_alias = stat_alias
    register_op("stat_alias", "ops", "stat_alias", read_only=True,
                batchable=True, batch_payload=_payload_stat,
                lease_read=True)
    try:
        _, cluster = _cluster()
        nn = cluster.namenodes[0]
        nn.perform("mkdirs", "/ba")
        for i in range(4):
            nn.perform("create", f"/ba/f{i}")
            nn.perform("stat", f"/ba/f{i}")      # warm the hint cache
        wops = [WorkloadOp("stat_alias", f"/ba/f{i}") for i in range(4)]
        outcomes = nn.execute_batch(wops)
        assert all(o.ok for o in outcomes)
        assert any(o.batched for o in outcomes)
        # grouped payload == sequential payload
        for i, o in enumerate(outcomes):
            assert o.result.value == nn.perform("stat", f"/ba/f{i}").value
    finally:
        REGISTRY.unregister("stat_alias")
        del HopsFSOps.stat_alias


def test_concat_leaves_no_orphaned_file_related_rows():
    """concat must re-own EVERY file-related row (inv/ruc/... included),
    not just block+replica — a truncated source carries inv rows."""
    store, cluster = _cluster()
    nn = cluster.namenodes[0]
    nn.perform("mkdirs", "/o")
    for name in ("t", "s"):
        nn.perform("create", f"/o/{name}")
        for _ in range(2):
            bid = nn.perform("add_block", f"/o/{name}").value
            nn.perform("complete_block", f"/o/{name}", bid, size=10)
    nn.perform("truncate", "/o/s", 10)       # drops a block -> inv rows
    assert store.table("inv").n_rows > 0
    sid = nn.perform("stat", "/o/s").value["id"]
    nn.invoke(WorkloadOp("concat", "/o/t", args={"srcs": ["/o/s"]}))
    for tname in ("block", "replica", "urb", "prb", "ruc", "cr", "er",
                  "inv"):
        for part in store.table(tname).parts:
            for row in part.values():
                assert row["inode_id"] != sid, (tname, row)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        register_op("read", "ops", "get_block_locations")


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_execute_shims_warn_and_work():
    _, cluster = _cluster()
    nn = cluster.namenodes[0]
    with pytest.deprecated_call():
        nn.execute("mkdirs", "/s/t")
    with pytest.deprecated_call():
        res = nn.execute("ls", "/s")
    assert res.value == ["t"]
    with pytest.deprecated_call():
        nn.execute_wop(WorkloadOp("create", "/s/t/f"))
    # the shim applies registry defaults exactly like the old executor did
    with pytest.deprecated_call():
        nn.execute_wop(WorkloadOp("chmod_file", "/s/t/f"))
    assert nn.perform("stat", "/s/t/f").value["perm"] == 0o640
    with pytest.deprecated_call():
        nn.execute_wop(WorkloadOp("rename_file", "/s/t/f"))
    assert nn.perform("ls", "/s/t").value == ["f.mv"]
