"""The HDFS-style `DFSClient` facade + composable middleware.

Covers the error taxonomy end to end (FileNotFound, FileAlreadyExists,
SubtreeLockedError retried-then-surfaced, NodeGroupDown, dead-namenode
failover), typed results, deferred batching through `execute_batch`, and
`run_trace` state equivalence with sequential execution.
"""
import pytest

from repro.core import (DFSClient, FileAlreadyExists, FileNotFound,
                        FileStatus, MetadataStore, NamenodeCluster,
                        NodeGroupDown, StoreError, SubtreeLockedError,
                        WorkloadOp, format_fs, materialize_namespace,
                        namespace_snapshot, subtree_retry)
from repro.core.workload import (NamespaceSpec, SyntheticNamespace,
                                 make_spotify_trace)


def _cluster(n_nn=2, n_datanodes=4):
    store = MetadataStore(n_datanodes=n_datanodes)
    format_fs(store)
    return store, NamenodeCluster(store, n_nn)


def _seed_file(dfs, path="/data/f", n_blocks=2, block_size=100):
    dfs.mkdirs(path.rsplit("/", 1)[0])
    dfs.create(path)
    for _ in range(n_blocks):
        bid = dfs.add_block(path)
        dfs.complete_block(path, bid, size=block_size)


# ---------------------------------------------------------------------------
# typed results
# ---------------------------------------------------------------------------

def test_typed_results_roundtrip():
    _, cluster = _cluster()
    dfs = DFSClient(cluster)
    _seed_file(dfs)
    st = dfs.stat("/data/f")
    assert isinstance(st, FileStatus)
    assert (st.is_dir, st.size, st.path) == (False, 200, "/data/f")
    blocks = dfs.open("/data/f")
    assert [b.size for b in blocks] == [100, 100]
    assert all(len(b.datanodes) >= 1 for b in blocks)
    assert dfs.ls("/data") == ("f",)
    cs = dfs.content_summary("/data")
    assert cs.children == 1
    assert dfs.exists("/data/f") and not dfs.exists("/data/nope")


def test_facade_rename_delete_route_by_inode_type():
    _, cluster = _cluster()
    dfs = DFSClient(cluster)
    _seed_file(dfs, "/a/b/f")
    dfs.rename("/a/b/f", "/a/b/g")            # file -> rename_file
    assert dfs.ls("/a/b") == ("g",)
    dfs.rename("/a/b", "/a/c")                # dir -> subtree protocol
    assert dfs.ls("/a/c") == ("g",)
    with pytest.raises(Exception):
        dfs.delete("/a/c")                    # dir without recursive
    d = dfs.delete("/a/c", recursive=True)
    assert d.deleted == 2 and d.recursive
    assert dfs.ls("/a") == ()                 # /a survives, now empty
    _seed_file(dfs, "/a/f2")
    d = dfs.delete("/a/f2")                   # file -> delete_file
    assert d.deleted == 1 and not d.recursive


def test_facade_new_ops_truncate_concat():
    _, cluster = _cluster()
    dfs = DFSClient(cluster)
    _seed_file(dfs, "/w/a")
    _seed_file(dfs, "/w/b")
    c = dfs.concat("/w/a", ["/w/b"])
    assert (c.blocks_moved, c.size) == (2, 400)
    assert not dfs.exists("/w/b")
    t = dfs.truncate("/w/a", 250)
    assert (t.size, t.removed_blocks) == (250, 1)
    assert dfs.stat("/w/a").size == 250


# ---------------------------------------------------------------------------
# error taxonomy through the facade
# ---------------------------------------------------------------------------

def test_file_not_found_and_already_exists():
    _, cluster = _cluster()
    dfs = DFSClient(cluster)
    dfs.mkdirs("/e")
    with pytest.raises(FileNotFound):
        dfs.stat("/e/missing")
    with pytest.raises(FileNotFound):
        dfs.open("/e/missing")
    dfs.create("/e/f")
    with pytest.raises(FileAlreadyExists):
        dfs.create("/e/f")
    with pytest.raises(FileAlreadyExists):
        dfs.mkdir("/e")


def test_subtree_locked_retried_then_surfaced():
    store, cluster = _cluster(2)
    dfs = DFSClient(cluster, subtree_retries=3, subtree_backoff=0.0)
    dfs._selector._sticky = 0                 # pin to NN0
    _seed_file(dfs, "/locked/f")
    # NN1 (alive) holds the application-level subtree lock on /locked
    t = store.table("inode")
    row = dict(t.get((1, "locked")))
    row["subtree_lock"] = 1
    t.put(row)
    with pytest.raises(SubtreeLockedError):
        dfs.stat("/locked/f")
    assert dfs.retries >= 3                   # retried, then surfaced
    # lock released -> op succeeds again
    row = dict(t.get((1, "locked")))
    row["subtree_lock"] = None
    t.put(row)
    assert dfs.stat("/locked/f").size == 200


def test_subtree_lock_of_dead_namenode_is_reclaimed():
    store, cluster = _cluster(2)
    dfs = DFSClient(cluster)
    dfs._selector._sticky = 0
    _seed_file(dfs, "/locked/f")
    t = store.table("inode")
    row = dict(t.get((1, "locked")))
    row["subtree_lock"] = 1
    t.put(row)
    cluster.kill(1)
    for _ in range(6):                        # liveness decays via ticks
        cluster.tick()
    assert dfs.stat("/locked/f").size == 200  # reclaim §6.2, no error


def test_node_group_down_surfaces():
    store, cluster = _cluster(2)
    dfs = DFSClient(cluster)
    _seed_file(dfs)
    for dn in range(store.n_datanodes):
        store.fail_datanode(dn)
    with pytest.raises(NodeGroupDown):
        dfs.stat("/data/f")
    store.recover_datanode(0)
    store.recover_datanode(2)


def test_dead_namenode_failover_mid_op():
    _, cluster = _cluster(3)
    dfs = DFSClient(cluster)
    _seed_file(dfs)
    nn0 = cluster.namenodes[0]
    dfs._selector._sticky = 0

    real_stat = nn0.ops.stat

    def dying_stat(path):
        nn0.ops.stat = real_stat              # die once
        nn0.alive = False
        raise StoreError("namenode 0 lost mid-op")

    nn0.ops.stat = dying_stat
    st = dfs.stat("/data/f")                  # transparently fails over
    assert st.size == 200
    assert dfs.retries >= 1
    assert dfs._selector._sticky != 0         # sticky re-selected


def test_no_alive_namenodes_raises():
    _, cluster = _cluster(2)
    dfs = DFSClient(cluster)
    dfs.mkdirs("/z")
    cluster.kill(0)
    cluster.kill(1)
    with pytest.raises(StoreError):
        dfs.stat("/z")


def test_custom_middleware_stack():
    calls = []

    def tracing(nxt):
        def handler(ctx):
            calls.append(ctx.op)
            return nxt(ctx)
        return handler

    _, cluster = _cluster()
    dfs = DFSClient(cluster,
                    middleware=[tracing, subtree_retry(retries=2,
                                                       backoff=0.0)])
    dfs.mkdirs("/m")
    assert calls == ["mkdirs"]


# ---------------------------------------------------------------------------
# batching
# ---------------------------------------------------------------------------

def test_batch_context_returns_typed_results_and_errors():
    _, cluster = _cluster()
    dfs = DFSClient(cluster)
    _seed_file(dfs)
    with dfs.batch() as b:
        h_stat = b.stat("/data/f")
        h_ls = b.ls("/data")
        h_open = b.open("/data/f")
        h_missing = b.stat("/data/nope")
        h_mut = b.submit("chmod_file", "/data/f", perm=0o600)
    assert isinstance(h_stat.result(), FileStatus)
    assert h_ls.result() == ("f",)
    assert [bl.size for bl in h_open.result()] == [100, 100]
    with pytest.raises(FileNotFound):
        h_missing.result()
    h_mut.result()                            # mutation applied in order
    assert dfs.stat("/data/f").perm == 0o600


def test_batch_unflushed_handle_raises():
    _, cluster = _cluster()
    dfs = DFSClient(cluster)
    dfs.mkdirs("/b")
    b = dfs.batch()
    h = b.ls("/b")
    with pytest.raises(RuntimeError):
        h.result()
    b.flush()
    assert h.result() == ()


def test_batch_reusable_after_explicit_flush():
    _, cluster = _cluster()
    dfs = DFSClient(cluster)
    _seed_file(dfs, "/r/a")
    _seed_file(dfs, "/r/b")
    b = dfs.batch()
    h1 = b.stat("/r/a")
    b.flush()
    h2 = b.stat("/r/b")
    b.flush()
    assert h1.result().path == "/r/a" and h1.result().size == 200
    assert h2.result().path == "/r/b" and h2.result().size == 200


def test_batch_fails_over_on_mid_batch_death():
    """A namenode dying WHILE executing the batch records per-op
    StoreError outcomes; flush must retry those on a survivor."""
    _, cluster = _cluster(2)
    dfs = DFSClient(cluster)
    _seed_file(dfs)
    dfs._selector._sticky = 0
    nn0 = cluster.namenodes[0]

    real_stat = nn0.ops.stat

    def dying_stat(path):
        nn0.ops.stat = real_stat
        nn0.alive = False
        raise StoreError("lost mid-batch")

    nn0.ops.stat = dying_stat
    with dfs.batch() as b:
        h = b.stat("/data/f")
    assert h.result().size == 200
    assert dfs.retries >= 1


def test_batch_fails_over_when_namenode_dies():
    _, cluster = _cluster(2)
    dfs = DFSClient(cluster)
    _seed_file(dfs)
    dfs._selector._sticky = 0
    nn0 = cluster.namenodes[0]

    real = nn0.execute_batch

    def dying_batch(wops):
        nn0.execute_batch = real
        nn0.alive = False
        raise StoreError("died holding the batch")

    nn0.execute_batch = dying_batch
    with dfs.batch() as b:
        h = b.stat("/data/f")
    assert h.result().size == 200


# ---------------------------------------------------------------------------
# run_trace: the Fig 7 methodology through the facade
# ---------------------------------------------------------------------------

def test_run_trace_matches_sequential_namespace():
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=12, files_per_dir=3)
    trace = make_spotify_trace(ns_ref, 250, seed=7)

    def run(batch_size, n_nn):
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, n_nn)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=12, files_per_dir=3)
        materialize_namespace(cluster.namenodes[0], ns)
        stats = DFSClient(cluster).run_trace(trace, batch_size=batch_size)
        return store, stats

    store_seq, seq = run(1, 1)
    store_bat, bat = run(8, 2)
    assert namespace_snapshot(store_seq) == namespace_snapshot(store_bat)
    assert bat.ok + bat.failed == len(trace)
    assert bat.total_cost.round_trips <= seq.total_cost.round_trips
