"""Batched multi-namenode request pipeline (paper §2.2, §7.2).

The two contract properties from the issue:
  1. batched execution leaves the store in EXACTLY the state sequential
     execution does (strict full-table equality on a single namenode;
     logical-namespace equality across namenode counts, where physical
     ids legitimately differ);
  2. OpCost accounting is conserved across batching: the merge of per-
     namenode aggregates == the pipeline's total == the merge of every
     successful op's cost.
Plus: the vectorized phash partition grouping agrees with the store's
partitioner, batching actually saves round trips, the batched DES scales
with namenode count, and the trace generator matches the §7.2 mix.
"""

from repro.core import (MetadataStore, NamenodeCluster, OpCost,
                        RequestPipeline, format_fs, materialize_namespace,
                        namespace_snapshot)
from repro.core.cluster_sim import BatchedHopsFSSim, profile_ops
from repro.core.store import _hash_key
from repro.core.workload import (NamespaceSpec, SPOTIFY_TRACE_MIX,
                                 SpotifyWorkload, SyntheticNamespace,
                                 TraceReplay, make_spotify_trace)


def _build(n_namenodes: int, *, n_dirs: int = 16, files_per_dir: int = 4):
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, n_namenodes)
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                            files_per_dir=files_per_dir)
    materialize_namespace(cluster.namenodes[0], ns)
    return store, cluster, ns


def _trace(ns, n_ops=300, seed=5):
    return make_spotify_trace(ns, n_ops, seed=seed)


# ---------------------------------------------------------------------------
# 1. state equivalence
# ---------------------------------------------------------------------------

def test_batched_equals_sequential_state_single_nn():
    """Strict equality: with one namenode, batched execution must leave
    every table byte-identical to sequential execution (same mtimes, same
    ids — nothing may be reordered observably)."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref)
    store_seq, cluster_seq, _ = _build(1)
    seq = RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_bat, cluster_bat, _ = _build(1)
    bat = RequestPipeline(cluster_bat, batch_size=8).run(trace)
    assert store_seq.dump_state() == store_bat.dump_state()
    # same per-op outcome stream too
    assert [(o.ok, o.error) for o in seq.outcomes] == \
           [(o.ok, o.error) for o in bat.outcomes]
    assert bat.batched_fraction > 0.2     # batching actually engaged


def test_batched_equals_sequential_namespace_multi_nn():
    """Across namenode counts the physical ids differ (per-NN id-allocator
    blocks) but the logical namespace must be identical."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref)
    store_seq, cluster_seq, _ = _build(1)
    RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_bat, cluster_bat, _ = _build(4)
    RequestPipeline(cluster_bat, batch_size=8).run(trace)
    assert namespace_snapshot(store_seq) == namespace_snapshot(store_bat)


# ---------------------------------------------------------------------------
# 2. cost conservation
# ---------------------------------------------------------------------------

def test_opcost_conserved_across_batching():
    _, cluster, ns = _build(4)
    stats = RequestPipeline(cluster, batch_size=8).run(_trace(ns))
    per_nn = OpCost()
    for c in stats.per_nn_cost.values():
        per_nn.merge(c)
    per_op = OpCost()
    for o in stats.outcomes:
        if o.ok:
            per_op.merge(o.result.cost)
    assert per_nn.as_dict() == stats.total_cost.as_dict() == per_op.as_dict()
    # every op got an outcome, and namenode op counters agree
    assert stats.ok + stats.failed == len(stats.outcomes)
    assert sum(stats.per_nn_ops.values()) == stats.ok


def test_batching_saves_round_trips():
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref)
    _, cluster_seq, _ = _build(1)
    seq = RequestPipeline(cluster_seq, batch_size=1).run(trace)
    _, cluster_bat, _ = _build(1)
    bat = RequestPipeline(cluster_bat, batch_size=16).run(trace)
    assert bat.total_cost.round_trips < seq.total_cost.round_trips
    # reads dominate the §7.2 mix => savings should be substantial
    assert bat.total_cost.round_trips <= 0.95 * seq.total_cost.round_trips


def test_concurrent_pipeline_namespace_consistent():
    """Threaded namenodes over the shared store: every op completes and
    the namespace matches a sequential run of the same trace (the trace's
    mutations target distinct paths, so interleaving is benign)."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref, n_ops=200)
    store_seq, cluster_seq, _ = _build(1)
    RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_con, cluster_con, _ = _build(4)
    stats = RequestPipeline(cluster_con, batch_size=8,
                            concurrent=True).run(trace)
    assert stats.ok + stats.failed == len(trace)
    assert namespace_snapshot(store_con) == namespace_snapshot(store_seq)


# ---------------------------------------------------------------------------
# 3. vectorized partition grouping (phash kernel path)
# ---------------------------------------------------------------------------

def test_vectorized_partitions_match_store():
    from repro.core.namenode import _partitions_for
    store = MetadataStore(n_datanodes=4)
    ids = [1, 2, 3, 999, 12345, 2**31 - 1, 64, 65]
    expect = [store.table("inode").partition_of(i) for i in ids]
    # scalar path (small batch) and forced kernel path must both agree
    assert _partitions_for(ids, store.n_partitions) == expect
    assert _partitions_for(ids, store.n_partitions, min_batch=1) == expect
    assert expect == [_hash_key(i) % store.n_partitions for i in ids]


# ---------------------------------------------------------------------------
# 4. trace generation + DES scaling
# ---------------------------------------------------------------------------

def test_spotify_trace_mix():
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    wl = SpotifyWorkload(ns, seed=3, mix=SPOTIFY_TRACE_MIX)
    hist = wl.mix_histogram(20_000)
    assert 64.0 < hist.get("read", 0) < 70.0          # ~67% getBlockLocations
    assert 10.0 < hist.get("ls", 0) < 14.0            # ~12% listStatus


def test_trace_replay_deterministic():
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=10)
    trace = make_spotify_trace(ns, 50, seed=9)
    r1, r2 = TraceReplay(trace), TraceReplay(trace)
    a = [r1.next_op() for _ in range(120)]
    b = [r2.next_op() for _ in range(120)]
    assert a == b
    assert a[:50] == trace and a[50:100] == trace      # cyclic


def test_batched_sim_throughput_scales_with_namenodes():
    profiles = profile_ops()
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    trace = make_spotify_trace(ns, 1000, seed=11)
    tps = []
    for n_nn in (1, 4):
        sim = BatchedHopsFSSim(n_namenodes=n_nn, n_ndb=8,
                               profiles=profiles, batch_size=16, seed=1)
        sim.start_clients(150 * n_nn, TraceReplay(trace))
        tps.append(sim.run(0.15).throughput)
    assert tps[1] > 2.0 * tps[0]


def test_batched_sim_batching_engages_under_load():
    profiles = profile_ops()
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    trace = make_spotify_trace(ns, 1000, seed=11)
    sim = BatchedHopsFSSim(n_namenodes=1, n_ndb=4, profiles=profiles,
                           batch_size=16, seed=1)
    sim.start_clients(400, TraceReplay(trace))
    res = sim.run(0.15)
    assert res.completed > 0
    assert sim.batched_ops > 0.2 * res.completed
    # nn-side counter ticks at batch finish; client-side `completed` half an
    # RTT later, so in-flight ops at the horizon leave nn counters ahead
    assert sum(sim.nn_ops_completed) >= res.completed
